//! Fig 6 — residual-gradient histograms at the final epoch: LS vs AdaComp
//! (FC layer, conv dense). Paper: the LS histogram has tails out to +/-240K;
//! AdaComp's is orders of magnitude tighter.
//!
//!   cargo run --release --example fig6_histogram [-- --epochs 25]

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::metrics::LogHistogram;
use adacomp::util::cli::Args;
use adacomp::util::json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let cases: &[(&str, Kind, usize)] = &[
        ("ls-lt300", Kind::LocalSelect, 300),
        ("adacomp-lt5000", Kind::AdaComp, 5000),
    ];

    let mut summaries = Vec::new();
    let mut out = Vec::new();
    for (name, kind, lt) in cases {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.run_name = format!("fig6-{name}");
        w.cfg.compression.kind = *kind;
        w.cfg.compression.lt_fc = *lt;
        w.cfg.compression.kind_conv = Some(Kind::None);
        w.cfg.divergence_loss = 1e30;

        let meta = w.manifest.model(&w.model)?.clone();
        let fc_idx = meta
            .layout
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind != adacomp::LayerKind::Conv)
            .max_by_key(|(_, l)| l.len())
            .map(|(i, _)| i)
            .unwrap();

        let epochs = w.cfg.epochs;
        println!("== {} ==", w.cfg.run_name);
        let mut hist = LogHistogram::new(1e-6, 60);
        let mut hook = |epoch: usize, comp: &dyn adacomp::Compressor, _dw: &[f32]| {
            if epoch + 1 == epochs {
                hist.add_all(comp.residue(fc_idx));
            }
        };
        let rec = w.run_with_hook(&mut hook)?;
        let edge = hist.max_magnitude_edge();
        println!("  final-epoch RG histogram: {} samples, max |RG| bucket ~ {:.3e}", hist.total(), edge);
        // print a compact, log-binned bar view
        for (e, c) in hist.series() {
            if c > 0 {
                let bar = "#".repeat(((c as f64).log2().max(0.0) as usize).min(40));
                println!("  {:>12.3e}  {:>8}  {}", e, c, bar);
            }
        }
        summaries.push((name.to_string(), edge, hist.to_json()));
        out.push(rec);
    }

    println!("\nFig 6 summary:");
    let mut t = report::Table::new(&["run", "max |RG| bucket"]);
    for (name, edge, _) in &summaries {
        t.row(vec![name.clone(), format!("{:.3e}", edge)]);
    }
    t.print();
    let (a, _) = (summaries[0].1, summaries[1].1);
    println!(
        "paper shape: LS tail >> AdaComp tail (here {:.1e} vs {:.1e}, ratio {:.1e})",
        summaries[0].1,
        summaries[1].1,
        a / summaries[1].1.max(1e-30)
    );
    std::fs::create_dir_all("results")?;
    let j = json::arr(
        summaries
            .into_iter()
            .map(|(n, e, h)| {
                json::obj(vec![
                    ("run", json::s(&n)),
                    ("max_edge", json::num(e as f64)),
                    ("histogram", h),
                ])
            })
            .collect(),
    );
    std::fs::write("results/fig6_histogram.json", j.to_string())?;
    report::save_runs("fig6_runs", &out)?;
    println!("saved results/fig6_histogram.json");
    Ok(())
}
