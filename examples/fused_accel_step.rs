//! L1-on-accelerator demo: run the AdaComp compression *as compiled HLO*
//! (the Pallas kernels from python/compile/kernels/adacomp.py, AOT-lowered)
//! from rust via PJRT, verify it agrees with the rust hot-path
//! implementation bit-for-bit on the selection, and compare wall time.
//!
//! This is the deployment shape for accelerator fleets: compression runs
//! where the gradients live (device memory), and only the packed bytes ever
//! reach the host/NIC. On this CPU testbed the rust path wins (no PJRT
//! round-trip); the VMEM/roofline estimate for real TPUs is in
//! `python -m compile.vmem` and DESIGN.md §Hardware-Adaptation.
//!
//!   cargo run --release --example fused_accel_step

use std::path::Path;

use adacomp::compress::{self, Config, Kind};
use adacomp::models::{LayerKind, Layout};
use adacomp::runtime::pjrt::compile_hlo;
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats};

fn main() -> anyhow::Result<()> {
    let dir = adacomp::harness::default_artifacts_dir();
    let mut rows = Vec::new();
    for (n, lt) in [(2400usize, 50usize), (25600, 50), (51200, 50), (10240, 500)] {
        let path = Path::new(dir).join(format!("adacomp_n{n}_lt{lt}.hlo.txt"));
        if !path.exists() {
            eprintln!("missing {} — run `make artifacts`", path.display());
            continue;
        }
        let exe = compile_hlo(&path)?;

        let mut rng = Pcg32::seeded(7);
        let g = rng.normal_vec(n, 0.5);
        let dw = rng.normal_vec(n, 0.2);
        let h: Vec<f32> = g.iter().zip(dw.iter()).map(|(a, b)| a + b).collect();

        // HLO path
        let run_hlo = || -> anyhow::Result<(Vec<f32>, Vec<f32>, f32)> {
            let out = exe
                .execute::<xla::Literal>(&[xla::Literal::vec1(&g), xla::Literal::vec1(&h)])
                .map_err(|e| anyhow::anyhow!("{e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok((
                parts[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                parts[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
                parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0],
            ))
        };
        let (gq_hlo, res_hlo, scale_hlo) = run_hlo()?;

        // rust hot path, seeded to the same state: residue0 = g - dw
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: lt,
            ..Config::with_kind(Kind::AdaComp)
        };
        // emulate residue = g - dw by two folds: pack is stateful, so use the
        // pure contract instead: G = g, dW = dw -> fresh compressor packing
        // dw_total = g gives G = g but H = g + dw only when dw == g… so
        // compare against a transliteration with explicit (G, dW):
        let (gq_rs, res_rs, scale_rs, sent_rs) = rust_pure(&g, &dw, lt);

        let mut mism = 0usize;
        for i in 0..n {
            if (gq_hlo[i] - gq_rs[i]).abs() > 1e-5 || (res_hlo[i] - res_rs[i]).abs() > 1e-5 {
                mism += 1;
            }
        }
        assert_eq!(mism, 0, "HLO vs rust mismatch at n={n}");
        assert!((scale_hlo - scale_rs).abs() < 1e-5);

        let t_hlo = Stats::from(&time_n(|| {
            let _ = run_hlo();
        }, 2, 10));
        let mut comp = compress::build(&cfg, &layout);
        let t_rust = Stats::from(&time_n(
            || {
                std::hint::black_box(comp.pack_layer(0, &dw));
            },
            2,
            50,
        ));
        rows.push((n, lt, sent_rs, t_hlo.mean_ns, t_rust.mean_ns));
        println!(
            "n={n:<7} lt={lt:<4} sent={sent_rs:<6} HLO(pallas) {}  rust-hot-path {}  agree: yes",
            fmt_ns(t_hlo.mean_ns),
            fmt_ns(t_rust.mean_ns)
        );
    }
    println!("\nAll L1 HLO graphs agree with the rust implementation (same selection,");
    println!("values, residues, scale) — three implementations, one semantics.");
    Ok(())
}

/// Transliteration of Algorithm 2 on explicit (G, dW) — identical to
/// tests/golden.rs and the python oracle.
fn rust_pure(g: &[f32], dw: &[f32], lt: usize) -> (Vec<f32>, Vec<f32>, f32, usize) {
    let n = g.len();
    let nbins = n.div_ceil(lt);
    let mut gmax = vec![0.0f32; nbins];
    for b in 0..nbins {
        let hi = ((b + 1) * lt).min(n);
        for i in b * lt..hi {
            gmax[b] = gmax[b].max(g[i].abs());
        }
    }
    let scale = gmax.iter().sum::<f32>() / nbins as f32;
    let mut gq = vec![0.0f32; n];
    let mut residue = g.to_vec();
    let mut sent = 0usize;
    for b in 0..nbins {
        if gmax[b] <= 0.0 {
            continue;
        }
        let hi = ((b + 1) * lt).min(n);
        for i in b * lt..hi {
            if (g[i] + dw[i]).abs() >= gmax[b] {
                sent += 1;
                let v = if g[i] > 0.0 {
                    scale
                } else if g[i] < 0.0 {
                    -scale
                } else {
                    0.0
                };
                gq[i] = v;
                residue[i] = g[i] - v;
            }
        }
    }
    (gq, residue, scale, sent)
}
