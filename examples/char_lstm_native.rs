//! The paper's recurrent scenario, fully offline: char-LSTM on the
//! Markov-Shakespeare corpus through the hermetic layer-graph backend
//! (embed -> LSTM x2 -> fc head), AdaComp at the fc/lstm/embed L_T default
//! of 500 vs the uncompressed baseline — Table 2's "LSTM compresses ~200X
//! with negligible degradation" claim at CPU-testbed scale.
//!
//!   cargo run --release --example char_lstm_native
//!
//! No artifacts needed (the workload forces `--backend native`). Flags:
//! --epochs, --learners, --batch, --seq-len, --train, --test, --threads.

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // this harness is native-by-construction; let explicit flags win
    if !argv.iter().any(|a| a == "--backend" || a.starts_with("--backend=")) {
        argv.extend(["--backend".to_string(), "native".to_string()]);
    }
    let args = Args::parse_from(argv, &[]);

    let mut runs = Vec::new();
    for kind in [Kind::None, Kind::AdaComp] {
        let mut w = Workload::from_args(&args, "char_lstm")?;
        w.cfg.compression.kind = kind;
        if args.get("learners").is_none() {
            // 2 learners so the fabric carries real recurrent-layer traffic
            w.cfg.n_learners = 2;
        }
        w.cfg.run_name = format!("char-lstm-{}", kind.name());
        println!(
            "== {} [{}] | L_T(fc/lstm/embed) {} ==",
            w.cfg.run_name,
            w.backend,
            w.cfg.compression.lt_fc
        );
        let rec = w.run()?;
        println!("{}", report::epoch_line(&rec));
        runs.push(rec);
    }

    let mut t = report::Table::new(&[
        "scheme",
        "test-err %",
        "test loss",
        "rate (wire)",
        "rate (paper)",
        "bytes up",
    ]);
    for r in &runs {
        let last = r.epochs.last().expect("at least one epoch");
        t.row(vec![
            r.scheme.clone(),
            format!("{:.2}", r.final_test_error()),
            format!("{:.3}", last.test_loss),
            format!("{:.1}x", r.mean_rate_wire()),
            format!("{:.1}x", r.mean_rate_paper()),
            format!("{}", r.fabric.bytes_up),
        ]);
    }
    println!();
    t.print();
    println!(
        "\npaper context: Table 2 reports ~200X effective compression on\n\
         fully-connected/recurrent layers at L_T=500 with negligible\n\
         accuracy loss; the paper-accounting rate above is the comparable\n\
         number at this scaled size."
    );
    let (j, c) = report::save_runs("char_lstm_native", &runs)?;
    println!("saved {j} and {c}");
    Ok(())
}
