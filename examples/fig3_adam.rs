//! Fig 3 — AdaComp under Adam vs SGD on CIFAR-CNN.
//!
//! Paper: Adam baseline 18.1% vs Adam+AdaComp 18.3%; Adam converges faster
//! initially than SGD with the same compression rates.
//!
//!   cargo run --release --example fig3_adam [-- --epochs 20]

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::optim::LrSchedule;
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let mut runs = Vec::new();
    for (opt, lr, kind) in [
        ("sgd", 0.02, Kind::None),
        ("sgd", 0.02, Kind::AdaComp),
        ("adam", 1e-3, Kind::None),
        ("adam", 1e-3, Kind::AdaComp),
    ] {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.optimizer = opt.into();
        if args.get("lr").is_none() {
            w.cfg.lr = LrSchedule::Constant(lr);
        }
        w.cfg.compression.kind = kind;
        w.cfg.run_name = format!("fig3-{}-{}", opt, kind.name());
        println!("== {} ==", w.cfg.run_name);
        let rec = w.run()?;
        let pts: Vec<String> = rec
            .epochs
            .iter()
            .map(|e| format!("({}, {:.2})", e.epoch, e.test_error_pct))
            .collect();
        println!("  {}", pts.join(" "));
        runs.push(rec);
    }

    let mut t = report::Table::new(&["optimizer", "scheme", "final err%", "early err% (1/4 in)", "rate(paper)"]);
    for r in &runs {
        let quarter = r.epochs.len() / 4;
        t.row(vec![
            r.optimizer.clone(),
            r.scheme.clone(),
            format!("{:.2}", r.final_test_error()),
            format!("{:.2}", r.epochs[quarter].test_error_pct),
            format!("{:.0}x", r.mean_rate_paper()),
        ]);
    }
    println!("\nFig 3 (paper: Adam faster initial convergence, similar final; compression has no impact):");
    t.print();
    report::save_runs("fig3_adam", &runs)?;
    Ok(())
}
