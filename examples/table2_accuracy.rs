//! Table 2 — baseline vs AdaComp top-1 error across the model zoo.
//!
//! Paper settings: conv L_T=50, FC/LSTM L_T=500; same hyper-parameters as
//! the uncompressed baseline; learner counts per model. Workloads are the
//! scaled substitutes of DESIGN.md §Substitutions, so compare *deltas*
//! (AdaComp - baseline), not absolute errors, against the paper.
//!
//!   cargo run --release --example table2_accuracy
//!   cargo run --release --example table2_accuracy -- --models cifar_cnn,char_lstm --learners 4
//!   cargo run --release --example table2_accuracy -- --epochs 30   # closer to paper scale

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::{Args};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    // (model, learners) mirroring Table 2's "Learner number" row, scaled to
    // what the batch variants support.
    let default_plan: &[(&str, usize)] = &[
        ("mnist_dnn", 4),
        ("mnist_cnn", 4),
        ("cifar_cnn", 8),
        ("alexnet_s", 8),
        ("resnet18_s", 4),
        ("bn50_dnn_s", 8),
        ("char_lstm", 2),
    ];
    let models: Vec<String> = match args.get("models") {
        Some(list) => list.split(',').map(|s| s.to_string()).collect(),
        None => default_plan.iter().map(|(m, _)| m.to_string()).collect(),
    };

    let mut t = report::Table::new(&[
        "model",
        "learners",
        "baseline err%",
        "adacomp err%",
        "delta",
        "conv rate",
        "fc rate",
        "diverged",
    ]);
    let mut all = Vec::new();
    for model in &models {
        let learners = args.usize_or(
            "learners",
            default_plan
                .iter()
                .find(|(m, _)| m == model)
                .map(|(_, l)| *l)
                .unwrap_or(2),
        );
        let mut errs = Vec::new();
        let mut conv_rate = String::from("-");
        let mut fc_rate = String::from("-");
        let mut diverged = false;
        for kind in [Kind::None, Kind::AdaComp] {
            let mut w = Workload::from_args(&args, model)?;
            w.cfg.n_learners = learners;
            w.cfg.batch_per_learner =
                (adacomp::harness::defaults_for(model).batch / learners).max(1);
            w.cfg.compression.kind = kind;
            w.cfg.run_name = format!("table2-{model}-{}-{}L", kind.name(), learners);
            eprintln!("running {} ...", w.cfg.run_name);
            let rec = w.run()?;
            eprintln!("  {}", report::epoch_line(&rec));
            errs.push(rec.final_test_error());
            if kind == Kind::AdaComp {
                let last = rec.epochs.last().unwrap();
                if last.comp_conv.elements > 0 {
                    conv_rate = format!("{:.0}x", last.comp_conv.rate_paper());
                }
                fc_rate = format!("{:.0}x", last.comp_fc.rate_paper());
                diverged = rec.diverged;
            }
            all.push(rec);
        }
        t.row(vec![
            model.clone(),
            learners.to_string(),
            format!("{:.2}", errs[0]),
            format!("{:.2}", errs[1]),
            format!("{:+.2}", errs[1] - errs[0]),
            conv_rate,
            fc_rate,
            diverged.to_string(),
        ]);
    }
    println!("\nTable 2 (scaled workloads — compare deltas and rates with the paper):");
    t.print();
    println!("paper: deltas within ~0.5%, conv ~40x, FC/LSTM ~200x");
    report::save_runs("table2_accuracy", &all)?;
    Ok(())
}
