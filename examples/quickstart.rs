//! Quickstart: train MNIST-DNN with and without AdaComp and compare — the
//! 60-second tour of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Flags: --model, --epochs, --learners, --lt, ... (see `adacomp train --help`).

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let mut runs = Vec::new();
    for kind in [Kind::None, Kind::AdaComp] {
        let mut w = Workload::from_args(&args, "mnist_dnn")?;
        w.cfg.compression.kind = kind;
        if args.get("learners").is_none() {
            // 2 learners by default so the fabric has real traffic to report
            w.cfg.n_learners = 2;
            w.cfg.batch_per_learner = 50;
        }
        w.cfg.run_name = format!("quickstart-{}", kind.name());
        println!("== {} ==", w.cfg.run_name);
        let rec = w.run()?;
        println!("{}", report::epoch_line(&rec));
        runs.push(rec);
    }

    let mut t = report::Table::new(&[
        "scheme",
        "test-err %",
        "rate (wire)",
        "rate (paper)",
        "bytes up",
    ]);
    for r in &runs {
        t.row(vec![
            r.scheme.clone(),
            format!("{:.2}", r.final_test_error()),
            format!("{:.1}x", r.mean_rate_wire()),
            format!("{:.1}x", r.mean_rate_paper()),
            format!("{}", r.fabric.bytes_up),
        ]);
    }
    println!();
    t.print();
    let (j, c) = report::save_runs("quickstart", &runs)?;
    println!("\nsaved {j} and {c}");
    Ok(())
}
