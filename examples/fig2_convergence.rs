//! Fig 2 — convergence curves: baseline vs AdaComp at several learner
//! counts, plus the paper's stress tests (extreme L_T).
//!
//!   cargo run --release --example fig2_convergence -- --model cifar_cnn --learner-counts 1,8
//!   cargo run --release --example fig2_convergence -- --stress
//!
//! Stress test (paper Fig 2a/2b): CIFAR-CNN with L_T=500 everywhere;
//! AlexNet with conv L_T=800 / FC L_T=8000.

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["stress"]);
    let model = args.str_or("model", "cifar_cnn");
    let mut runs = Vec::new();

    if args.flag("stress") {
        // paper's "Stress test under Extreme Compression"
        let cases: Vec<(String, usize, usize)> = if model == "alexnet_s" {
            vec![("stress conv800/fc8000".into(), 800, 8000)]
        } else {
            vec![("stress L_T=500/500".into(), 500, 500)]
        };
        for (name, lt_conv, lt_fc) in cases {
            let mut w = Workload::from_args(&args, &model)?;
            w.cfg.run_name = format!("{model}-{name}");
            w.cfg.compression.kind = Kind::AdaComp;
            w.cfg.compression.lt_conv = lt_conv;
            w.cfg.compression.lt_fc = lt_fc;
            println!("== {} ==", w.cfg.run_name);
            let rec = w.run()?;
            print_curve(&rec);
            runs.push(rec);
        }
    }

    for learners in args.usize_list_or("learner-counts", &[1, 4, 8]) {
        for kind in [Kind::None, Kind::AdaComp] {
            let mut w = Workload::from_args(&args, &model)?;
            let base_batch = adacomp::harness::defaults_for(&model).batch;
            w.cfg.n_learners = learners;
            w.cfg.batch_per_learner = (base_batch / learners).max(1);
            w.cfg.compression.kind = kind;
            w.cfg.run_name = format!("{model}-{}-{}L", kind.name(), learners);
            println!("== {} ==", w.cfg.run_name);
            let rec = w.run()?;
            print_curve(&rec);
            runs.push(rec);
        }
    }

    println!("\nFig 2 series (epoch, test-err%) per run saved to results/fig2_convergence.*");
    let mut t = report::Table::new(&["run", "final err%", "rate(paper)", "diverged"]);
    for r in &runs {
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.final_test_error()),
            format!("{:.0}x", r.mean_rate_paper()),
            r.diverged.to_string(),
        ]);
    }
    t.print();
    report::save_runs("fig2_convergence", &runs)?;
    Ok(())
}

fn print_curve(rec: &adacomp::metrics::RunRecord) {
    let pts: Vec<String> = rec
        .epochs
        .iter()
        .map(|e| format!("({}, {:.2})", e.epoch, e.test_error_pct))
        .collect();
    println!("  {}", pts.join(" "));
}
