//! Fig 7 — compression rate vs (a) minibatch size and (b) learner count,
//! AdaComp vs Dryden, CIFAR-CNN.
//!
//! (a) single learner, minibatch 128..2048: rate degrades with batch for
//!     both, but AdaComp stays ~5-10x ahead of Dryden.
//! (b) super-minibatch fixed at 128 split over 1..128 learners: more
//!     learners -> smaller local batch -> higher AdaComp rate.
//!
//!   cargo run --release --example fig7_scaling -- --sweep mb
//!   cargo run --release --example fig7_scaling -- --sweep learners
//!   (default: both)

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let sweep = args.str_or("sweep", "both");
    let mut runs = Vec::new();

    if sweep == "mb" || sweep == "both" {
        println!("== Fig 7a: compression rate vs minibatch size (1 learner) ==");
        let mut t = report::Table::new(&["minibatch", "adacomp rate", "dryden rate", "adacomp err%", "dryden err%"]);
        for &mb in &args.usize_list_or("minibatches", &[128, 256, 512, 1024, 2048]) {
            let mut rates = Vec::new();
            let mut errs = Vec::new();
            for kind in [Kind::AdaComp, Kind::Dryden] {
                let mut w = Workload::from_args(&args, "cifar_cnn")?;
                w.cfg.n_learners = 1;
                w.cfg.batch_per_learner = mb;
                // keep samples-per-epoch constant: fewer steps at larger mb
                w.cfg.steps_per_epoch = (5120 / mb).max(1);
                w.cfg.compression.kind = kind;
                w.cfg.run_name = format!("fig7a-{}-mb{}", kind.name(), mb);
                eprintln!("running {} ...", w.cfg.run_name);
                let rec = w.run()?;
                rates.push(rec.mean_rate_paper());
                errs.push(rec.final_test_error());
                runs.push(rec);
            }
            t.row(vec![
                mb.to_string(),
                format!("{:.0}x", rates[0]),
                format!("{:.0}x", rates[1]),
                format!("{:.2}", errs[0]),
                format!("{:.2}", errs[1]),
            ]);
        }
        t.print();
        println!("paper shape: both degrade with minibatch; AdaComp ~5-10x better\n");
    }

    if sweep == "learners" || sweep == "both" {
        println!("== Fig 7b: AdaComp rate vs learners (super-minibatch 128) ==");
        let mut t = report::Table::new(&["learners", "batch/learner", "rate (paper)", "rate (wire)", "err%"]);
        for &n in &args.usize_list_or("learner-counts", &[1, 2, 8, 32, 128]) {
            let mut w = Workload::from_args(&args, "cifar_cnn")?;
            w.cfg.n_learners = n;
            w.cfg.batch_per_learner = (128 / n).max(1);
            w.cfg.compression.kind = Kind::AdaComp;
            w.cfg.run_name = format!("fig7b-{}L", n);
            eprintln!("running {} ...", w.cfg.run_name);
            let rec = w.run()?;
            t.row(vec![
                n.to_string(),
                w.cfg.batch_per_learner.to_string(),
                format!("{:.0}x", rec.mean_rate_paper()),
                format!("{:.0}x", rec.mean_rate_wire()),
                format!("{:.2}", rec.final_test_error()),
            ]);
            runs.push(rec);
        }
        t.print();
        println!("paper shape: rate grows with learner count (smaller local batch = lower activity)");
    }

    report::save_runs("fig7_scaling", &runs)?;
    Ok(())
}
