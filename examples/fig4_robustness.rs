//! Fig 4 — test error vs effective compression rate for Dryden, Local
//! Selection, AdaComp (SGD) and AdaComp (Adam) on CIFAR-CNN, with *all*
//! layers compressed at the same rate (lt_override).
//!
//! Paper: below ~250x everyone is fine; past that LS and Dryden blow up
//! while AdaComp stays ~22% even beyond 2000x.
//!
//!   cargo run --release --example fig4_robustness
//!   cargo run --release --example fig4_robustness -- --lts 50,200,500,2000,5000 --epochs 20

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::optim::LrSchedule;
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let lts = args.usize_list_or("lts", &[50, 200, 500, 2000, 5000]);
    // Dryden fractions chosen to land on comparable effective rates:
    // rate ~ 32 bits*f^-1 / 32 bits = 1/f  => f = 1/rate
    let fractions: Vec<f64> = lts
        .iter()
        .map(|&lt| 1.0 / (lt as f64 * 2.0)) // LS rate ~ lt*2 under 16-bit slots
        .collect();

    let mut runs = Vec::new();
    let mut series: Vec<(String, f64, f64, bool)> = Vec::new(); // (scheme, rate, err, diverged)

    for (i, &lt) in lts.iter().enumerate() {
        for (label, kind, opt) in [
            ("adacomp-sgd", Kind::AdaComp, "sgd"),
            ("adacomp-adam", Kind::AdaComp, "adam"),
            ("ls-sgd", Kind::LocalSelect, "sgd"),
        ] {
            let mut w = Workload::from_args(&args, "cifar_cnn")?;
            w.cfg.compression.kind = kind;
            w.cfg.compression.lt_override = lt;
            w.cfg.optimizer = opt.into();
            if opt == "adam" && args.get("lr").is_none() {
                w.cfg.lr = LrSchedule::Constant(1e-3);
            }
            w.cfg.run_name = format!("fig4-{label}-lt{lt}");
            eprintln!("running {} ...", w.cfg.run_name);
            let rec = w.run()?;
            eprintln!("  {}", report::epoch_line(&rec));
            series.push((
                label.to_string(),
                rec.mean_rate_paper(),
                rec.final_test_error(),
                rec.diverged,
            ));
            runs.push(rec);
        }
        // Dryden at a matched rate
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.compression.kind = Kind::Dryden;
        w.cfg.compression.topk_fraction = fractions[i];
        w.cfg.run_name = format!("fig4-dryden-f{:.5}", fractions[i]);
        eprintln!("running {} ...", w.cfg.run_name);
        let rec = w.run()?;
        eprintln!("  {}", report::epoch_line(&rec));
        series.push((
            "dryden-sgd".to_string(),
            rec.mean_rate_paper(),
            rec.final_test_error(),
            rec.diverged,
        ));
        runs.push(rec);
    }

    println!("\nFig 4 series: test error vs effective compression rate");
    let mut t = report::Table::new(&["scheme", "eff. rate (paper acct)", "test-err %", "diverged"]);
    series.sort_by(|a, b| (a.0.clone(), a.1).partial_cmp(&(b.0.clone(), b.1)).unwrap());
    for (scheme, rate, err, div) in &series {
        t.row(vec![
            scheme.clone(),
            format!("{:.0}x", rate),
            format!("{:.2}", err),
            div.to_string(),
        ]);
    }
    t.print();
    println!("paper shape: AdaComp flat (~18-22%) across the sweep; LS and Dryden degrade/diverge at high rates");
    report::save_runs("fig4_robustness", &runs)?;
    Ok(())
}
