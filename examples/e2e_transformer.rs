//! End-to-end system driver: distributed data-parallel training of the
//! char-transformer LM on the Shakespeare corpus with AdaComp compression,
//! through the full stack — L2 JAX model AOT-lowered to HLO, executed from
//! rust via PJRT; AdaComp pack/exchange/unpack per step over the ring
//! topology; Adam at the central update. Logs the loss curve and reports
//! throughput + compression; results recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example e2e_transformer
//!   cargo run --release --example e2e_transformer -- --steps 300 --learners 4
//!
//! The exported transformer is d_model=256 / 4 layers / 4 heads / seq 96
//! (~3.2M params). The paper's prompt target (~100M) is a knob away —
//! python -m compile.aot exports any size via model.build_transformer — but
//! a CPU testbed trains this size in minutes, which is what CI needs.

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;
use adacomp::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let steps = args.usize_or("steps", 200);
    let learners = args.usize_or("learners", 4);

    let mut runs = Vec::new();
    for kind in [Kind::AdaComp, Kind::None] {
        let mut w = Workload::from_args(&args, "transformer")?;
        w.cfg.n_learners = learners;
        w.cfg.batch_per_learner = args.usize_or("batch", (4 / learners).max(1));
        // steps are what matter for the e2e driver: one "epoch" = 20 steps
        w.cfg.steps_per_epoch = 20;
        w.cfg.epochs = steps / 20;
        w.cfg.compression.kind = kind;
        w.cfg.run_name = format!("e2e-transformer-{}", kind.name());
        println!(
            "== {} : {} learners x batch {} x {} steps ==",
            w.cfg.run_name, w.cfg.n_learners, w.cfg.batch_per_learner, steps
        );
        let sw = Stopwatch::start();
        let rec = w.run()?;
        let secs = sw.secs();
        for e in &rec.epochs {
            println!(
                "  step {:>4}  train-loss {:.4}  test next-char err {:.2}%  rate(paper) {:>6.1}x",
                (e.epoch + 1) * 20,
                e.train_loss,
                e.test_error_pct,
                e.comp_all.rate_paper(),
            );
        }
        let tokens = (steps * w.cfg.n_learners * w.cfg.batch_per_learner * 96) as f64;
        println!(
            "  wall {:.1}s  |  {:.0} tokens/s  |  bytes up {}  |  sim comm time {:.3}s",
            secs,
            tokens / secs,
            rec.fabric.bytes_up,
            rec.fabric.sim_time_s
        );
        runs.push(rec);
    }

    let mut t = report::Table::new(&[
        "scheme",
        "final loss",
        "next-char err%",
        "rate (paper)",
        "bytes up",
    ]);
    for r in &runs {
        let e = r.epochs.last().unwrap();
        t.row(vec![
            r.scheme.clone(),
            format!("{:.4}", e.train_loss),
            format!("{:.2}", e.test_error_pct),
            format!("{:.0}x", r.mean_rate_paper()),
            format!("{}", r.fabric.bytes_up),
        ]);
    }
    println!();
    t.print();
    let loss_gap = runs[0].epochs.last().unwrap().train_loss
        - runs[1].epochs.last().unwrap().train_loss;
    println!("\nloss gap (adacomp - baseline): {loss_gap:+.4} (paper claim: negligible)");
    report::save_runs("e2e_transformer", &runs)?;
    Ok(())
}
