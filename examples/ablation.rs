//! Ablations of AdaComp's design choices (DESIGN.md §5):
//!
//!   scale-factor  — the soft threshold H = residue + c*dW; paper studied
//!                   c in 1.5..3.0 and picked 2.0 "for computational ease"
//!   quantizer     — per-layer scale (paper) vs per-bin scale
//!   topology      — ring vs parameter server (identical math, different
//!                   bytes/latency profile)
//!
//!   cargo run --release --example ablation [-- --epochs 8]

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let mut runs = Vec::new();

    println!("== ablation: soft-threshold scale factor ==");
    let mut t = report::Table::new(&["factor", "test-err %", "rate (paper)", "sent/elem"]);
    for factor in [1.5f32, 2.0, 2.5, 3.0] {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.compression.kind = Kind::AdaComp;
        w.cfg.compression.scale_factor = factor;
        w.cfg.run_name = format!("ablate-sf{factor}");
        let rec = w.run()?;
        let last = rec.epochs.last().unwrap();
        t.row(vec![
            format!("{factor}"),
            format!("{:.2}", rec.final_test_error()),
            format!("{:.0}x", rec.mean_rate_paper()),
            format!("{:.5}", last.comp_all.sparsity()),
        ]);
        runs.push(rec);
    }
    t.print();

    println!("\n== ablation: per-layer vs per-bin quantization scale ==");
    let mut t = report::Table::new(&["quantizer", "test-err %", "rate (paper)"]);
    for per_bin in [false, true] {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.compression.kind = Kind::AdaComp;
        w.cfg.compression.per_bin_scale = per_bin;
        w.cfg.run_name = format!("ablate-q-{}", if per_bin { "bin" } else { "layer" });
        let rec = w.run()?;
        t.row(vec![
            if per_bin { "per-bin max" } else { "per-layer mean|gmax| (paper)" }.into(),
            format!("{:.2}", rec.final_test_error()),
            format!("{:.0}x", rec.mean_rate_paper()),
        ]);
        runs.push(rec);
    }
    t.print();

    println!("\n== ablation: topology (identical math, different wire profile) ==");
    let mut t = report::Table::new(&["topology", "test-err %", "bytes up", "sim comm time"]);
    for topo in ["ring", "ps", "ps:4", "hier:4"] {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.compression.kind = Kind::AdaComp;
        w.cfg.n_learners = 8;
        w.cfg.batch_per_learner = 16;
        w.cfg.topology = topo.into();
        w.cfg.run_name = format!("ablate-topo-{topo}");
        let rec = w.run()?;
        t.row(vec![
            topo.into(),
            format!("{:.2}", rec.final_test_error()),
            format!("{}", rec.fabric.bytes_up),
            format!("{:.3}s", rec.fabric.sim_time_s),
        ]);
        runs.push(rec);
    }
    t.print();

    report::save_runs("ablation", &runs)?;
    Ok(())
}
