//! Fig 5 — 95th percentile of |dW| and |Residual Gradient| over epochs:
//! LS (L_T=200, L_T=300) vs AdaComp (L_T=5000), FC layer only compressed
//! (conv layers dense, as in the paper's focused experiment).
//!
//! Paper: LS@200 stable; LS@300 grows exponentially (positive feedback ->
//! divergence); AdaComp@5000 bumps early then stabilizes.
//!
//!   cargo run --release --example fig5_residual_growth [-- --epochs 25]

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::metrics::percentile;
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let cases: &[(&str, Kind, usize)] = &[
        ("ls-lt200", Kind::LocalSelect, 200),
        ("ls-lt300", Kind::LocalSelect, 300),
        ("adacomp-lt5000", Kind::AdaComp, 5000),
    ];

    let mut runs = Vec::new();
    let mut curves: Vec<(String, Vec<(usize, f32, f32)>)> = Vec::new();

    for (name, kind, lt) in cases {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.run_name = format!("fig5-{name}");
        w.cfg.compression.kind = *kind;
        w.cfg.compression.lt_fc = *lt;
        w.cfg.compression.kind_conv = Some(Kind::None); // conv dense
        // let the run continue past bad losses so we can watch RG grow
        w.cfg.divergence_loss = 1e30;

        // find the fc weight layer (the big one)
        let meta = w.manifest.model(&w.model)?.clone();
        let fc_idx = meta
            .layout
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind != adacomp::LayerKind::Conv)
            .max_by_key(|(_, l)| l.len())
            .map(|(i, _)| i)
            .unwrap();

        println!("== {} (tracking layer '{}') ==", w.cfg.run_name, meta.layout.layers[fc_idx].name);
        let mut curve: Vec<(usize, f32, f32)> = Vec::new();
        let mut hook = |epoch: usize, comp: &dyn adacomp::Compressor, dw: &[f32]| {
            let rg95 = percentile(comp.residue(fc_idx), 95.0);
            let l = &meta.layout.layers[fc_idx];
            let dw95 = percentile(&dw[l.offset..l.offset + l.len()], 95.0);
            println!("  epoch {epoch:>3}  dW p95 {dw95:.4e}  RG p95 {rg95:.4e}");
            curve.push((epoch, dw95, rg95));
        };
        let rec = w.run_with_hook(&mut hook)?;
        curves.push((name.to_string(), curve));
        runs.push(rec);
    }

    println!("\nFig 5 summary: RG p95 growth factor (last / first epoch)");
    let mut t = report::Table::new(&["run", "RG p95 first", "RG p95 last", "growth", "final err%"]);
    for ((name, curve), rec) in curves.iter().zip(runs.iter()) {
        let first = curve.first().map(|c| c.2).unwrap_or(0.0).max(1e-12);
        let last = curve.last().map(|c| c.2).unwrap_or(0.0);
        t.row(vec![
            name.clone(),
            format!("{:.3e}", first),
            format!("{:.3e}", last),
            format!("{:.1}x", last / first),
            format!("{:.2}", rec.final_test_error()),
        ]);
    }
    t.print();
    println!("paper shape: LS growth explodes as L_T rises; AdaComp stabilizes even at L_T=5000");
    report::save_runs("fig5_residual_growth", &runs)?;
    Ok(())
}
