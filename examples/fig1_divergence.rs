//! Fig 1 — why conv layers need a better scheme.
//!
//! Three CIFAR-CNN runs (paper Fig 1):
//!   (a) no compression                                  -> baseline error
//!   (b) FC compressed with Dryden top-0.3%, conv dense  -> modest degradation
//!   (c) FC Dryden top-0.3% + conv 1-bit quantization    -> divergence
//!
//!   cargo run --release --example fig1_divergence [-- --epochs 20]

use adacomp::compress::Kind;
use adacomp::harness::{report, Workload};
use adacomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&[]);
    let mut runs = Vec::new();

    let cases: &[(&str, Kind, Option<Kind>)] = &[
        ("baseline (no compression)", Kind::None, None),
        ("FC dryden 0.3%, conv dense", Kind::Dryden, Some(Kind::None)),
        ("FC dryden 0.3% + conv 1-bit", Kind::Dryden, Some(Kind::OneBit)),
    ];

    for (name, fc_kind, conv_kind) in cases {
        let mut w = Workload::from_args(&args, "cifar_cnn")?;
        w.cfg.run_name = name.to_string();
        w.cfg.compression.kind = *fc_kind;
        w.cfg.compression.kind_conv = *conv_kind;
        w.cfg.compression.topk_fraction = 0.003;
        println!("== {name} ==");
        let rec = w.run()?;
        for e in &rec.epochs {
            println!(
                "  epoch {:>3}  loss {:>8.4}  test-err {:>6.2}%",
                e.epoch, e.train_loss, e.test_error_pct
            );
        }
        runs.push(rec);
    }

    println!("\nFig 1 summary (paper: 18% baseline, ~20% FC-only, divergence with conv 1-bit):");
    let mut t = report::Table::new(&["configuration", "final test-err %", "diverged / degraded"]);
    let base = runs[0].final_test_error();
    for r in &runs {
        let verdict = if r.diverged || !r.epochs.iter().all(|e| e.train_loss.is_finite()) {
            "DIVERGED".to_string()
        } else if r.final_test_error() > base + 10.0 {
            "severely degraded".to_string()
        } else if r.final_test_error() > base + 1.0 {
            "modest degradation".to_string()
        } else {
            "ok".to_string()
        };
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.final_test_error()),
            verdict,
        ]);
    }
    t.print();
    report::save_runs("fig1_divergence", &runs)?;
    Ok(())
}
