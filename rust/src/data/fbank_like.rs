//! BN50-like synthetic speech frames: 440-dim fbank-context vectors,
//! configurable state (class) count.
//!
//! The paper's BN50 is an internal IBM corpus: 16M frames of 40-dim fbank
//! features with +/-5 frame context (440 dims) and 5999 CD-HMM state
//! targets. We synthesize class-conditional smooth feature vectors: each
//! state has a prototype (drawn once), and a frame is prototype + colored
//! noise (temporally smooth across the context window, like real speech).

use super::{sample_rng, Dataset, Split, XBuf};
use crate::util::rng::Pcg32;

const DIM: usize = 440;
const BANDS: usize = 40; // 40 fbank bands x 11 context frames

pub struct FbankLike {
    seed: u64,
    states: usize,
    n_train: usize,
    n_test: usize,
    /// Per-state prototype, lazily seeded per state (not stored: states can
    /// be 5999; 440*5999*4B = 10MB would be fine, but recompute keeps the
    /// dataset allocation-free).
    proto_scale: f32,
}

impl FbankLike {
    pub fn new(seed: u64, states: usize, n_train: usize, n_test: usize) -> FbankLike {
        FbankLike {
            seed,
            states,
            n_train,
            n_test,
            proto_scale: 1.0,
        }
    }

    fn prototype(&self, state: usize, out: &mut [f32]) {
        let mut rng = Pcg32::new(self.seed.wrapping_add(state as u64 * 6007), 0xfba);
        // smooth across bands: random walk, shared across context frames with
        // a slow drift (speech-like temporal correlation)
        let mut band = [0.0f32; BANDS];
        let mut v = 0.0f32;
        for b in band.iter_mut() {
            v = 0.7 * v + 0.6 * rng.normal();
            *b = v;
        }
        let drift = rng.range(-0.05, 0.05);
        for ctx in 0..DIM / BANDS {
            for b in 0..BANDS {
                out[ctx * BANDS + b] =
                    self.proto_scale * (band[b] + drift * ctx as f32);
            }
        }
    }
}

impl Dataset for FbankLike {
    fn name(&self) -> &'static str {
        "fbank_like"
    }
    fn train_len(&self) -> usize {
        self.n_train
    }
    fn test_len(&self) -> usize {
        self.n_test
    }
    fn x_elems(&self) -> usize {
        DIM
    }
    fn y_elems(&self) -> usize {
        1
    }
    fn num_classes(&self) -> usize {
        self.states
    }

    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]) {
        let xs = match x {
            XBuf::F32(b) => b,
            XBuf::I32(_) => panic!("fbank_like is an f32 dataset"),
        };
        assert_eq!(xs.len(), indices.len() * DIM);
        let mut proto = vec![0.0f32; DIM];
        for (b, &idx) in indices.iter().enumerate() {
            let mut rng = sample_rng(self.seed, split, idx);
            let state = idx % self.states;
            self.prototype(state, &mut proto);
            let out = &mut xs[b * DIM..(b + 1) * DIM];
            // temporally smooth noise across the context axis
            let mut n = [0.0f32; BANDS];
            for band in n.iter_mut() {
                *band = rng.normal();
            }
            for ctx in 0..DIM / BANDS {
                for band in 0..BANDS {
                    n[band] = 0.6 * n[band] + 0.8 * rng.normal();
                    out[ctx * BANDS + band] = proto[ctx * BANDS + band] + 0.7 * n[band];
                }
            }
            y[b] = state as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = FbankLike::new(1, 1500, 1000, 100);
        let mut x = vec![0.0; 440 * 4];
        let mut y = vec![0; 4];
        d.fill(Split::Train, &[0, 1, 1500, 3001], XBuf::F32(&mut x), &mut y);
        assert_eq!(y, vec![0, 1, 0, 1]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn same_state_closer_than_different() {
        let d = FbankLike::new(2, 50, 1000, 100);
        let mut x = vec![0.0; 440 * 3];
        let mut y = vec![0; 3];
        // idx 0 and 50 share state 0; idx 1 is state 1
        d.fill(Split::Train, &[0, 50, 1], XBuf::F32(&mut x), &mut y);
        let d01: f32 = x[..440]
            .iter()
            .zip(&x[440..880])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let d02: f32 = x[..440]
            .iter()
            .zip(&x[880..])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(d01 < d02, "same-state {d01} should be < cross-state {d02}");
    }
}
