//! Dataset substrates.
//!
//! The paper's datasets are either gated (ImageNet 140GB, BN50 is an IBM
//! internal speech corpus) or gratuitous to redistribute; each generator
//! here is the closest synthetic equivalent that exercises the same code
//! path — same tensor shapes, same class counts, deterministic, and
//! *learnable* so convergence/divergence phenomena show (DESIGN.md
//! §Substitutions has the full mapping).
//!
//! All datasets are procedural: a sample is a pure function of
//! (dataset seed, split, index), so no storage, no I/O on the training
//! path, and learner shards are trivially reproducible.

pub mod cifar_like;
pub mod fbank_like;
pub mod mnist_gen;
pub mod shakespeare;
pub mod synth;

use crate::util::rng::Pcg32;

/// Train or held-out test split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    fn stream(&self) -> u64 {
        match self {
            Split::Train => 0x7121,
            Split::Test => 0x7e57,
        }
    }
}

/// Batch destination: image/speech models take f32, char models take i32.
pub enum XBuf<'a> {
    F32(&'a mut [f32]),
    I32(&'a mut [i32]),
}

/// A deterministic, procedurally generated dataset.
pub trait Dataset: Send + Sync {
    fn name(&self) -> &'static str;
    fn train_len(&self) -> usize;
    fn test_len(&self) -> usize;
    /// Per-sample x element count (e.g. 32*32*3).
    fn x_elems(&self) -> usize;
    /// Per-sample y element count (1 for classification, seq_len for LM).
    fn y_elems(&self) -> usize;
    fn num_classes(&self) -> usize;
    fn int_input(&self) -> bool {
        false
    }

    /// Write samples `indices` into `x`/`y` (batch-major).
    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]);
}

/// Per-sample RNG: pure function of (seed, split, index).
pub(crate) fn sample_rng(seed: u64, split: Split, index: usize) -> Pcg32 {
    Pcg32::new(seed ^ (index as u64).wrapping_mul(0x9e3779b97f4a7c15), split.stream())
}

/// Shard `train_len` samples across `n_learners`; learner `l` owns every
/// n-th sample (interleaved, as in the paper's equal-shard data parallelism).
#[derive(Debug, Clone)]
pub struct Shard {
    pub learner: usize,
    pub n_learners: usize,
    pub train_len: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        let base = self.train_len / self.n_learners;
        let extra = (self.train_len % self.n_learners > self.learner) as usize;
        base + extra
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Global index of the shard's i-th sample.
    pub fn global(&self, i: usize) -> usize {
        i * self.n_learners + self.learner
    }
}

/// Draw a batch of shard-local indices for one epoch-step (with-replacement
/// sampling keeps every learner's batch size constant regardless of shard
/// remainder, matching the paper's fixed per-learner minibatch) into a
/// reusable buffer — the engine's per-step learner phase allocates nothing.
pub fn draw_batch_into(rng: &mut Pcg32, shard: &Shard, batch: usize, out: &mut Vec<usize>) {
    out.clear();
    out.extend((0..batch).map(|_| shard.global(rng.below(shard.len() as u32) as usize)));
}

/// Allocating convenience wrapper over [`draw_batch_into`].
pub fn draw_batch(rng: &mut Pcg32, shard: &Shard, batch: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(batch);
    draw_batch_into(rng, shard, batch, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_partition_is_exact() {
        for n in [1usize, 3, 8] {
            let total: usize = (0..n)
                .map(|l| {
                    Shard {
                        learner: l,
                        n_learners: n,
                        train_len: 1001,
                    }
                    .len()
                })
                .sum();
            assert_eq!(total, 1001);
        }
    }

    #[test]
    fn shards_disjoint() {
        let a = Shard {
            learner: 0,
            n_learners: 2,
            train_len: 10,
        };
        let b = Shard {
            learner: 1,
            n_learners: 2,
            train_len: 10,
        };
        let sa: Vec<usize> = (0..a.len()).map(|i| a.global(i)).collect();
        let sb: Vec<usize> = (0..b.len()).map(|i| b.global(i)).collect();
        for i in &sa {
            assert!(!sb.contains(i));
        }
        assert_eq!(sa.len() + sb.len(), 10);
    }

    #[test]
    fn sample_rng_deterministic_and_distinct() {
        let a = sample_rng(1, Split::Train, 5).next_u32();
        let b = sample_rng(1, Split::Train, 5).next_u32();
        let c = sample_rng(1, Split::Train, 6).next_u32();
        let d = sample_rng(1, Split::Test, 5).next_u32();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn draw_batch_in_range() {
        let shard = Shard {
            learner: 1,
            n_learners: 4,
            train_len: 100,
        };
        let mut rng = Pcg32::seeded(3);
        let idx = draw_batch(&mut rng, &shard, 16);
        assert_eq!(idx.len(), 16);
        for i in idx {
            assert!(i < 100);
            assert_eq!(i % 4, 1);
        }
    }
}
