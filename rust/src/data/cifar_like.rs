//! CIFAR-like procedural image task: 32x32x3, configurable class count.
//!
//! Each class is a smooth random RGB field (a sum of low-frequency 2-D
//! sinusoids with class-specific frequencies/phases/amplitudes). A sample is
//! its class template under a random circular shift plus pixel noise — so a
//! conv net must learn translation-robust spectral/texture features; a
//! linear model on raw pixels does much worse. The same generator with 100
//! classes stands in for the scaled-ImageNet tasks (DESIGN.md
//! §Substitutions).

use super::{sample_rng, Dataset, Split, XBuf};
use crate::util::rng::Pcg32;

const H: usize = 32;
const W: usize = 32;
const C: usize = 3;
const K: usize = 4; // sinusoid components per channel

pub struct CifarLike {
    seed: u64,
    classes: usize,
    n_train: usize,
    n_test: usize,
    noise: f32,
    /// Per class: flattened template [H*W*C].
    templates: Vec<Vec<f32>>,
}

impl CifarLike {
    pub fn new(seed: u64, classes: usize, n_train: usize, n_test: usize) -> CifarLike {
        let mut templates = Vec::with_capacity(classes);
        for cls in 0..classes {
            let mut rng = Pcg32::new(seed.wrapping_add(cls as u64 * 7919), 0xc1fa);
            let mut t = vec![0.0f32; H * W * C];
            for ch in 0..C {
                for _ in 0..K {
                    let fx = rng.below(4) as f32 + 1.0; // 1..4 cycles
                    let fy = rng.below(4) as f32 + 1.0;
                    let phx = rng.range(0.0, std::f32::consts::TAU);
                    let phy = rng.range(0.0, std::f32::consts::TAU);
                    let amp = rng.range(0.2, 0.6);
                    for i in 0..H {
                        for j in 0..W {
                            let v = amp
                                * (fx * std::f32::consts::TAU * i as f32 / H as f32 + phx).sin()
                                * (fy * std::f32::consts::TAU * j as f32 / W as f32 + phy).sin();
                            t[(i * W + j) * C + ch] += v;
                        }
                    }
                }
            }
            templates.push(t);
        }
        CifarLike {
            seed,
            classes,
            n_train,
            n_test,
            noise: 0.35,
            templates,
        }
    }

    /// Paper CIFAR10 stand-in: 10 classes.
    pub fn cifar10(seed: u64, n_train: usize, n_test: usize) -> CifarLike {
        Self::new(seed, 10, n_train, n_test)
    }

    /// Scaled-ImageNet stand-in: 100 classes.
    pub fn imagenet100(seed: u64, n_train: usize, n_test: usize) -> CifarLike {
        Self::new(seed, 100, n_train, n_test)
    }

    fn render(&self, rng: &mut Pcg32, cls: usize, out: &mut [f32]) {
        let t = &self.templates[cls];
        let dy = rng.below(H as u32) as usize;
        let dx = rng.below(W as u32) as usize;
        for i in 0..H {
            let si = (i + dy) % H;
            for j in 0..W {
                let sj = (j + dx) % W;
                for ch in 0..C {
                    out[(i * W + j) * C + ch] =
                        t[(si * W + sj) * C + ch] + self.noise * rng.normal();
                }
            }
        }
    }
}

impl Dataset for CifarLike {
    fn name(&self) -> &'static str {
        "cifar_like"
    }
    fn train_len(&self) -> usize {
        self.n_train
    }
    fn test_len(&self) -> usize {
        self.n_test
    }
    fn x_elems(&self) -> usize {
        H * W * C
    }
    fn y_elems(&self) -> usize {
        1
    }
    fn num_classes(&self) -> usize {
        self.classes
    }

    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]) {
        let xs = match x {
            XBuf::F32(b) => b,
            XBuf::I32(_) => panic!("cifar_like is an f32 dataset"),
        };
        assert_eq!(xs.len(), indices.len() * self.x_elems());
        assert_eq!(y.len(), indices.len());
        for (b, &idx) in indices.iter().enumerate() {
            let mut rng = sample_rng(self.seed, split, idx);
            let cls = (idx + rng.below(1) as usize) % self.classes; // class = idx mod classes (balanced)
            self.render(&mut rng, cls, &mut xs[b * self.x_elems()..(b + 1) * self.x_elems()]);
            y[b] = cls as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let d = CifarLike::cifar10(7, 100, 20);
        let mut x1 = vec![0.0; d.x_elems() * 2];
        let mut y1 = vec![0; 2];
        d.fill(Split::Train, &[3, 14], XBuf::F32(&mut x1), &mut y1);
        let mut x2 = vec![0.0; d.x_elems() * 2];
        let mut y2 = vec![0; 2];
        d.fill(Split::Train, &[3, 14], XBuf::F32(&mut x2), &mut y2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn balanced_labels() {
        let d = CifarLike::cifar10(7, 1000, 100);
        let idx: Vec<usize> = (0..1000).collect();
        let mut x = vec![0.0; d.x_elems() * 1000];
        let mut y = vec![0; 1000];
        d.fill(Split::Train, &idx, XBuf::F32(&mut x), &mut y);
        let mut counts = [0usize; 10];
        for v in y {
            counts[v as usize] += 1;
        }
        for c in counts {
            assert_eq!(c, 100);
        }
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // nearest-template classification on clean correlation should beat
        // chance by a wide margin -> the task is learnable
        let d = CifarLike::cifar10(3, 200, 50);
        let idx: Vec<usize> = (0..100).collect();
        let mut x = vec![0.0; d.x_elems() * 100];
        let mut y = vec![0; 100];
        d.fill(Split::Test, &idx, XBuf::F32(&mut x), &mut y);
        // spectral energy signature is shift-invariant; use abs-correlation
        // of per-channel means as a crude proxy: just check distinct classes
        // differ more than same-class samples on average template distance.
        let mut same = 0.0f64;
        let mut diff = 0.0f64;
        let (mut ns, mut nd) = (0usize, 0usize);
        for a in 0..20 {
            for b in 0..20 {
                if a >= b {
                    continue;
                }
                let xa = &x[a * d.x_elems()..(a + 1) * d.x_elems()];
                let xb = &x[b * d.x_elems()..(b + 1) * d.x_elems()];
                // shift-invariant-ish statistic: per-channel histograms of energy
                let mut da = [0.0f64; 12];
                let mut db = [0.0f64; 12];
                for (i, &v) in xa.iter().enumerate() {
                    da[(i % 3) * 4 + ((v.abs() * 2.0) as usize).min(3)] += 1.0;
                }
                for (i, &v) in xb.iter().enumerate() {
                    db[(i % 3) * 4 + ((v.abs() * 2.0) as usize).min(3)] += 1.0;
                }
                let dist: f64 = da.iter().zip(db.iter()).map(|(p, q)| (p - q) * (p - q)).sum();
                if y[a] == y[b] {
                    same += dist;
                    ns += 1;
                } else {
                    diff += dist;
                    nd += 1;
                }
            }
        }
        assert!(diff / nd as f64 > same / ns.max(1) as f64);
    }
}
