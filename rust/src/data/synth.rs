//! Generic synthetic classification task: class-conditional gaussians in
//! d dimensions. Used by unit/integration tests (fast, learnable by a small
//! MLP) and as a teacher-student smoke workload.

use super::{sample_rng, Dataset, Split, XBuf};
use crate::util::rng::Pcg32;

pub struct GaussianMixture {
    seed: u64,
    dim: usize,
    classes: usize,
    n_train: usize,
    n_test: usize,
    noise: f32,
    /// Per-class means, [classes * dim].
    means: Vec<f32>,
}

impl GaussianMixture {
    pub fn new(seed: u64, dim: usize, classes: usize, n_train: usize, n_test: usize, noise: f32) -> Self {
        let mut rng = Pcg32::new(seed, 0x6a05);
        let means = rng.normal_vec(classes * dim, 1.0);
        GaussianMixture {
            seed,
            dim,
            classes,
            n_train,
            n_test,
            noise,
            means,
        }
    }
}

impl Dataset for GaussianMixture {
    fn name(&self) -> &'static str {
        "gaussian_mixture"
    }
    fn train_len(&self) -> usize {
        self.n_train
    }
    fn test_len(&self) -> usize {
        self.n_test
    }
    fn x_elems(&self) -> usize {
        self.dim
    }
    fn y_elems(&self) -> usize {
        1
    }
    fn num_classes(&self) -> usize {
        self.classes
    }

    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]) {
        let xs = match x {
            XBuf::F32(b) => b,
            XBuf::I32(_) => panic!("gaussian_mixture is an f32 dataset"),
        };
        for (b, &idx) in indices.iter().enumerate() {
            let mut rng = sample_rng(self.seed, split, idx);
            let cls = idx % self.classes;
            let mean = &self.means[cls * self.dim..(cls + 1) * self.dim];
            let out = &mut xs[b * self.dim..(b + 1) * self.dim];
            for (o, &m) in out.iter_mut().zip(mean.iter()) {
                *o = m + self.noise * rng.normal();
            }
            y[b] = cls as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_mean_classifies() {
        let d = GaussianMixture::new(1, 16, 4, 100, 100, 0.5);
        let idx: Vec<usize> = (0..40).collect();
        let mut x = vec![0.0; 16 * 40];
        let mut y = vec![0; 40];
        d.fill(Split::Test, &idx, XBuf::F32(&mut x), &mut y);
        let mut correct = 0;
        for b in 0..40 {
            let xb = &x[b * 16..(b + 1) * 16];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..4 {
                let m = &d.means[c * 16..(c + 1) * 16];
                let dist: f32 = xb.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y[b] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 38, "nearest-mean got {correct}/40");
    }
}
