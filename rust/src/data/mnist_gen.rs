//! Procedural MNIST-like digits: 28x28x1 grayscale, 10 classes.
//!
//! Each digit class is a fixed set of stroke segments on the unit square;
//! samples rasterize the strokes with a per-sample random affine transform
//! (rotation, scale, translation), stroke thickness jitter and pixel noise —
//! structurally the same invariances real MNIST demands.

use super::{sample_rng, Dataset, Split, XBuf};
use crate::util::rng::Pcg32;

const H: usize = 28;
const W: usize = 28;

/// Stroke templates per digit: (x0, y0, x1, y1) in [0,1]^2 (y down).
fn strokes(digit: usize) -> &'static [(f32, f32, f32, f32)] {
    match digit {
        0 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
            (0.3, 0.8, 0.3, 0.2),
        ],
        1 => &[(0.5, 0.15, 0.5, 0.85), (0.35, 0.3, 0.5, 0.15)],
        2 => &[
            (0.3, 0.25, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.5),
            (0.7, 0.5, 0.3, 0.8),
            (0.3, 0.8, 0.7, 0.8),
        ],
        3 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.5),
            (0.45, 0.5, 0.7, 0.5),
            (0.7, 0.5, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
        ],
        4 => &[
            (0.35, 0.2, 0.3, 0.55),
            (0.3, 0.55, 0.75, 0.55),
            (0.65, 0.2, 0.65, 0.85),
        ],
        5 => &[
            (0.7, 0.2, 0.3, 0.2),
            (0.3, 0.2, 0.3, 0.5),
            (0.3, 0.5, 0.7, 0.5),
            (0.7, 0.5, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
        ],
        6 => &[
            (0.65, 0.2, 0.35, 0.35),
            (0.35, 0.35, 0.3, 0.8),
            (0.3, 0.8, 0.7, 0.8),
            (0.7, 0.8, 0.7, 0.55),
            (0.7, 0.55, 0.3, 0.55),
        ],
        7 => &[(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.45, 0.85)],
        8 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
            (0.3, 0.8, 0.3, 0.2),
            (0.3, 0.5, 0.7, 0.5),
        ],
        _ => &[
            (0.7, 0.45, 0.3, 0.45),
            (0.3, 0.45, 0.3, 0.2),
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.85),
        ],
    }
}

pub struct MnistGen {
    seed: u64,
    n_train: usize,
    n_test: usize,
}

impl MnistGen {
    pub fn new(seed: u64, n_train: usize, n_test: usize) -> MnistGen {
        MnistGen {
            seed,
            n_train,
            n_test,
        }
    }

    fn render(&self, rng: &mut Pcg32, digit: usize, out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        let angle = rng.range(-0.26, 0.26); // ~±15°
        let scale = rng.range(0.85, 1.15);
        let tx = rng.range(-0.08, 0.08);
        let ty = rng.range(-0.08, 0.08);
        let thick = rng.range(0.045, 0.075);
        let (sin, cos) = angle.sin_cos();
        // transform stroke endpoints around center (0.5, 0.5)
        let tf = |x: f32, y: f32| -> (f32, f32) {
            let (cx, cy) = (x - 0.5, y - 0.5);
            (
                0.5 + scale * (cos * cx - sin * cy) + tx,
                0.5 + scale * (sin * cx + cos * cy) + ty,
            )
        };
        for &(x0, y0, x1, y1) in strokes(digit) {
            let (ax, ay) = tf(x0, y0);
            let (bx, by) = tf(x1, y1);
            // rasterize by distance-to-segment
            let (dx, dy) = (bx - ax, by - ay);
            let len2 = (dx * dx + dy * dy).max(1e-8);
            for i in 0..H {
                let py = (i as f32 + 0.5) / H as f32;
                for j in 0..W {
                    let px = (j as f32 + 0.5) / W as f32;
                    let t = (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0);
                    let (qx, qy) = (ax + t * dx, ay + t * dy);
                    let d = ((px - qx) * (px - qx) + (py - qy) * (py - qy)).sqrt();
                    if d < thick {
                        let v = 1.0 - (d / thick) * 0.5;
                        let cell = &mut out[i * W + j];
                        if v > *cell {
                            *cell = v;
                        }
                    }
                }
            }
        }
        for v in out.iter_mut() {
            *v = (*v + 0.05 * rng.normal()).clamp(0.0, 1.0);
            // center to roughly zero-mean like standard MNIST preprocessing
            *v -= 0.13;
        }
    }
}

impl Dataset for MnistGen {
    fn name(&self) -> &'static str {
        "mnist_gen"
    }
    fn train_len(&self) -> usize {
        self.n_train
    }
    fn test_len(&self) -> usize {
        self.n_test
    }
    fn x_elems(&self) -> usize {
        H * W
    }
    fn y_elems(&self) -> usize {
        1
    }
    fn num_classes(&self) -> usize {
        10
    }

    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]) {
        let xs = match x {
            XBuf::F32(b) => b,
            XBuf::I32(_) => panic!("mnist_gen is an f32 dataset"),
        };
        assert_eq!(xs.len(), indices.len() * self.x_elems());
        for (b, &idx) in indices.iter().enumerate() {
            let mut rng = sample_rng(self.seed, split, idx);
            let digit = idx % 10;
            self.render(&mut rng, digit, &mut xs[b * self.x_elems()..(b + 1) * self.x_elems()]);
            y[b] = digit as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nonempty_digits() {
        let d = MnistGen::new(1, 100, 10);
        let mut x = vec![0.0; 784 * 10];
        let mut y = vec![0; 10];
        d.fill(Split::Train, &(0..10).collect::<Vec<_>>(), XBuf::F32(&mut x), &mut y);
        for b in 0..10 {
            let img = &x[b * 784..(b + 1) * 784];
            let ink: f32 = img.iter().map(|v| (v + 0.13).max(0.0)).sum();
            assert!(ink > 10.0, "digit {b} empty: ink {ink}");
            assert_eq!(y[b], b as i32);
        }
    }

    #[test]
    fn samples_vary_within_class() {
        let d = MnistGen::new(1, 100, 10);
        let mut x = vec![0.0; 784 * 2];
        let mut y = vec![0; 2];
        // indices 0 and 10 are both digit 0
        d.fill(Split::Train, &[0, 10], XBuf::F32(&mut x), &mut y);
        assert_eq!(y, vec![0, 0]);
        let diff: f32 = x[..784]
            .iter()
            .zip(&x[784..])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1.0, "augmentation should vary samples: {diff}");
    }

    #[test]
    fn all_strokes_defined() {
        for d in 0..10 {
            assert!(!strokes(d).is_empty());
        }
    }
}
