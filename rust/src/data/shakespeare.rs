//! Character-level Shakespeare corpus for the char-LSTM / transformer tasks.
//!
//! The paper uses Karpathy's tiny-shakespeare (50k lines, vocab 67). We
//! cannot ship that file offline, so the corpus here is: a genuine embedded
//! public-domain seed (sonnets + famous passages, ~4KB) expanded by an
//! order-3 character Markov chain fit on the seed — preserving the seed's
//! character statistics, vocabulary and local structure at arbitrary length
//! (DESIGN.md §Substitutions). Deterministic given the seed value.
//!
//! Vocabulary is capped at `model::VOCAB` = 67 ids; characters beyond the
//! cap map to id 0 (never happens with the embedded seed, which has < 60
//! distinct characters).

use std::collections::HashMap;

use super::{Dataset, Split, XBuf};
use crate::util::rng::Pcg32;

pub const VOCAB: usize = 67;

/// Genuine public-domain seed text (Shakespeare).
const SEED_TEXT: &str = r#"Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date:
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade
Nor lose possession of that fair thou owest;
Nor shall Death brag thou wander'st in his shade,
When in eternal lines to time thou growest:
So long as men can breathe or eyes can see,
So long lives this and this gives life to thee.

To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;

Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.

All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms.
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school.

Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments;
Our stern alarums changed to merry meetings,
Our dreadful marches to delightful measures.

If music be the food of love, play on;
Give me excess of it, that, surfeiting,
The appetite may sicken, and so die.
That strain again! it had a dying fall:
O, it came o'er my ear like the sweet sound,
That breathes upon a bank of violets,
Stealing and giving odour!

Tomorrow, and tomorrow, and tomorrow,
Creeps in this petty pace from day to day
To the last syllable of recorded time,
And all our yesterdays have lighted fools
The way to dusty death. Out, out, brief candle!
Life's but a walking shadow, a poor player
That struts and frets his hour upon the stage
And then is heard no more: it is a tale
Told by an idiot, full of sound and fury,
Signifying nothing.

O Romeo, Romeo! wherefore art thou Romeo?
Deny thy father and refuse thy name;
Or, if thou wilt not, be but sworn my love,
And I'll no longer be a Capulet.
'Tis but thy name that is my enemy;
Thou art thyself, though not a Montague.
What's Montague? it is nor hand, nor foot,
Nor arm, nor face, nor any other part
Belonging to a man. O, be some other name!
What's in a name? that which we call a rose
By any other name would smell as sweet.

The quality of mercy is not strain'd,
It droppeth as the gentle rain from heaven
Upon the place beneath: it is twice blest;
It blesseth him that gives and him that takes:
'Tis mightiest in the mightiest: it becomes
The throned monarch better than his crown;
His sceptre shows the force of temporal power,
The attribute to awe and majesty,
Wherein doth sit the dread and fear of kings;
But mercy is above this sceptred sway;
It is enthroned in the hearts of kings,
It is an attribute to God himself.
"#;

/// Character vocabulary built from the seed, id-stable across runs.
pub struct CharVocab {
    pub chars: Vec<char>,
    map: HashMap<char, usize>,
}

impl CharVocab {
    pub fn from_seed() -> CharVocab {
        let mut chars: Vec<char> = SEED_TEXT
            .chars()
            .collect::<std::collections::BTreeSet<char>>()
            .into_iter()
            .collect();
        chars.truncate(VOCAB);
        let map = chars.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        CharVocab { chars, map }
    }

    pub fn id(&self, c: char) -> usize {
        *self.map.get(&c).unwrap_or(&0)
    }

    pub fn len(&self) -> usize {
        self.chars.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chars.is_empty()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| *self.chars.get(i as usize).unwrap_or(&'?'))
            .collect()
    }
}

/// Order-3 Markov chain over seed characters.
fn markov_expand(target_len: usize, seed: u64) -> Vec<u8> {
    let bytes: Vec<u8> = SEED_TEXT.bytes().collect();
    // context -> list of next bytes (weighted by multiplicity)
    let mut table: HashMap<[u8; 3], Vec<u8>> = HashMap::new();
    for w in bytes.windows(4) {
        table
            .entry([w[0], w[1], w[2]])
            .or_default()
            .push(w[3]);
    }
    let mut rng = Pcg32::new(seed, 0x5a5a);
    let mut out = Vec::with_capacity(target_len);
    out.extend_from_slice(&bytes[..3]);
    while out.len() < target_len {
        let ctx = [
            out[out.len() - 3],
            out[out.len() - 2],
            out[out.len() - 1],
        ];
        match table.get(&ctx) {
            Some(nexts) => {
                let c = nexts[rng.below(nexts.len() as u32) as usize];
                out.push(c);
            }
            None => {
                // dead end (end of seed): restart from a random seed position
                let p = rng.below((bytes.len() - 3) as u32) as usize;
                out.extend_from_slice(&bytes[p..p + 3]);
            }
        }
    }
    out.truncate(target_len);
    out
}

pub struct Shakespeare {
    vocab: CharVocab,
    /// Token ids of the expanded corpus.
    corpus: Vec<u8>,
    seq_len: usize,
    n_train: usize,
    n_test: usize,
    /// Windows in [0, split_at) are train; [split_at, ..) test.
    split_at: usize,
    seed: u64,
}

impl Shakespeare {
    pub fn new(seed: u64, corpus_len: usize, seq_len: usize, n_train: usize, n_test: usize) -> Shakespeare {
        let vocab = CharVocab::from_seed();
        let raw = markov_expand(corpus_len, seed);
        let corpus: Vec<u8> = raw
            .iter()
            .map(|&b| vocab.id(b as char) as u8)
            .collect();
        let usable = corpus.len().saturating_sub(seq_len + 1);
        let split_at = usable * 9 / 10;
        Shakespeare {
            vocab,
            corpus,
            seq_len,
            n_train,
            n_test,
            split_at,
            seed,
        }
    }

    pub fn vocab(&self) -> &CharVocab {
        &self.vocab
    }

    fn window_start(&self, split: Split, idx: usize) -> usize {
        // hash the index into the split's region deterministically
        let mut rng = super::sample_rng(self.seed, split, idx);
        match split {
            Split::Train => rng.below(self.split_at as u32) as usize,
            Split::Test => {
                let usable = self.corpus.len() - self.seq_len - 1;
                self.split_at + rng.below((usable - self.split_at) as u32) as usize
            }
        }
    }
}

impl Dataset for Shakespeare {
    fn name(&self) -> &'static str {
        "shakespeare"
    }
    fn train_len(&self) -> usize {
        self.n_train
    }
    fn test_len(&self) -> usize {
        self.n_test
    }
    fn x_elems(&self) -> usize {
        self.seq_len
    }
    fn y_elems(&self) -> usize {
        self.seq_len
    }
    fn num_classes(&self) -> usize {
        VOCAB
    }
    fn int_input(&self) -> bool {
        true
    }

    fn fill(&self, split: Split, indices: &[usize], x: XBuf, y: &mut [i32]) {
        let xs = match x {
            XBuf::I32(b) => b,
            XBuf::F32(_) => panic!("shakespeare is an i32 (char-id) dataset"),
        };
        let t = self.seq_len;
        assert_eq!(xs.len(), indices.len() * t);
        assert_eq!(y.len(), indices.len() * t);
        for (b, &idx) in indices.iter().enumerate() {
            let s = self.window_start(split, idx);
            for j in 0..t {
                xs[b * t + j] = self.corpus[s + j] as i32;
                y[b * t + j] = self.corpus[s + j + 1] as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_under_cap() {
        let v = CharVocab::from_seed();
        assert!(v.len() <= VOCAB);
        assert!(v.len() > 30);
        // roundtrip a char
        let id = v.id('e');
        assert_eq!(v.chars[id], 'e');
    }

    #[test]
    fn markov_preserves_charset() {
        let out = markov_expand(5000, 1);
        assert_eq!(out.len(), 5000);
        let seed_set: std::collections::HashSet<u8> = SEED_TEXT.bytes().collect();
        for b in out {
            assert!(seed_set.contains(&b));
        }
    }

    #[test]
    fn xy_shifted_by_one() {
        let d = Shakespeare::new(1, 20_000, 16, 100, 10);
        let mut x = vec![0; 16 * 2];
        let mut y = vec![0; 16 * 2];
        d.fill(Split::Train, &[0, 5], XBuf::I32(&mut x), &mut y);
        // y[j] should be x[j+1] within a window
        for b in 0..2 {
            for j in 0..15 {
                assert_eq!(y[b * 16 + j], x[b * 16 + j + 1]);
            }
        }
    }

    #[test]
    fn ids_in_vocab_range() {
        let d = Shakespeare::new(2, 10_000, 32, 100, 10);
        let mut x = vec![0; 32];
        let mut y = vec![0; 32];
        d.fill(Split::Test, &[3], XBuf::I32(&mut x), &mut y);
        for &v in x.iter().chain(y.iter()) {
            assert!((0..VOCAB as i32).contains(&v));
        }
    }

    #[test]
    fn train_test_regions_disjoint() {
        let d = Shakespeare::new(3, 50_000, 32, 1000, 100);
        let max_train = (0..200)
            .map(|i| d.window_start(Split::Train, i))
            .max()
            .unwrap();
        let min_test = (0..200)
            .map(|i| d.window_start(Split::Test, i))
            .min()
            .unwrap();
        assert!(max_train < min_test);
    }
}
