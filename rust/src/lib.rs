//! AdaComp — Adaptive Residual Gradient Compression for data-parallel
//! distributed training (Chen et al., AAAI 2018) — full-system reproduction.
//!
//! Three-layer architecture (see DESIGN.md):
//!   L1: Pallas compression kernels (python/compile/kernels, AOT to HLO)
//!   L2: JAX model zoo (python/compile/model.py, AOT to HLO)
//!   L3: this crate — the distributed training coordinator: compression
//!       engines, simulated multi-learner fabric, topologies, optimizers,
//!       datasets, metrics, and the experiment harnesses that regenerate
//!       every figure/table of the paper.
//!
//! Python never runs on the training path: `make artifacts` lowers L1+L2 to
//! HLO text once; the rust binary loads them via PJRT (`runtime::pjrt`,
//! behind the `pjrt` cargo feature — hermetic builds use the native
//! layer-graph executors (`runtime::net`: composable fc/relu/conv/pool/
//! embedding/LSTM layers over the shared flat `Layout`) and stay
//! artifact-free, including the paper's recurrent char-LSTM workload).
//!
//! The multi-learner engine runs the per-learner phase on a persistent
//! worker pool (`runtime::ExecutorFactory` + `train::Engine`) and, by
//! default, streams the exchange per layer: each layer is packed and
//! reduced over the topology while earlier layers are still in backward
//! (`--exchange streamed`; `barrier` keeps the classic join-then-exchange
//! round). The exchange hot path is zero-allocation in steady state and
//! results are bit-identical for every thread count and both exchange
//! modes (DESIGN.md §Threading, §Overlap pipeline).

pub mod comm;
pub mod config;
pub mod compress;
pub mod data;
pub mod harness;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

pub use compress::{Compressor, Packet};
pub use models::{LayerKind, Layout, Manifest};
pub use runtime::{Executor, ExecutorFactory};
pub use train::{Engine, ExchangeMode, TrainConfig};
