//! Model metadata: the coordinator's view of a model's parameter layout.
//!
//! Parsed from `artifacts/manifest.json` (written by `python -m compile.aot`),
//! or constructed programmatically for tests and the native executor. The
//! layout is what lets compression apply the paper's per-layer-kind L_T
//! defaults (conv 50, fc/lstm 500) and lets the coordinator carve flat
//! parameter/gradient buffers into layers.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Layer taxonomy from the paper (drives the L_T default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    Lstm,
    Embed,
}

impl LayerKind {
    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "fc" => LayerKind::Fc,
            "lstm" => LayerKind::Lstm,
            "embed" => LayerKind::Embed,
            other => bail!("unknown layer kind '{other}'"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Fc => "fc",
            LayerKind::Lstm => "lstm",
            LayerKind::Embed => "embed",
        }
    }
}

/// One parameter tensor.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: LayerKind,
    /// Paper-default L_T recorded by the exporter (50 conv / 500 fc+lstm).
    pub lt_default: usize,
    /// Offset into the flat parameter vector.
    pub offset: usize,
}

impl LayerInfo {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Ordered parameter layout of a model.
#[derive(Debug, Clone)]
pub struct Layout {
    pub layers: Vec<LayerInfo>,
    pub total: usize,
}

impl Layout {
    pub fn new(mut layers: Vec<LayerInfo>) -> Layout {
        let mut off = 0;
        for l in layers.iter_mut() {
            l.offset = off;
            off += l.len();
        }
        Layout {
            layers,
            total: off,
        }
    }

    /// Build from (name, shape, kind) triples with paper L_T defaults.
    pub fn from_specs(specs: &[(&str, &[usize], LayerKind)]) -> Layout {
        Layout::new(
            specs
                .iter()
                .map(|(name, shape, kind)| LayerInfo {
                    name: name.to_string(),
                    shape: shape.to_vec(),
                    kind: *kind,
                    // Paper L_T defaults: conv 50; fc and lstm 500 (Table 1).
                    // The paper has no embedding workload; embedding
                    // gradients are row-sparse like fc/lstm (few rows per
                    // minibatch, large residual build-up), so `Embed` takes
                    // the documented fc/lstm default of 500 — mirrored by
                    // `compress::Config::lt_for` and the python exporter's
                    // `LT_DEFAULT`.
                    lt_default: match kind {
                        LayerKind::Conv => 50,
                        LayerKind::Fc | LayerKind::Lstm | LayerKind::Embed => 500,
                    },
                    offset: 0,
                })
                .collect(),
        )
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Dense element count of every layer, layer order — the shape the
    /// exchange path (reduce plan, topologies, `Reduced`) works in.
    pub fn layer_lens(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.len()).collect()
    }

    /// Slice layer `i` out of a flat buffer.
    pub fn view<'a>(&self, i: usize, flat: &'a [f32]) -> &'a [f32] {
        let l = &self.layers[i];
        &flat[l.offset..l.offset + l.len()]
    }

    pub fn view_mut<'a>(&self, i: usize, flat: &'a mut [f32]) -> &'a mut [f32] {
        let l = &self.layers[i];
        &mut flat[l.offset..l.offset + l.len()]
    }
}

/// Input/output signature of an exported model (from the manifest).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub layout: Layout,
    pub step_hlo: String,
    pub eval_hlo: String,
    pub init_bin: String,
    pub batch: usize,
    pub seq_len: usize,
    pub x_shape: Vec<usize>,
    pub x_is_int: bool,
    pub y_shape: Vec<usize>,
    pub num_classes: usize,
}

impl ModelMeta {
    fn from_json(v: &Json) -> Result<ModelMeta> {
        let name = v.get("name").as_str().context("model name")?.to_string();
        let params = v.get("params").as_arr().context("params")?;
        let mut layers = Vec::with_capacity(params.len());
        for p in params {
            layers.push(LayerInfo {
                name: p.get("name").as_str().context("param name")?.to_string(),
                shape: p.get("shape").usize_vec().context("param shape")?,
                kind: LayerKind::parse(p.get("kind").as_str().context("param kind")?)?,
                lt_default: p.get("lt").as_usize().context("param lt")?,
                offset: 0,
            });
        }
        Ok(ModelMeta {
            name,
            layout: Layout::new(layers),
            step_hlo: v.get("step_hlo").as_str().context("step_hlo")?.to_string(),
            eval_hlo: v.get("eval_hlo").as_str().context("eval_hlo")?.to_string(),
            init_bin: v.get("init_bin").as_str().context("init_bin")?.to_string(),
            batch: v.get("batch").as_usize().context("batch")?,
            seq_len: v.get("seq_len").as_usize().unwrap_or(0),
            x_shape: v.get("x_shape").usize_vec().context("x_shape")?,
            x_is_int: v.get("x_dtype").as_str() == Some("i32"),
            y_shape: v.get("y_shape").usize_vec().context("y_shape")?,
            num_classes: v.get("num_classes").as_usize().context("num_classes")?,
        })
    }
}

/// The parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: String,
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let txt = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = Json::from_str_slice(&txt).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let models_obj = v.get("models").as_obj().context("manifest.models")?;
        let mut models = Vec::new();
        for m in models_obj.values() {
            models.push(ModelMeta::from_json(m)?);
        }
        Ok(Manifest {
            dir: dir.to_string(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                format!(
                    "model '{}' not in manifest (have: {})",
                    name,
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Load a model's initial flat parameter vector from its init bin.
    pub fn load_init(&self, meta: &ModelMeta) -> Result<Vec<f32>> {
        let path = Path::new(&self.dir).join(&meta.init_bin);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != meta.layout.total * 4 {
            bail!(
                "{}: expected {} f32 ({} bytes), file has {} bytes",
                meta.init_bin,
                meta.layout.total,
                meta.layout.total * 4,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A small synthetic layout used across unit tests: one conv-ish layer and
/// one fc-ish layer with paper-default L_T.
pub fn test_layout() -> Layout {
    Layout::from_specs(&[
        ("conv_w", &[5, 5, 3, 8], LayerKind::Conv), // 600 elements
        ("fc_w", &[40, 30], LayerKind::Fc),         // 1200 elements
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets() {
        let l = test_layout();
        assert_eq!(l.num_layers(), 2);
        assert_eq!(l.layers[0].len(), 600);
        assert_eq!(l.layers[1].offset, 600);
        assert_eq!(l.total, 1800);
    }

    #[test]
    fn layer_lens_match_views() {
        let l = test_layout();
        assert_eq!(l.layer_lens(), vec![600, 1200]);
    }

    #[test]
    fn views() {
        let l = test_layout();
        let mut flat = vec![0.0f32; l.total];
        l.view_mut(1, &mut flat)[0] = 7.0;
        assert_eq!(flat[600], 7.0);
        assert_eq!(l.view(1, &flat)[0], 7.0);
    }

    #[test]
    fn lt_defaults() {
        let l = test_layout();
        assert_eq!(l.layers[0].lt_default, 50);
        assert_eq!(l.layers[1].lt_default, 500);
    }

    #[test]
    fn kind_parse() {
        assert!(LayerKind::parse("conv").is_ok());
        assert!(LayerKind::parse("nope").is_err());
        assert_eq!(LayerKind::parse("lstm").unwrap().name(), "lstm");
    }

    #[test]
    fn manifest_from_json_text() {
        let txt = r#"{"models": {"m": {
            "name": "m", "step_hlo": "m.step.hlo.txt", "eval_hlo": "m.eval.hlo.txt",
            "init_bin": "m.init.bin", "batch": 4, "seq_len": 0,
            "x_shape": [4, 8], "x_dtype": "f32", "y_shape": [4],
            "num_classes": 3, "num_params": 27,
            "params": [{"name": "w", "shape": [8, 3], "kind": "fc", "lt": 500},
                       {"name": "b", "shape": [3], "kind": "fc", "lt": 500}]
        }}}"#;
        let v = Json::from_str_slice(txt).unwrap();
        let m = ModelMeta::from_json(v.get("models").get("m")).unwrap();
        assert_eq!(m.layout.total, 27);
        assert_eq!(m.layout.layers[1].offset, 24);
        assert!(!m.x_is_int);
    }
}
