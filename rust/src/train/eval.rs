//! Held-out evaluation: test error % (top-1, as the paper's tables) and
//! mean test loss.

use anyhow::Result;

use crate::data::{Dataset, Split, XBuf};
use crate::runtime::{Batch, Executor};

/// Evaluate `params` over (up to) the whole test split in executor-sized
/// batches; trailing remainder is dropped (test set sizes are chosen
/// divisible in the harnesses).
pub fn test_error(
    executor: &mut dyn Executor,
    dataset: &dyn Dataset,
    params: &[f32],
) -> Result<(f64, f64)> {
    let bs = executor.eval_batch();
    let nbatches = (dataset.test_len() / bs).max(1).min(64);
    let mut total = 0usize;
    let mut correct = 0.0f64;
    let mut loss_sum = 0.0f64;
    let mut batch = if dataset.int_input() {
        Batch::i32(
            vec![0; bs * dataset.x_elems()],
            vec![0; bs * dataset.y_elems()],
            bs,
        )
    } else {
        Batch::f32(
            vec![0.0; bs * dataset.x_elems()],
            vec![0; bs * dataset.y_elems()],
            bs,
        )
    };
    for bi in 0..nbatches {
        let indices: Vec<usize> = (bi * bs..(bi + 1) * bs)
            .map(|i| i % dataset.test_len())
            .collect();
        if batch.x_i32.is_empty() {
            dataset.fill(Split::Test, &indices, XBuf::F32(&mut batch.x_f32), &mut batch.y);
        } else {
            dataset.fill(Split::Test, &indices, XBuf::I32(&mut batch.x_i32), &mut batch.y);
        }
        let out = executor.eval(params, &batch)?;
        total += bs * dataset.y_elems();
        correct += out.ncorrect as f64;
        loss_sum += out.loss_sum_weighted as f64;
    }
    let err_pct = 100.0 * (1.0 - correct / total as f64);
    Ok((err_pct, loss_sum / nbatches as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixture;
    use crate::runtime::native::NativeMlp;

    #[test]
    fn random_net_near_chance() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 64, 0.3);
        let mut m = NativeMlp::new(&[8, 4], 16);
        let params = vec![0.0f32; m.layout().total]; // uniform logits
        let (err, loss) = test_error(&mut m, &ds, &params).unwrap();
        // all-zero net: argmax is class 0, accuracy = 25% on balanced labels
        assert!(err > 60.0 && err <= 80.0, "err {err}");
        assert!((loss - (4.0f64).ln()).abs() < 1e-3);
    }
}
