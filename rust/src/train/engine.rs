//! The synchronous data-parallel training engine — paper Algorithm 1.
//!
//! Per step, every learner samples its shard minibatch, runs forward+backward
//! (its own executor), and `pack()`s each layer through its compressor; the
//! engine then `exchange()`s all packets over the configured topology
//! (parameter server or ring), unpacks into the dense mean gradient, and
//! applies the central optimizer. All learners hold identical weights at
//! every step — the paper's synchronous-SGD setting.
//!
//! **Parallel learner phase.** The per-learner work is embarrassingly
//! parallel: when the backend's [`ExecutorFactory`] reports `parallel()`,
//! each learner owns a `Send` executor and the step fans learners out across
//! `cfg.threads` scoped worker threads. The exchange/reduce stays on the
//! engine thread and consumes packets in learner-id order, and per-step loss
//! accounting also sums in learner-id order — so the results are
//! **bit-identical** to the sequential path for any thread count (the
//! determinism contract, DESIGN.md §Threading; pinned by
//! rust/tests/engine_native.rs::parallel_matches_sequential_bitwise).
//! Backends whose executors cannot cross threads (PJRT's `Rc`-backed client)
//! fall back to one shared executor driven sequentially, behind the same API.
//! Workers are scoped per step (spawn+join ≈ 0.1–0.2 ms for 8 threads),
//! which amortizes against multi-millisecond learner phases; a persistent
//! pool would shave that constant and is a candidate follow-up if profiles
//! ever show it mattering.
//!
//! **Zero-alloc exchange.** Packet buffers recycle through the compressor
//! pools, packets live in per-learner slots reused across steps, and the
//! topology reduces into a persistent [`Reduced`] — the steady-state
//! exchange/reduce path performs no heap allocation (rust/tests/alloc_free.rs).
//!
//! Learners are simulated in-process (DESIGN.md §Substitutions): the
//! semantics (who computes what on which data, what crosses the wire) are
//! exactly the distributed ones; the fabric charges every packet its real
//! encoded byte size.

use anyhow::Result;

use super::{eval::test_error, learner::Learner};
use crate::comm::{topology, Fabric, LinkModel, Reduced};
use crate::compress::{self, Packet};
use crate::data::Dataset;
use crate::metrics::{percentile, CompStat, EpochRecord, RunRecord};
use crate::models::{LayerKind, Layout};
use crate::optim::{self, LrSchedule};
use crate::runtime::ExecutorFactory;
use crate::util::timer::Stopwatch;

/// Everything that defines one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub run_name: String,
    pub model_name: String,
    /// Compute backend the run was wired with: "native" (hermetic
    /// layer-graph executors), "pjrt" (AOT artifacts), or "auto" (resolve
    /// at workload build time). Informational to the engine itself — the
    /// harness resolves it before the engine runs.
    pub backend: String,
    pub n_learners: usize,
    pub batch_per_learner: usize,
    pub epochs: usize,
    /// Optimizer steps per epoch; 0 = train_len / (batch * learners).
    pub steps_per_epoch: usize,
    pub lr: LrSchedule,
    pub optimizer: String,
    pub momentum: f32,
    pub compression: compress::Config,
    pub topology: String,
    pub link: LinkModel,
    pub seed: u64,
    /// Abort (mark diverged) when train loss exceeds this or goes non-finite.
    pub divergence_loss: f64,
    /// Callback cadence for residue stats (every epoch end).
    pub track_residue: bool,
    /// Global-norm clip applied to the mean gradient before the central
    /// update (0 = off). Applied *after* exchange so it never interacts with
    /// the compression path.
    pub clip_norm: f32,
    /// Worker threads for the per-learner phase: 0 = auto (one per hardware
    /// thread, capped at n_learners), 1 = sequential. Results are
    /// bit-identical for every value (see module docs).
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            run_name: "run".into(),
            model_name: "model".into(),
            backend: "auto".into(),
            n_learners: 1,
            batch_per_learner: 32,
            epochs: 5,
            steps_per_epoch: 0,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd".into(),
            momentum: 0.9,
            compression: compress::Config::default(),
            topology: "ring".into(),
            link: LinkModel::default(),
            seed: 42,
            divergence_loss: 1e4,
            track_residue: true,
            clip_norm: 0.0,
            threads: 0,
        }
    }
}

/// Observer hook for figure harnesses that need per-epoch internals:
/// `hook(epoch, learner0_compressor, learner0_last_dw)` — enough for the
/// Fig 5 percentile curves and Fig 6 residual histograms.
pub type EpochHook<'a> = dyn FnMut(usize, &dyn compress::Compressor, &[f32]) + 'a;

pub struct Engine<'a> {
    pub factory: &'a dyn ExecutorFactory,
    pub dataset: &'a dyn Dataset,
    pub layout: &'a Layout,
}

impl<'a> Engine<'a> {
    pub fn new(
        factory: &'a dyn ExecutorFactory,
        dataset: &'a dyn Dataset,
        layout: &'a Layout,
    ) -> Engine<'a> {
        Engine {
            factory,
            dataset,
            layout,
        }
    }

    /// Resolve the worker-thread count for a run: honor `cfg.threads`, cap at
    /// n_learners, and force 1 when the backend cannot cross threads.
    fn resolve_threads(&self, cfg: &TrainConfig) -> usize {
        if !self.factory.parallel() || cfg.n_learners <= 1 {
            return 1;
        }
        let want = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        want.clamp(1, cfg.n_learners)
    }

    pub fn run(&mut self, cfg: &TrainConfig, init_params: &[f32]) -> Result<RunRecord> {
        self.run_with_hook(cfg, init_params, None)
    }

    pub fn run_with_hook(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<RunRecord> {
        Ok(self.run_full(cfg, init_params, hook)?.0)
    }

    /// Full training loop; `hook(epoch, learner0_compressor, last_dw)` runs
    /// at each epoch end before evaluation. Returns the record and the
    /// final trained parameters (for checkpointing).
    pub fn run_full(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        mut hook: Option<&mut EpochHook<'_>>,
    ) -> Result<(RunRecord, Vec<f32>)> {
        assert!(cfg.n_learners >= 1);
        let layout = self.layout;
        let dataset = self.dataset;
        let factory = self.factory;
        let threads = self.resolve_threads(cfg);
        let parallel = threads > 1;

        let mut params = init_params.to_vec();
        let mut optimizer = optim::build(&cfg.optimizer, params.len(), cfg.momentum)
            .unwrap_or_else(|| panic!("unknown optimizer '{}'", cfg.optimizer));
        let mut topo = topology::build(&cfg.topology)
            .unwrap_or_else(|| panic!("unknown topology '{}'", cfg.topology));
        let mut fabric = Fabric::new(cfg.link);

        // Evaluation + sequential fallback run on this executor; in parallel
        // mode every learner additionally owns a worker executor.
        let mut local = factory.build_local()?;
        let mut learners: Vec<Learner> = (0..cfg.n_learners)
            .map(|id| -> Result<Learner> {
                let exec = if parallel {
                    Some(factory.build_worker()?)
                } else {
                    None
                };
                Ok(Learner::new(
                    id,
                    cfg.n_learners,
                    dataset,
                    layout,
                    &cfg.compression,
                    cfg.batch_per_learner,
                    cfg.seed,
                    exec,
                ))
            })
            .collect::<Result<Vec<Learner>>>()?;

        // Per-learner packet slots, reused across steps (no Vec-of-Vec
        // rebuild; buffers recycle through the compressor pools).
        let mut slots: Vec<Vec<Packet>> = (0..cfg.n_learners)
            .map(|_| Vec::with_capacity(layout.num_layers()))
            .collect();

        let steps_per_epoch = if cfg.steps_per_epoch > 0 {
            cfg.steps_per_epoch
        } else {
            (dataset.train_len() / (cfg.batch_per_learner * cfg.n_learners)).max(1)
        };
        let layer_lens: Vec<usize> = layout.layers.iter().map(|l| l.len()).collect();
        let inv_learners = 1.0f32 / cfg.n_learners as f32;

        let mut record = RunRecord {
            name: cfg.run_name.clone(),
            model: cfg.model_name.clone(),
            scheme: cfg.compression.kind.name().to_string(),
            learners: cfg.n_learners,
            batch_per_learner: cfg.batch_per_learner,
            optimizer: cfg.optimizer.clone(),
            epochs: Vec::new(),
            diverged: false,
            fabric: Default::default(),
        };

        let mut grad_mean = vec![0.0f32; layout.total];
        let mut reduced = Reduced::new(&layer_lens);

        'epochs: for epoch in 0..cfg.epochs {
            let sw = Stopwatch::start();
            let lr = cfg.lr.at(epoch);
            let mut loss_sum = 0.0f64;
            let mut nloss = 0usize;
            let mut comp_conv = CompStat::default();
            let mut comp_fc = CompStat::default();
            let mut comp_all = CompStat::default();

            for _step in 0..steps_per_epoch {
                // 1. every learner: local fwd/bwd + pack, fanned out across
                // worker threads (or sequentially on the shared executor)
                if parallel {
                    let chunk = cfg.n_learners.div_ceil(threads);
                    let params_ref: &[f32] = &params;
                    std::thread::scope(|scope| {
                        let mut handles = Vec::with_capacity(threads);
                        for (lch, sch) in
                            learners.chunks_mut(chunk).zip(slots.chunks_mut(chunk))
                        {
                            handles.push(scope.spawn(move || -> Result<()> {
                                for (l, s) in lch.iter_mut().zip(sch.iter_mut()) {
                                    l.step(params_ref, dataset, layout, s)?;
                                }
                                Ok(())
                            }));
                        }
                        for h in handles {
                            h.join().expect("learner worker panicked")?;
                        }
                        Ok::<(), anyhow::Error>(())
                    })?;
                } else {
                    for (l, s) in learners.iter_mut().zip(slots.iter_mut()) {
                        l.step_with(local.as_mut(), &params, dataset, layout, s)?;
                    }
                }

                // 2. accounting on the engine thread, learner-id order (the
                // f64 loss sum is order-sensitive — this keeps it identical
                // to the sequential path bit-for-bit)
                for (l, slot) in learners.iter().zip(slots.iter()) {
                    loss_sum += l.loss as f64;
                    nloss += 1;
                    if !l.loss.is_finite() || l.loss as f64 > cfg.divergence_loss {
                        record.diverged = true;
                    }
                    for (li, p) in slot.iter().enumerate() {
                        match layout.layers[li].kind {
                            LayerKind::Conv => comp_conv.add(p),
                            _ => comp_fc.add(p),
                        }
                        comp_all.add(p);
                    }
                }

                if record.diverged {
                    // record the partial epoch and stop
                    let (err, tloss) = test_error(local.as_mut(), dataset, &params)
                        .unwrap_or((100.0, f64::NAN));
                    record.epochs.push(epoch_record(
                        layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc,
                        comp_all, &learners, cfg, sw.secs(),
                    ));
                    break 'epochs;
                }

                // 3. exchange + unpack (dense sum, learner-id order) into the
                // persistent buffers, 4. central update
                topo.exchange_into(&slots, &layer_lens, &mut fabric, &mut reduced);
                for (li, sum) in reduced.sums.iter().enumerate() {
                    let dst = layout.view_mut(li, &mut grad_mean);
                    for (d, &s) in dst.iter_mut().zip(sum.iter()) {
                        *d = s * inv_learners;
                    }
                }
                if cfg.clip_norm > 0.0 {
                    let norm = crate::tensor::ops::dot(&grad_mean, &grad_mean).sqrt();
                    if norm > cfg.clip_norm {
                        let s = cfg.clip_norm / norm;
                        grad_mean.iter_mut().for_each(|g| *g *= s);
                    }
                }
                optimizer.step(&mut params, &grad_mean, lr);
            }

            if let Some(h) = hook.as_deref_mut() {
                h(epoch, learners[0].compressor.as_ref(), learners[0].grads());
            }

            let (err, tloss) = test_error(local.as_mut(), dataset, &params)?;
            record.epochs.push(epoch_record(
                layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc, comp_all,
                &learners, cfg, sw.secs(),
            ));
        }

        record.fabric = fabric.stats.clone();
        Ok((record, params))
    }
}

#[allow(clippy::too_many_arguments)]
fn epoch_record(
    layout: &Layout,
    epoch: usize,
    loss_sum: f64,
    nloss: usize,
    err: f64,
    tloss: f64,
    lr: f32,
    comp_conv: CompStat,
    comp_fc: CompStat,
    comp_all: CompStat,
    learners: &[Learner],
    cfg: &TrainConfig,
    wall: f64,
) -> EpochRecord {
    let (mut rg_p95, mut dw_p95) = (0.0f32, 0.0f32);
    if cfg.track_residue && !learners.is_empty() {
        let c = &learners[0].compressor;
        let last_dw = learners[0].grads();
        for li in 0..layout.num_layers() {
            rg_p95 = rg_p95.max(percentile(c.residue(li), 95.0));
        }
        if !last_dw.is_empty() {
            for li in 0..layout.num_layers() {
                dw_p95 = dw_p95.max(percentile(layout.view(li, last_dw), 95.0));
            }
        }
    }
    EpochRecord {
        epoch,
        train_loss: loss_sum / nloss.max(1) as f64,
        test_error_pct: err,
        test_loss: tloss,
        lr,
        comp_conv,
        comp_fc,
        comp_all,
        rg_p95,
        dw_p95,
        wall_secs: wall,
    }
}
