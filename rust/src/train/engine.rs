//! The synchronous data-parallel training engine — paper Algorithm 1.
//!
//! Per step, every learner samples its shard minibatch, runs forward+backward
//! (its own executor), and packs each layer through its compressor into its
//! reduce-plan bucket cell; the engine reduces each bucket over the
//! configured topology (`ps`, `ps:<S>`, `hier:<G>`, `ring`), unpacks into
//! the dense mean gradient, and applies the central optimizer. All learners
//! hold identical weights at every step — the paper's synchronous-SGD
//! setting.
//!
//! **Reduce plan** (DESIGN.md §Topologies). The engine builds a
//! [`ReducePlan`] once per run from the model layout: tiny layers (biases)
//! coalesce into buckets — one wire message per bucket, one latency charge
//! per bucket — and each bucket maps onto a **port** of the topology
//! (`ps:<S>` exposes S shard ports). The plan, not the topology, defines
//! the message structure, so bytes on the wire are identical across
//! topologies and exchange modes. `cfg.bucket_bytes` sets the coalescing
//! threshold (0 = auto: the link's latency·bandwidth product; 1 = per-layer
//! messages).
//!
//! **Layer-streamed exchange pipeline** (`--exchange streamed`, the
//! default). Gradients complete in reverse layer order during backward, and
//! the runtime reports each layout layer the moment its span is final
//! ([`Executor::step_streamed`]). Learners pack each layer immediately into
//! its bucket cell; the moment a *bucket* — not a layer — is complete at
//! every learner, the engine thread reduces it over the topology
//! ([`Topology::exchange_bucket_into`](crate::comm::Topology)) while
//! earlier layers are still in backward. The fabric places each bucket's
//! round on its port's simulated timeline (rounds on disjoint ports
//! overlap; rounds on one port serialize) so `FabricStats::sim_step_s()` /
//! `projected_speedup()` report the wall-clock value of compression +
//! overlap + sharding against the canonical dense baseline
//! ([`ReducePlan::dense_round_s`]). `--exchange barrier` joins all learners
//! first, then runs the same bucket rounds serialized after compute — same
//! packets, same bytes, different placement.
//!
//! **Persistent worker pool.** When the backend's [`ExecutorFactory`]
//! reports `parallel()`, the engine spawns `cfg.threads` workers **once per
//! run** and parks them on a condvar between steps
//! ([`pool::PoolCtl`](super::pool)). Each worker owns a contiguous chunk of
//! learners; all cross-learner reductions stay on the engine thread.
//!
//! **Determinism contract** (DESIGN.md §Threading, §Topologies): results
//! are **bit-identical** across every thread count, both exchange modes,
//! *and every topology*, because packets are reduced per bucket in
//! learner-id order (the simulated shard/rack/ring structure shapes only
//! the timeline), packing happens in the same (streamed) order in both
//! modes, and the f64 loss sum runs on the engine thread in learner-id
//! order. (One residual cross-mode difference: on a *diverged* run the
//! final aborted step's traffic appears in the streamed fabric stats but
//! not the barrier ones — streamed has already exchanged by the time the
//! loss is read, barrier skips that exchange. Losses and weights are
//! unaffected.) Pinned by rust/tests/engine_native.rs::{
//! parallel_matches_sequential_bitwise, streamed_matches_barrier_bitwise,
//! topologies_bitwise_identical}.
//!
//! **Zero-alloc exchange.** Packet buffers recycle through the compressor
//! pools, packets live in per-(learner, bucket) cells reused across steps,
//! and the topologies reduce into a persistent [`Reduced`] — the bucketed
//! cell→exchange→hand-back loop performs no steady-state heap allocation
//! (rust/tests/alloc_free.rs).
//!
//! Learners are simulated in-process (DESIGN.md §Substitutions): the
//! semantics (who computes what on which data, what crosses the wire) are
//! exactly the distributed ones; the fabric charges every packet its real
//! encoded byte size.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::eval::test_error;
use super::learner::{cells_for_plan, BucketCell, Learner};
use super::pool::PoolCtl;
use crate::comm::{topology, Bucket, Fabric, LinkModel, Reduced, ReducePlan, Topology};
use crate::compress::{self, Packet};
use crate::data::Dataset;
use crate::metrics::{percentile, CompStat, EpochRecord, RunRecord};
use crate::models::{LayerKind, Layout};
use crate::optim::{self, LrSchedule, Optimizer};
use crate::runtime::{Executor, ExecutorFactory};
use crate::util::timer::Stopwatch;

/// Exchange scheduling mode (`TrainConfig::exchange`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Overlap pack/exchange with the remaining backward (per-bucket rounds
    /// pipelined on the topology's ports).
    Streamed,
    /// Classic full barrier between the learner phase and the serialized
    /// bucket rounds.
    Barrier,
}

impl ExchangeMode {
    pub const NAMES: &'static [&'static str] = &["streamed", "barrier"];

    pub fn parse(name: &str) -> Result<ExchangeMode> {
        match name {
            "streamed" => Ok(ExchangeMode::Streamed),
            "barrier" => Ok(ExchangeMode::Barrier),
            other => bail!(
                "unknown exchange mode '{other}' (valid: {})",
                Self::NAMES.join(", ")
            ),
        }
    }
}

/// Everything that defines one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub run_name: String,
    pub model_name: String,
    /// Compute backend the run was wired with: "native" (hermetic
    /// layer-graph executors), "pjrt" (AOT artifacts), or "auto" (resolve
    /// at workload build time). Informational to the engine itself — the
    /// harness resolves it before the engine runs.
    pub backend: String,
    pub n_learners: usize,
    pub batch_per_learner: usize,
    pub epochs: usize,
    /// Optimizer steps per epoch; 0 = train_len / (batch * learners).
    pub steps_per_epoch: usize,
    pub lr: LrSchedule,
    pub optimizer: String,
    pub momentum: f32,
    pub compression: compress::Config,
    /// Exchange topology: "ring", "ps", "ps:<S>" (S shard servers),
    /// "hier:<G>" (racks of G feeding a root). Identical results for every
    /// choice; only bytes-per-link and the simulated timeline differ.
    pub topology: String,
    pub link: LinkModel,
    pub seed: u64,
    /// Abort (mark diverged) when train loss exceeds this or goes non-finite.
    pub divergence_loss: f64,
    /// Callback cadence for residue stats (every epoch end).
    pub track_residue: bool,
    /// Global-norm clip applied to the mean gradient before the central
    /// update (0 = off). Applied *after* exchange so it never interacts with
    /// the compression path.
    pub clip_norm: f32,
    /// Worker threads for the per-learner phase: 0 = auto (one per hardware
    /// thread, capped at n_learners), 1 = sequential. Results are
    /// bit-identical for every value (see module docs).
    pub threads: usize,
    /// Exchange scheduling: "streamed" (overlap per-bucket pack/exchange
    /// with backward, the default) or "barrier" (join all learners, then
    /// the same bucket rounds serialized). Bit-identical results either way
    /// (see module docs).
    pub exchange: String,
    /// Reduce-plan coalescing threshold in dense wire bytes: consecutive
    /// layers below it share one bucket message. 0 = auto (the link's
    /// latency·bandwidth product — [`ReducePlan::auto_threshold`]);
    /// 1 = one message per layer (the pre-plan wire shape). Affects only
    /// message granularity, never results.
    pub bucket_bytes: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            run_name: "run".into(),
            model_name: "model".into(),
            backend: "auto".into(),
            n_learners: 1,
            batch_per_learner: 32,
            epochs: 5,
            steps_per_epoch: 0,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd".into(),
            momentum: 0.9,
            compression: compress::Config::default(),
            topology: "ring".into(),
            link: LinkModel::default(),
            seed: 42,
            divergence_loss: 1e4,
            track_residue: true,
            clip_norm: 0.0,
            threads: 0,
            exchange: "streamed".into(),
            bucket_bytes: 0,
        }
    }
}

/// Observer hook for figure harnesses that need per-epoch internals:
/// `hook(epoch, learner0_compressor, learner0_last_dw)` — enough for the
/// Fig 5 percentile curves and Fig 6 residual histograms.
pub type EpochHook<'a> = dyn FnMut(usize, &dyn compress::Compressor, &[f32]) + 'a;

pub struct Engine<'a> {
    pub factory: &'a dyn ExecutorFactory,
    pub dataset: &'a dyn Dataset,
    pub layout: &'a Layout,
}

/// Run-scoped state shared between the engine thread and the pool workers.
/// Everything here is either lock-protected or atomically published; the
/// pool's generation barrier guarantees workers only touch it inside their
/// own step generation.
struct Shared<'a> {
    dataset: &'a dyn Dataset,
    layout: &'a Layout,
    /// The run's reduce plan: bucket coalescing + port mapping, built once.
    plan: ReducePlan,
    /// Central weights. Workers hold the read lock for the learner phase;
    /// the engine takes the write lock for the optimizer update (phases
    /// never overlap, so neither side ever blocks).
    params: RwLock<Vec<f32>>,
    learners: Vec<Mutex<Learner>>,
    /// Per-(learner, bucket) packet hand-off cells.
    cells: Vec<Vec<BucketCell>>,
    /// Learners that have completed bucket `bi` this step.
    ready: Vec<AtomicUsize>,
    /// Phase-start instant the pack-time ready stamps are measured from
    /// (reset by the engine before each step).
    phase_start: Mutex<Instant>,
    /// Nanoseconds (since phase start, min 1) when bucket `bi`'s LAST
    /// learner completed it — written by that learner at pack time, so the
    /// overlap timeline reflects when the bucket became exchangeable, not
    /// when the engine got around to observing it (identical semantics at
    /// every thread count). 0 = not yet.
    ready_at: Vec<AtomicU64>,
    /// Wakes the engine's bucket scan when a bucket completes or a worker
    /// checks in.
    event: ReadyEvent,
}

/// A sequence-counted wakeup for the engine's streamed bucket scan: bumped
/// by workers on every bucket completion and phase check-in, waited on (with
/// a short timeout as a missed-wakeup backstop) by the engine when a scan
/// pass finds nothing ready — the engine blocks instead of busy-spinning a
/// core away from the workers it is waiting on.
#[derive(Default)]
struct ReadyEvent {
    seq: Mutex<u64>,
    cv: Condvar,
}

impl ReadyEvent {
    fn bump(&self) {
        let mut s = self.seq.lock().unwrap();
        *s += 1;
        self.cv.notify_all();
    }

    fn current(&self) -> u64 {
        *self.seq.lock().unwrap()
    }

    /// Block until the sequence advances past `last` or a short timeout
    /// elapses; returns the sequence seen.
    fn wait_past(&self, last: u64) -> u64 {
        let mut s = self.seq.lock().unwrap();
        while *s == last {
            let (guard, timeout) = self
                .cv
                .wait_timeout(s, std::time::Duration::from_micros(500))
                .unwrap();
            s = guard;
            if timeout.timed_out() {
                break;
            }
        }
        *s
    }
}

/// Pool-worker body: park for the next step generation, run this worker's
/// learner chunk (publish per-bucket packets + bump the ready counters),
/// check in. Both exchange modes run the same streamed learner phase — the
/// mode only changes when the engine consumes the buckets.
fn worker_loop(shared: &Shared<'_>, ctl: &PoolCtl, range: std::ops::Range<usize>) {
    let mut gen = 0u64;
    while let Some(g) = ctl.next_gen(gen) {
        gen = g;
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> Result<()> {
            let params = shared.params.read().unwrap();
            for i in range.clone() {
                let mut l = shared.learners[i].lock().unwrap();
                l.step_streamed(
                    &params,
                    shared.dataset,
                    shared.layout,
                    &shared.plan,
                    &shared.cells[i],
                    &mut |bi| shared.bucket_packed(bi),
                )?;
            }
            Ok(())
        }));
        ctl.report(match res {
            Ok(Ok(())) => None,
            Ok(Err(e)) => Some(format!("{e:#}")),
            Err(p) => Some(panic_message(p.as_ref())),
        });
        // wake the engine's bucket scan so it can observe all_done (matters
        // when a failed worker leaves buckets that will never become ready)
        shared.event.bump();
    }
}

impl Shared<'_> {
    /// Bucket-ready notification target (both sequential and pooled): bump
    /// bucket `bi`'s counter; the learner completing the count records the
    /// pack-time ready stamp and wakes the engine.
    fn bucket_packed(&self, bi: usize) {
        let c = self.ready[bi].fetch_add(1, Ordering::Release) + 1;
        if c == self.learners.len() {
            let ns = self.phase_start.lock().unwrap().elapsed().as_nanos() as u64;
            self.ready_at[bi].store(ns.max(1), Ordering::Release);
            self.event.bump();
        }
    }
}

/// Shuts the pool down on drop — including during an engine-thread unwind
/// (a panicking hook, a bug), where parked workers would otherwise deadlock
/// the `thread::scope`'s implicit join.
struct PoolShutdown<'a>(&'a PoolCtl);

impl Drop for PoolShutdown<'_> {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

impl<'a> Engine<'a> {
    pub fn new(
        factory: &'a dyn ExecutorFactory,
        dataset: &'a dyn Dataset,
        layout: &'a Layout,
    ) -> Engine<'a> {
        Engine {
            factory,
            dataset,
            layout,
        }
    }

    /// Resolve the worker-thread count for a run: honor `cfg.threads`, cap at
    /// n_learners, and force 1 when the backend cannot cross threads.
    fn resolve_threads(&self, cfg: &TrainConfig) -> usize {
        if !self.factory.parallel() || cfg.n_learners <= 1 {
            return 1;
        }
        let want = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        want.clamp(1, cfg.n_learners)
    }

    pub fn run(&mut self, cfg: &TrainConfig, init_params: &[f32]) -> Result<RunRecord> {
        self.run_with_hook(cfg, init_params, None)
    }

    pub fn run_with_hook(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<RunRecord> {
        Ok(self.run_full(cfg, init_params, hook)?.0)
    }

    /// Full training loop; `hook(epoch, learner0_compressor, last_dw)` runs
    /// at each epoch end before evaluation. Returns the record and the
    /// final trained parameters (for checkpointing).
    pub fn run_full(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<(RunRecord, Vec<f32>)> {
        assert!(cfg.n_learners >= 1);
        let layout = self.layout;
        let dataset = self.dataset;
        let factory = self.factory;

        // Validate every by-name knob up front so a typo'd config fails with
        // the valid list, not a mid-run panic.
        let mode = ExchangeMode::parse(&cfg.exchange)?;
        let optimizer = optim::build(&cfg.optimizer, init_params.len(), cfg.momentum)
            .ok_or_else(|| {
                anyhow!(
                    "unknown optimizer '{}' (valid: sgd, adam, rmsprop)",
                    cfg.optimizer
                )
            })?;
        let topo = topology::build(&cfg.topology, cfg.n_learners)?;
        let threads = self.resolve_threads(cfg);
        let parallel = threads > 1;

        // The run's reduce plan: bucket coalescing + port partition, built
        // once from the layout (DESIGN.md §Topologies).
        let threshold = if cfg.bucket_bytes == 0 {
            ReducePlan::auto_threshold(&cfg.link)
        } else {
            cfg.bucket_bytes
        };
        let plan = ReducePlan::build(layout, threshold, topo.ports());
        let num_buckets = plan.num_buckets();

        let local = factory.build_local()?;
        let learners = (0..cfg.n_learners)
            .map(|id| -> Result<Mutex<Learner>> {
                let exec = if parallel {
                    Some(factory.build_worker()?)
                } else {
                    None
                };
                Ok(Mutex::new(Learner::new(
                    id,
                    cfg.n_learners,
                    dataset,
                    layout,
                    &cfg.compression,
                    cfg.batch_per_learner,
                    cfg.seed,
                    exec,
                )))
            })
            .collect::<Result<Vec<_>>>()?;

        let cells: Vec<Vec<BucketCell>> =
            (0..cfg.n_learners).map(|_| cells_for_plan(&plan)).collect();
        let shared = Shared {
            dataset,
            layout,
            plan,
            params: RwLock::new(init_params.to_vec()),
            learners,
            cells,
            ready: (0..num_buckets).map(|_| AtomicUsize::new(0)).collect(),
            phase_start: Mutex::new(Instant::now()),
            ready_at: (0..num_buckets).map(|_| AtomicU64::new(0)).collect(),
            event: ReadyEvent::default(),
        };

        let record = if parallel {
            let ctl = PoolCtl::new();
            std::thread::scope(|scope| {
                let chunk = cfg.n_learners.div_ceil(threads);
                let mut workers = 0usize;
                let mut start = 0usize;
                while start < cfg.n_learners {
                    let end = (start + chunk).min(cfg.n_learners);
                    let (sh, c) = (&shared, &ctl);
                    scope.spawn(move || worker_loop(sh, c, start..end));
                    workers += 1;
                    start = end;
                }
                // Shut the pool down however run_loop exits (ok, error, or
                // panic) — parked workers would otherwise deadlock the
                // scope's implicit join.
                let _shutdown = PoolShutdown(&ctl);
                run_loop(
                    cfg,
                    layout,
                    dataset,
                    local,
                    &shared,
                    Some((&ctl, workers)),
                    mode,
                    topo,
                    optimizer,
                    hook,
                )
            })?
        } else {
            run_loop(
                cfg, layout, dataset, local, &shared, None, mode, topo, optimizer, hook,
            )?
        };

        let params = shared.params.into_inner().unwrap();
        Ok((record, params))
    }
}

/// Fold one packet into the per-kind compression stats. Single definition
/// so the normal exchange path and the diverged-barrier path (which counts
/// packed-but-unsent packets) can never drift apart.
fn tally_packet(
    layout: &Layout,
    p: &Packet,
    comp_conv: &mut CompStat,
    comp_fc: &mut CompStat,
    comp_all: &mut CompStat,
) {
    match layout.layers[p.layer].kind {
        LayerKind::Conv => comp_conv.add(p),
        _ => comp_fc.add(p),
    }
    comp_all.add(p);
}

/// Take one ready bucket out of every learner's cell (learner-id order —
/// the determinism contract), fold its packets into the compression stats,
/// reduce it over the topology, and hand the spent packets back for
/// next-step recycling. Allocation-free in steady state (`gather` reuses
/// its per-learner vecs).
#[allow(clippy::too_many_arguments)]
fn exchange_one_bucket(
    shared: &Shared<'_>,
    layout: &Layout,
    layer_lens: &[usize],
    bucket: &Bucket,
    gather: &mut [Vec<Packet>],
    topo: &mut dyn Topology,
    fabric: &mut Fabric,
    reduced: &mut Reduced,
    comp_conv: &mut CompStat,
    comp_fc: &mut CompStat,
    comp_all: &mut CompStat,
) -> crate::comm::RoundCost {
    let bi = bucket.id;
    for (l, cells) in shared.cells.iter().enumerate() {
        let mut cell = cells[bi].lock();
        for slot in cell.slots.iter_mut() {
            gather[l].push(slot.take().expect("ready bucket is missing a packet"));
        }
    }
    for packets in gather.iter() {
        for p in packets {
            tally_packet(layout, p, comp_conv, comp_fc, comp_all);
        }
    }
    let cost = topo.exchange_bucket_into(bucket, &*gather, layer_lens, fabric, reduced);
    for (l, cells) in shared.cells.iter().enumerate() {
        let mut cell = cells[bi].lock();
        for (slot, p) in cell.slots.iter_mut().zip(gather[l].drain(..)) {
            *slot = Some(p);
        }
    }
    cost
}

/// The training loop proper, shared by all (sequential/pool ×
/// barrier/streamed × topology) combinations. `pool` carries the step
/// barrier and the worker count when a persistent pool is attached; `None`
/// runs every learner on the engine thread through `local`. Both modes run
/// the same streamed learner phase and the same per-bucket rounds — the
/// mode decides *when* the engine consumes buckets (mid-backward vs after
/// the join) and how the rounds land on the simulated timeline.
#[allow(clippy::too_many_arguments)]
fn run_loop(
    cfg: &TrainConfig,
    layout: &Layout,
    dataset: &dyn Dataset,
    mut local: Box<dyn Executor>,
    shared: &Shared<'_>,
    pool: Option<(&PoolCtl, usize)>,
    mode: ExchangeMode,
    mut topo: Box<dyn Topology>,
    mut optimizer: Box<dyn Optimizer>,
    mut hook: Option<&mut EpochHook<'_>>,
) -> Result<RunRecord> {
    let n = cfg.n_learners;
    let plan = &shared.plan;
    let num_buckets = plan.num_buckets();
    let layer_lens = layout.layer_lens();
    let inv_learners = 1.0f32 / n as f32;
    let streamed = mode == ExchangeMode::Streamed;
    let mut fabric = Fabric::new(cfg.link);

    let steps_per_epoch = if cfg.steps_per_epoch > 0 {
        cfg.steps_per_epoch
    } else {
        (dataset.train_len() / (cfg.batch_per_learner * n)).max(1)
    };

    let mut record = RunRecord {
        name: cfg.run_name.clone(),
        model: cfg.model_name.clone(),
        scheme: cfg.compression.kind.name().to_string(),
        learners: n,
        batch_per_learner: cfg.batch_per_learner,
        optimizer: cfg.optimizer.clone(),
        epochs: Vec::new(),
        diverged: false,
        fabric: Default::default(),
    };

    let mut grad_mean = vec![0.0f32; layout.total];
    let mut reduced = Reduced::new(&layer_lens);
    // The no-compression baseline: one coalesced whole-model dense round,
    // fixed for the run and identical across topologies, exchange modes,
    // and bucket thresholds — `projected_speedup()` always measures against
    // the same "before" system (never inflated by message-granularity
    // latency or deflated by sharding).
    let dense_round_s = plan.dense_round_s(&layer_lens, n, &cfg.link);
    // Engine scratch, reused every step (no allocation in the steady
    // state): per-learner bucket gathers, per-bucket done flags,
    // all-learners-ready timestamps, and per-port completion times.
    let max_bucket = plan.buckets.iter().map(|b| b.num_layers()).max().unwrap_or(0);
    let mut gather: Vec<Vec<Packet>> =
        (0..n).map(|_| Vec::with_capacity(max_bucket)).collect();
    let mut done_flags = vec![false; num_buckets];
    let mut stamps = vec![-1.0f64; num_buckets];
    let mut port_end = vec![0.0f64; topo.ports()];

    'epochs: for epoch in 0..cfg.epochs {
        let sw = Stopwatch::start();
        let lr = cfg.lr.at(epoch);
        let mut loss_sum = 0.0f64;
        let mut nloss = 0usize;
        let mut comp_conv = CompStat::default();
        let mut comp_fc = CompStat::default();
        let mut comp_all = CompStat::default();

        for _step in 0..steps_per_epoch {
            // --- learner phase (identical in both modes) -----------------
            for r in &shared.ready {
                r.store(0, Ordering::Relaxed);
            }
            for r in &shared.ready_at {
                r.store(0, Ordering::Relaxed);
            }
            done_flags.iter_mut().for_each(|d| *d = false);
            port_end.iter_mut().for_each(|p| *p = 0.0);
            *shared.phase_start.lock().unwrap() = Instant::now();
            let sw_phase = Stopwatch::start();

            if let Some((ctl, _)) = pool {
                ctl.kick();
            } else {
                // Sequential learner phase on the engine thread; ready
                // stamps are taken at pack time (same callback as the
                // pooled path) so the overlap timeline reflects when each
                // bucket *became* exchangeable at any thread count.
                for i in 0..n {
                    let params = shared.params.read().unwrap();
                    let mut l = shared.learners[i].lock().unwrap();
                    l.step_streamed_with(
                        local.as_mut(),
                        &params,
                        dataset,
                        layout,
                        plan,
                        &shared.cells[i],
                        &mut |bi| shared.bucket_packed(bi),
                    )?;
                }
            }

            if streamed {
                // --- streamed: consume buckets as they complete ----------
                // (reverse layer order is the natural completion order);
                // reduce each over the topology while the rest of backward
                // is still running, pipelining rounds across the
                // topology's ports.
                let mut pending = num_buckets;
                let mut comm_serial = 0.0f64;
                let mut saw_done = pool.is_none();
                let mut event_seq = shared.event.current();
                loop {
                    let mut progressed = false;
                    for (bi, bucket) in plan.buckets.iter().enumerate() {
                        if done_flags[bi] || shared.ready[bi].load(Ordering::Acquire) != n {
                            continue;
                        }
                        // the stamp store trails the final counter bump by
                        // nanoseconds; spin past that publish window
                        let mut ns = shared.ready_at[bi].load(Ordering::Acquire);
                        while ns == 0 {
                            std::hint::spin_loop();
                            ns = shared.ready_at[bi].load(Ordering::Acquire);
                        }
                        stamps[bi] = ns as f64 * 1e-9;
                        let cost = exchange_one_bucket(
                            shared,
                            layout,
                            &layer_lens,
                            bucket,
                            &mut gather,
                            topo.as_mut(),
                            &mut fabric,
                            &mut reduced,
                            &mut comp_conv,
                            &mut comp_fc,
                            &mut comp_all,
                        );
                        comm_serial += cost.comm_s;
                        // rounds on one port serialize; disjoint ports
                        // overlap — the sharded-PS win
                        let port = bucket.port;
                        port_end[port] = port_end[port].max(stamps[bi]) + cost.comm_s;
                        done_flags[bi] = true;
                        pending -= 1;
                        progressed = true;
                    }
                    if pending == 0 {
                        break;
                    }
                    if !progressed {
                        if saw_done {
                            // a full scan after every worker checked in
                            // found nothing: a worker failed mid-phase
                            // (surfaced by wait_done below)
                            break;
                        }
                        // Idle only: sample the pool barrier, then block on
                        // the ready event (short-timeout backstop) instead
                        // of busy-spinning a core away from the workers.
                        // While buckets are flowing, the scan touches
                        // nothing but atomics.
                        saw_done = match pool {
                            Some((ctl, workers)) => ctl.all_done(workers),
                            None => true,
                        };
                        event_seq = shared.event.wait_past(event_seq);
                    }
                }
                if let Some((ctl, workers)) = pool {
                    ctl.wait_done(workers)?;
                }
                if pending > 0 {
                    bail!("streamed exchange ended with {pending} buckets never ready");
                }
                // compute span = last bucket completion; fold the step onto
                // the simulated timeline (overlap vs barrier vs dense)
                let compute_s = stamps.iter().cloned().fold(0.0f64, f64::max);
                let comm_end = port_end.iter().cloned().fold(0.0f64, f64::max);
                fabric.record_step(compute_s, comm_serial, comm_end, dense_round_s);

                // loss accounting on the engine thread, learner-id order
                // (the f64 sum is order-sensitive)
                for cell in &shared.learners {
                    let l = cell.lock().unwrap();
                    loss_sum += l.loss as f64;
                    nloss += 1;
                    if !l.loss.is_finite() || l.loss as f64 > cfg.divergence_loss {
                        record.diverged = true;
                    }
                }
            } else {
                // --- barrier: join all learners, then the same bucket
                // rounds serialized after compute ------------------------
                if let Some((ctl, workers)) = pool {
                    ctl.wait_done(workers)?;
                }
                let compute_s = sw_phase.secs();

                for cell in &shared.learners {
                    let l = cell.lock().unwrap();
                    loss_sum += l.loss as f64;
                    nloss += 1;
                    if !l.loss.is_finite() || l.loss as f64 > cfg.divergence_loss {
                        record.diverged = true;
                    }
                }

                if !record.diverged {
                    let mut comm_serial = 0.0f64;
                    for bucket in &plan.buckets {
                        let cost = exchange_one_bucket(
                            shared,
                            layout,
                            &layer_lens,
                            bucket,
                            &mut gather,
                            topo.as_mut(),
                            &mut fabric,
                            &mut reduced,
                            &mut comp_conv,
                            &mut comp_fc,
                            &mut comp_all,
                        );
                        comm_serial += cost.comm_s;
                    }
                    fabric.record_step(
                        compute_s,
                        comm_serial,
                        compute_s + comm_serial,
                        dense_round_s,
                    );
                } else {
                    // diverged: the final step's packets were packed but will
                    // not cross the wire — still fold them into the epoch's
                    // compression stats so the partial-epoch report matches
                    // the streamed mode's accounting (only fabric traffic
                    // differs across modes on a diverged run; module docs)
                    for cells in &shared.cells {
                        for cell in cells.iter() {
                            let cell = cell.lock();
                            for p in cell.slots.iter().flatten() {
                                tally_packet(
                                    layout, p, &mut comp_conv, &mut comp_fc, &mut comp_all,
                                );
                            }
                        }
                    }
                }
            }

            if record.diverged {
                // record the partial epoch and stop (no central update)
                let (err, tloss) = {
                    let params = shared.params.read().unwrap();
                    test_error(local.as_mut(), dataset, &params).unwrap_or((100.0, f64::NAN))
                };
                let l0 = shared.learners[0].lock().unwrap();
                record.epochs.push(epoch_record(
                    layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc,
                    comp_all, &l0, cfg, sw.secs(),
                ));
                break 'epochs;
            }

            // central update: unpack the dense mean, clip, optimizer step
            for (li, sum) in reduced.sums.iter().enumerate() {
                let dst = layout.view_mut(li, &mut grad_mean);
                for (d, &s) in dst.iter_mut().zip(sum.iter()) {
                    *d = s * inv_learners;
                }
            }
            if cfg.clip_norm > 0.0 {
                let norm = crate::tensor::ops::dot(&grad_mean, &grad_mean).sqrt();
                if norm > cfg.clip_norm {
                    let s = cfg.clip_norm / norm;
                    grad_mean.iter_mut().for_each(|g| *g *= s);
                }
            }
            let mut params = shared.params.write().unwrap();
            optimizer.step(&mut params, &grad_mean, lr);
        }

        if let Some(h) = hook.as_deref_mut() {
            let l0 = shared.learners[0].lock().unwrap();
            h(epoch, l0.compressor.as_ref(), l0.grads());
        }

        let (err, tloss) = {
            let params = shared.params.read().unwrap();
            test_error(local.as_mut(), dataset, &params)?
        };
        let l0 = shared.learners[0].lock().unwrap();
        record.epochs.push(epoch_record(
            layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc, comp_all, &l0,
            cfg, sw.secs(),
        ));
    }

    record.fabric = fabric.stats.clone();
    Ok(record)
}

#[allow(clippy::too_many_arguments)]
fn epoch_record(
    layout: &Layout,
    epoch: usize,
    loss_sum: f64,
    nloss: usize,
    err: f64,
    tloss: f64,
    lr: f32,
    comp_conv: CompStat,
    comp_fc: CompStat,
    comp_all: CompStat,
    learner0: &Learner,
    cfg: &TrainConfig,
    wall: f64,
) -> EpochRecord {
    let (mut rg_p95, mut dw_p95) = (0.0f32, 0.0f32);
    if cfg.track_residue {
        let c = &learner0.compressor;
        let last_dw = learner0.grads();
        for li in 0..layout.num_layers() {
            rg_p95 = rg_p95.max(percentile(c.residue(li), 95.0));
        }
        if !last_dw.is_empty() {
            for li in 0..layout.num_layers() {
                dw_p95 = dw_p95.max(percentile(layout.view(li, last_dw), 95.0));
            }
        }
    }
    EpochRecord {
        epoch,
        train_loss: loss_sum / nloss.max(1) as f64,
        test_error_pct: err,
        test_loss: tloss,
        lr,
        comp_conv,
        comp_fc,
        comp_all,
        rg_p95,
        dw_p95,
        wall_secs: wall,
    }
}
