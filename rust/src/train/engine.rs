//! The synchronous data-parallel training engine — paper Algorithm 1.
//!
//! Per step, for every learner: sample the learner's shard minibatch, run
//! forward+backward (the AOT-compiled HLO via PJRT, or the native reference
//! executor), `pack()` each layer through the learner's compressor, then
//! `exchange()` all packets over the configured topology (parameter server
//! or ring), `unpack()` into the dense mean gradient and apply the central
//! optimizer. All learners hold identical weights at every step — the
//! paper's synchronous-SGD setting.
//!
//! Learners are simulated in-process (DESIGN.md §Substitutions): the
//! semantics (who computes what on which data, what crosses the wire) are
//! exactly the distributed ones; the fabric charges every packet its real
//! encoded byte size.

use anyhow::Result;

use super::{eval::test_error, learner::Learner};
use crate::comm::{topology, Fabric, LinkModel};
use crate::compress;
use crate::data::Dataset;
use crate::metrics::{percentile, CompStat, EpochRecord, RunRecord};
use crate::models::{LayerKind, Layout};
use crate::optim::{self, LrSchedule};
use crate::runtime::Executor;
use crate::util::timer::Stopwatch;

/// Everything that defines one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub run_name: String,
    pub model_name: String,
    pub n_learners: usize,
    pub batch_per_learner: usize,
    pub epochs: usize,
    /// Optimizer steps per epoch; 0 = train_len / (batch * learners).
    pub steps_per_epoch: usize,
    pub lr: LrSchedule,
    pub optimizer: String,
    pub momentum: f32,
    pub compression: compress::Config,
    pub topology: String,
    pub link: LinkModel,
    pub seed: u64,
    /// Abort (mark diverged) when train loss exceeds this or goes non-finite.
    pub divergence_loss: f64,
    /// Callback cadence for residue stats (every epoch end).
    pub track_residue: bool,
    /// Global-norm clip applied to the mean gradient before the central
    /// update (0 = off). Applied *after* exchange so it never interacts with
    /// the compression path.
    pub clip_norm: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            run_name: "run".into(),
            model_name: "model".into(),
            n_learners: 1,
            batch_per_learner: 32,
            epochs: 5,
            steps_per_epoch: 0,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd".into(),
            momentum: 0.9,
            compression: compress::Config::default(),
            topology: "ring".into(),
            link: LinkModel::default(),
            seed: 42,
            divergence_loss: 1e4,
            track_residue: true,
            clip_norm: 0.0,
        }
    }
}

/// Observer hook for figure harnesses that need per-epoch internals:
/// `hook(epoch, learner0_compressor, learner0_last_dw)` — enough for the
/// Fig 5 percentile curves and Fig 6 residual histograms.
pub type EpochHook<'a> = dyn FnMut(usize, &dyn compress::Compressor, &[f32]) + 'a;

pub struct Engine<'a> {
    pub executor: &'a mut dyn Executor,
    pub dataset: &'a dyn Dataset,
    pub layout: &'a Layout,
}

impl<'a> Engine<'a> {
    pub fn new(
        executor: &'a mut dyn Executor,
        dataset: &'a dyn Dataset,
        layout: &'a Layout,
    ) -> Engine<'a> {
        Engine {
            executor,
            dataset,
            layout,
        }
    }

    pub fn run(&mut self, cfg: &TrainConfig, init_params: &[f32]) -> Result<RunRecord> {
        self.run_with_hook(cfg, init_params, None)
    }

    pub fn run_with_hook(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<RunRecord> {
        Ok(self.run_full(cfg, init_params, hook)?.0)
    }

    /// Full training loop; `hook(epoch, learner0_compressor, last_dw)` runs
    /// at each epoch end before evaluation. Returns the record and the
    /// final trained parameters (for checkpointing).
    pub fn run_full(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        mut hook: Option<&mut EpochHook<'_>>,
    ) -> Result<(RunRecord, Vec<f32>)> {
        assert!(cfg.n_learners >= 1);
        let layout = self.layout;
        let mut params = init_params.to_vec();
        let mut optimizer = optim::build(&cfg.optimizer, params.len(), cfg.momentum)
            .unwrap_or_else(|| panic!("unknown optimizer '{}'", cfg.optimizer));
        let mut topo = topology::build(&cfg.topology)
            .unwrap_or_else(|| panic!("unknown topology '{}'", cfg.topology));
        let mut fabric = Fabric::new(cfg.link);

        let mut learners: Vec<Learner> = (0..cfg.n_learners)
            .map(|id| {
                Learner::new(
                    id,
                    cfg.n_learners,
                    self.dataset,
                    layout,
                    &cfg.compression,
                    cfg.batch_per_learner,
                    cfg.seed,
                )
            })
            .collect();

        let steps_per_epoch = if cfg.steps_per_epoch > 0 {
            cfg.steps_per_epoch
        } else {
            (self.dataset.train_len() / (cfg.batch_per_learner * cfg.n_learners)).max(1)
        };
        let layer_lens: Vec<usize> = layout.layers.iter().map(|l| l.len()).collect();
        let inv_learners = 1.0f32 / cfg.n_learners as f32;

        let mut record = RunRecord {
            name: cfg.run_name.clone(),
            model: cfg.model_name.clone(),
            scheme: cfg.compression.kind.name().to_string(),
            learners: cfg.n_learners,
            batch_per_learner: cfg.batch_per_learner,
            optimizer: cfg.optimizer.clone(),
            epochs: Vec::new(),
            diverged: false,
            fabric: Default::default(),
        };

        let mut grad_mean = vec![0.0f32; layout.total];
        let mut last_dw: Vec<f32> = Vec::new();

        'epochs: for epoch in 0..cfg.epochs {
            let sw = Stopwatch::start();
            let lr = cfg.lr.at(epoch);
            let mut loss_sum = 0.0f64;
            let mut nloss = 0usize;
            let mut comp_conv = CompStat::default();
            let mut comp_fc = CompStat::default();
            let mut comp_all = CompStat::default();

            for _step in 0..steps_per_epoch {
                // 1. every learner: local fwd/bwd + pack
                let mut per_learner: Vec<Vec<compress::Packet>> =
                    Vec::with_capacity(cfg.n_learners);
                for l in learners.iter_mut() {
                    let out = {
                        let batch = l.next_batch(self.dataset);
                        self.executor.step(&params, batch)?
                    };
                    loss_sum += out.loss as f64;
                    nloss += 1;
                    if !out.loss.is_finite() || out.loss as f64 > cfg.divergence_loss {
                        record.diverged = true;
                    }
                    if l.id == 0 {
                        last_dw = out.grads.clone();
                    }
                    let packets = l.pack(layout, &out.grads);
                    for (li, p) in packets.iter().enumerate() {
                        match layout.layers[li].kind {
                            LayerKind::Conv => comp_conv.add(p),
                            _ => comp_fc.add(p),
                        }
                        comp_all.add(p);
                    }
                    per_learner.push(packets);
                }

                if record.diverged {
                    // record the partial epoch and stop
                    let (err, tloss) =
                        test_error(self.executor, self.dataset, &params).unwrap_or((100.0, f64::NAN));
                    record.epochs.push(self.epoch_record(
                        epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc, comp_all,
                        &learners, &last_dw, cfg, sw.secs(),
                    ));
                    break 'epochs;
                }

                // 2. exchange + unpack (dense sum), 3. central update
                let reduced = topo.exchange(&per_learner, &layer_lens, &mut fabric);
                for (li, sum) in reduced.sums.iter().enumerate() {
                    let dst = layout.view_mut(li, &mut grad_mean);
                    for (d, &s) in dst.iter_mut().zip(sum.iter()) {
                        *d = s * inv_learners;
                    }
                }
                if cfg.clip_norm > 0.0 {
                    let norm = crate::tensor::ops::dot(&grad_mean, &grad_mean).sqrt();
                    if norm > cfg.clip_norm {
                        let s = cfg.clip_norm / norm;
                        grad_mean.iter_mut().for_each(|g| *g *= s);
                    }
                }
                optimizer.step(&mut params, &grad_mean, lr);
            }

            if let Some(h) = hook.as_deref_mut() {
                h(epoch, learners[0].compressor.as_ref(), &last_dw);
            }

            let (err, tloss) = test_error(self.executor, self.dataset, &params)?;
            record.epochs.push(self.epoch_record(
                epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc, comp_all,
                &learners, &last_dw, cfg, sw.secs(),
            ));
        }

        record.fabric = fabric.stats.clone();
        Ok((record, params))
    }

    #[allow(clippy::too_many_arguments)]
    fn epoch_record(
        &self,
        epoch: usize,
        loss_sum: f64,
        nloss: usize,
        err: f64,
        tloss: f64,
        lr: f32,
        comp_conv: CompStat,
        comp_fc: CompStat,
        comp_all: CompStat,
        learners: &[Learner],
        last_dw: &[f32],
        cfg: &TrainConfig,
        wall: f64,
    ) -> EpochRecord {
        let (mut rg_p95, mut dw_p95) = (0.0f32, 0.0f32);
        if cfg.track_residue && !learners.is_empty() {
            let c = &learners[0].compressor;
            for li in 0..self.layout.num_layers() {
                rg_p95 = rg_p95.max(percentile(c.residue(li), 95.0));
            }
            if !last_dw.is_empty() {
                for li in 0..self.layout.num_layers() {
                    dw_p95 = dw_p95.max(percentile(self.layout.view(li, last_dw), 95.0));
                }
            }
        }
        EpochRecord {
            epoch,
            train_loss: loss_sum / nloss.max(1) as f64,
            test_error_pct: err,
            test_loss: tloss,
            lr,
            comp_conv,
            comp_fc,
            comp_all,
            rg_p95,
            dw_p95,
            wall_secs: wall,
        }
    }
}
