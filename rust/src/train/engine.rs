//! The data-parallel training engine — paper Algorithm 1 under a
//! **bounded-staleness window scheduler**.
//!
//! Per step, every learner samples its shard minibatch, runs
//! forward+backward (its own executor), and packs each layer through its
//! compressor into its reduce-plan bucket cell; the engine reduces each
//! bucket over the configured topology (`ps`, `ps:<S>`, `hier:<G>`,
//! `ring`), unpacks into the dense mean gradient, and applies the central
//! optimizer.
//!
//! **Staleness window** (`--staleness K`, default 0; DESIGN.md §Bounded
//! staleness). Learners may run up to `K` steps ahead of the applied-update
//! frontier: step `t`'s gradients are computed against the param version
//! `θ_{max(0, t−K)}`, and a learner may start step `t` the moment update
//! `t−K−1` has been applied — it never waits for the fleet's slowest
//! member, only for the window. `K = 0` degenerates to the classic
//! synchronous engine (gradients at `θ_t`, every step a barrier) and is
//! **bit-identical to it by construction**: the same per-learner order of
//! operations, the same learner-id reduce order, the same f64 loss sum
//! (rust/tests/engine_native.rs::staleness_zero_matches_synchronous_bitwise).
//! AdaComp's residue accumulation is exactly what makes `K > 0` safe: a
//! gradient computed on slightly stale weights is a delayed update, and the
//! paper's compression is robust to delayed residual application.
//!
//! In-flight steps from adjacent windows coexist through **per-(learner,
//! bucket, step-slot) cells**: each learner owns a ring of `K + 1` cell
//! rows (slot = step mod `K + 1`), and a slot is reused only after its
//! step's update has been applied — the engine has emptied the cells and
//! the compressor pool has recycled the packet buffers, so the windowed
//! loop stays allocation-free in steady state (rust/tests/alloc_free.rs
//! pins `K = 2`). Central weights live in a **param-version ring** of the
//! same depth: `θ_v` occupies slot `v mod (K + 1)` and is overwritten by
//! `θ_{v+K+1}` only after every step that reads `θ_v` has finished.
//!
//! **Reduce plan** (DESIGN.md §Topologies). The engine builds a
//! [`ReducePlan`] from the model layout (rebuilt at membership epochs and
//! adaptive-controller re-tunes): tiny layers (biases)
//! coalesce into buckets — one wire message per bucket, one latency charge
//! per bucket — and each bucket maps onto a **port** of the topology
//! (`ps:<S>` exposes S shard ports). The engine exchanges a bucket's round
//! as soon as all learners have published it **for that step**; because a
//! learner publishes step `t` completely before touching step `t + 1`,
//! cross-step readiness is monotone and rounds still run in step order.
//!
//! **Simulated timeline** (DESIGN.md §Bounded staleness). The fabric's
//! step timeline is now continuous across steps: per-port completion times
//! (`port_end`) carry over, and each round is placed from its
//! [`RoundSched`] ready-time inputs — `max(bucket ready, port free)` —
//! where a bucket's ready time is the max over learners of
//! `start_l(t) + publish_offset_l · jitter_mult_l(t)`. Per-learner compute
//! spans are measured wall time of that learner's own step (so the
//! simulated fleet is N parallel learners at any local thread count),
//! scaled by the deterministic straggler model
//! ([`LinkModel::compute_mult`], `--jitter`). `FabricStats` additionally
//! accounts `stall_s` (simulated learner idle time waiting on the window —
//! the synchronous engine charges the full barrier wait here) and the
//! per-learner critical-path share. The dense baseline stays the
//! **synchronous coalesced round** ([`ReducePlan::dense_round_s`]):
//! `projected_speedup` always measures against the same K = 0, no-overlap,
//! no-compression "before" system.
//!
//! **Persistent worker pool.** When the backend's [`ExecutorFactory`]
//! reports `parallel()`, the engine spawns `cfg.threads` workers **once per
//! run**. Workers free-run their learner chunks through the step sequence
//! and park only when a step would outrun the staleness window or the
//! epoch frontier ([`pool::PoolCtl`](super::pool)); all cross-learner
//! reductions stay on the engine thread.
//!
//! **Determinism contract** (DESIGN.md §Threading, §Topologies, §Bounded
//! staleness): results are **bit-identical** across every thread count,
//! both exchange modes, every topology, *and under any jitter*, at every
//! fixed `K`: step `t`'s gradients depend only on `(θ_{max(0,t−K)}`, the
//! learner's private RNG/residue state), packets are reduced per bucket in
//! learner-id order, updates apply in step order on the engine thread, and
//! jitter shapes only the simulated timeline — never gradients, losses, or
//! bytes. (One residual cross-mode difference: on a *diverged* run the
//! final aborted step's traffic appears in the streamed fabric stats but
//! not the barrier ones — streamed has already exchanged by the time the
//! loss is read, barrier skips that exchange. Losses and weights are
//! unaffected.) Pinned by rust/tests/engine_native.rs::{
//! parallel_matches_sequential_bitwise, streamed_matches_barrier_bitwise,
//! topologies_bitwise_identical, staleness_zero_matches_synchronous_bitwise,
//! staleness_window_deterministic_under_jitter}.
//!
//! **Zero-alloc exchange.** Packet buffers recycle through the compressor
//! pools, packets live in the per-(learner, bucket, slot) cell rings
//! reused across steps, and the topologies reduce into a persistent
//! [`Reduced`] — the windowed cell→exchange→hand-back loop performs no
//! steady-state heap allocation (rust/tests/alloc_free.rs).
//!
//! Learners are simulated in-process (DESIGN.md §Substitutions): the
//! semantics (who computes what on which data and which weights, what
//! crosses the wire) are exactly the distributed ones; the fabric charges
//! every packet its real encoded byte size.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::checkpoint::Checkpoint;
use super::churn;
use super::eval::test_error;
use super::learner::{cell_ring_for_plan, BucketCell, Learner};
use super::pool::PoolCtl;
use crate::comm::{
    topology, Bucket, Fabric, LinkModel, MembershipChange, Reduced, ReducePlan, RoundSched,
    Topology,
};
use crate::compress::{self, Packet};
use crate::data::Dataset;
use crate::metrics::{percentile, CompStat, EpochRecord, RunRecord};
use crate::models::{LayerKind, Layout};
use crate::optim::{self, LrSchedule, Optimizer};
use crate::runtime::{Executor, ExecutorFactory};
use crate::util::timer::Stopwatch;

/// Exchange scheduling mode (`TrainConfig::exchange`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Overlap pack/exchange with the remaining backward (per-bucket rounds
    /// pipelined on the topology's ports).
    Streamed,
    /// Classic full barrier between the learner phase and the serialized
    /// bucket rounds.
    Barrier,
}

impl ExchangeMode {
    pub const NAMES: &'static [&'static str] = &["streamed", "barrier"];

    pub fn parse(name: &str) -> Result<ExchangeMode> {
        match name {
            "streamed" => Ok(ExchangeMode::Streamed),
            "barrier" => Ok(ExchangeMode::Barrier),
            other => bail!(
                "unknown exchange mode '{other}' (valid: {})",
                Self::NAMES.join(", ")
            ),
        }
    }
}

/// Upper bound on `--staleness`: the window holds `K + 1` param-vector
/// copies and `K + 1` packet-cell rings per learner, so an absurd `K` is a
/// config typo, not a schedule.
pub const MAX_STALENESS: usize = 16;

/// Fail fast on out-of-range window knobs, with the valid range in the
/// error — the `topology::build` pattern: config JSON, the CLI/harness,
/// and the engine itself all validate through here.
pub fn validate_window(staleness: usize, jitter: f64) -> Result<()> {
    if staleness > MAX_STALENESS {
        bail!(
            "staleness {staleness} out of range (valid: 0 <= K <= {MAX_STALENESS}; \
             0 = synchronous)"
        );
    }
    LinkModel::validate_jitter(jitter)
}

/// Fail fast on an out-of-range `--kernel-threads`, with the valid range in
/// the error — same contract as [`validate_window`]: config JSON, the
/// CLI/harness, and the engine itself all validate through here.
pub fn validate_kernel_threads(kernel_threads: usize) -> Result<()> {
    if kernel_threads > crate::tensor::parallel::MAX_KERNEL_THREADS {
        bail!(
            "kernel-threads {kernel_threads} out of range (valid: 0 <= N <= {}; \
             0 = auto budget threads / active learners)",
            crate::tensor::parallel::MAX_KERNEL_THREADS
        );
    }
    Ok(())
}

/// The intra-GEMM core budget for a fleet of `active_learners` live
/// learners: `cfg.kernel_threads` when pinned (> 0), else the auto rule
/// `max(1, total_thread_budget / active_learners)` — the run's total thread
/// budget (`cfg.threads`, or every hardware thread when 0) split evenly
/// over the live learners so intra-kernel parallelism never oversubscribes
/// the across-learner pool. Re-derived at every membership epoch; because
/// the parallel GEMM is bit-identical at any thread count, the budget only
/// ever changes speed.
pub fn kernel_thread_budget(cfg: &TrainConfig, active_learners: usize) -> usize {
    if cfg.kernel_threads > 0 {
        return cfg.kernel_threads;
    }
    let total = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.threads
    };
    crate::tensor::parallel::derive_budget(total, active_learners)
}

/// Everything that defines one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub run_name: String,
    pub model_name: String,
    /// Compute backend the run was wired with: "native" (hermetic
    /// layer-graph executors), "pjrt" (AOT artifacts), or "auto" (resolve
    /// at workload build time). Informational to the engine itself — the
    /// harness resolves it before the engine runs.
    pub backend: String,
    pub n_learners: usize,
    pub batch_per_learner: usize,
    pub epochs: usize,
    /// Optimizer steps per epoch; 0 = train_len / (batch * learners).
    pub steps_per_epoch: usize,
    pub lr: LrSchedule,
    pub optimizer: String,
    pub momentum: f32,
    pub compression: compress::Config,
    /// Exchange topology: "ring", "ps", "ps:<S>" (S shard servers),
    /// "hier:<G>" (racks of G feeding a root). Identical results for every
    /// choice; only bytes-per-link and the simulated timeline differ.
    pub topology: String,
    pub link: LinkModel,
    pub seed: u64,
    /// Abort (mark diverged) when train loss exceeds this or goes non-finite.
    pub divergence_loss: f64,
    /// Callback cadence for residue stats (every epoch end).
    pub track_residue: bool,
    /// Global-norm clip applied to the mean gradient before the central
    /// update (0 = off). Applied *after* exchange so it never interacts with
    /// the compression path.
    pub clip_norm: f32,
    /// Worker threads for the per-learner phase: 0 = auto (one per hardware
    /// thread, capped at n_learners), 1 = sequential. Results are
    /// bit-identical for every value (see module docs).
    pub threads: usize,
    /// Exchange scheduling: "streamed" (overlap per-bucket pack/exchange
    /// with backward, the default) or "barrier" (join all learners, then
    /// the same bucket rounds serialized). Bit-identical results either way
    /// (see module docs).
    pub exchange: String,
    /// Reduce-plan coalescing threshold in dense wire bytes: consecutive
    /// layers below it share one bucket message. 0 = auto (the link's
    /// latency·bandwidth product — [`ReducePlan::auto_threshold`]);
    /// 1 = one message per layer (the pre-plan wire shape). Affects only
    /// message granularity, never results.
    pub bucket_bytes: usize,
    /// Bounded-staleness window `K` (`--staleness`): learners may run up to
    /// `K` steps ahead of the applied-update frontier, computing step `t`'s
    /// gradients at `θ_{max(0, t−K)}`. 0 (the default) is the classic
    /// synchronous engine, bit-identical to the pre-window behavior.
    /// Results at a fixed `K` are deterministic across thread counts,
    /// exchange modes, topologies, and jitter settings (see module docs).
    pub staleness: usize,
    /// Scripted membership schedule (`--churn "fail@120:2,join@300:1"`;
    /// empty = static fleet). Events fire at the step boundary **before**
    /// the named global step, after the engine drains the staleness window
    /// to the frontier: `fail` drops learners and loses their residual
    /// state, `leave` hands residual + optimizer momentum state to the
    /// survivors through a v2 checkpoint, `join` adds cold learners. Same
    /// seed + schedule ⇒ bit-identical results at every thread count and
    /// exchange mode (see [`super::churn`]).
    pub churn: String,
    /// Mean steps between random single-learner failures (`--mtbf`; 0 =
    /// off). Draws are seeded like `--jitter` and materialized into the
    /// membership schedule before the run starts, so an MTBF run is exactly
    /// as reproducible as a scripted one.
    pub mtbf: u64,
    /// Intra-GEMM kernel threads per learner (`--kernel-threads`): 0 = auto
    /// budget `max(1, threads / active_learners)`, re-derived at membership
    /// epochs as the elastic fleet grows or shrinks; N > 0 pins the budget.
    /// Results are bit-identical at every value (see `tensor::gemm`) — the
    /// knob only moves speed.
    pub kernel_threads: usize,
    /// Adaptive control plane (`--controller on|off`, default "off"): with
    /// it on, a deterministic feedback controller re-tunes the staleness
    /// window, the bucket-coalescing threshold, and the per-layer AdaComp
    /// L_T at every epoch boundary from that epoch's deterministic
    /// measurements (see [`super::control`]). "off" is bit-identical to an
    /// engine without the controller; "on" is itself bit-deterministic
    /// across thread counts and exchange modes (the decisions consume only
    /// deterministic signals).
    pub controller: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            run_name: "run".into(),
            model_name: "model".into(),
            backend: "auto".into(),
            n_learners: 1,
            batch_per_learner: 32,
            epochs: 5,
            steps_per_epoch: 0,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd".into(),
            momentum: 0.9,
            compression: compress::Config::default(),
            topology: "ring".into(),
            link: LinkModel::default(),
            seed: 42,
            divergence_loss: 1e4,
            track_residue: true,
            clip_norm: 0.0,
            threads: 0,
            exchange: "streamed".into(),
            bucket_bytes: 0,
            staleness: 0,
            churn: String::new(),
            mtbf: 0,
            kernel_threads: 0,
            controller: "off".into(),
        }
    }
}

/// Observer hook for figure harnesses that need per-epoch internals:
/// `hook(epoch, learner0_compressor, learner0_last_dw)` — enough for the
/// Fig 5 percentile curves and Fig 6 residual histograms.
pub type EpochHook<'a> = dyn FnMut(usize, &dyn compress::Compressor, &[f32]) + 'a;

pub struct Engine<'a> {
    pub factory: &'a dyn ExecutorFactory,
    pub dataset: &'a dyn Dataset,
    pub layout: &'a Layout,
}

/// The learner-count-dependent half of the run state: everything a
/// membership epoch (churn event) rebuilds. Lives behind
/// [`Shared::fleet`]'s `RwLock`: workers and the engine's step loop take
/// read guards; the engine takes the write guard only at a membership
/// boundary, when the staleness window has been drained to the frontier
/// and every worker is parked in `wait_runnable` (the pool's open limit is
/// capped at the next event step, so no worker can be mid-step).
struct Fleet {
    /// The fleet's reduce plan: bucket coalescing + port mapping. Rebuilt
    /// (with `pub_ns` and the cell rings, which it sizes) at membership
    /// epochs and controller re-tunes; the bucket count may change with
    /// the live threshold and port count, up to `Shared::bucket_stride`.
    plan: ReducePlan,
    learners: Vec<Mutex<Learner>>,
    /// Per-(learner, step-slot, bucket) packet hand-off cells:
    /// `cells[l][slot][bucket]`, slot = step % window.
    cells: Vec<Vec<Vec<BucketCell>>>,
    /// `pub_ns[(l * window + slot) * n_buckets + b]`: nanoseconds into
    /// learner `l`'s own step when it published bucket `b` (min 1) — the
    /// per-learner ready-time offsets the simulated timeline scales by the
    /// jitter model. Written before the `ready` bump (Release) publishes it.
    pub_ns: Vec<AtomicU64>,
    /// `compute_ns[l * window + slot]`: learner `l`'s full measured step
    /// span (min 1). Written before the `finished` bump publishes it.
    compute_ns: Vec<AtomicU64>,
    /// `loss_bits[l * window + slot]`: the step's loss (f32 bits), written
    /// before the `finished` bump.
    loss_bits: Vec<AtomicU32>,
}

/// Run-scoped state shared between the engine thread and the pool workers.
/// Everything here is either lock-protected or atomically published; the
/// staleness window guarantees a step slot is never touched by a worker
/// while the engine still owns it (and vice versa).
struct Shared<'a> {
    dataset: &'a dyn Dataset,
    layout: &'a Layout,
    /// The learner-count-dependent state, rebuilt at membership epochs.
    fleet: RwLock<Fleet>,
    /// Param-version ring: slot `v % window` holds `θ_v` while any
    /// in-flight step may still read it. Workers hold a read lock for the
    /// duration of a learner step; the engine takes the write lock only
    /// for the slot being overwritten (dead by the window invariant).
    /// Deliberately *outside* the fleet — central weights survive churn.
    hist: Vec<RwLock<Vec<f32>>>,
    /// Allocated window size (number of step slots / param versions). With
    /// the controller off this is exactly `K + 1`; with it on the ring is
    /// allocated once at [`control::staleness_cap`]` + 1` so the live K can
    /// widen without reallocating history.
    window: usize,
    /// The *live* staleness bound `K` (step `t` reads `θ_{max(0, t−K)}`).
    /// Re-tuned by the adaptive controller at drained epoch boundaries
    /// (every worker parked at the epoch frontier); always ≤ `window − 1`,
    /// so the param-version ring invariant holds at any live value. The
    /// pool-gate mutex ([`PoolCtl::set_staleness`]) orders the store before
    /// any worker can start a step under the new bound, so Relaxed loads
    /// suffice.
    staleness: AtomicUsize,
    /// Row stride of `ready`: an upper bound on the bucket count of any
    /// plan the run can rebuild (one bucket per layer — coalescing only
    /// merges). The *live* bucket count is `fleet.plan.num_buckets()`,
    /// which controller re-tunes may change between epochs.
    bucket_stride: usize,
    /// `ready[slot * bucket_stride + b]`: learners that completed bucket
    /// `b` of the slot's in-flight step.
    ready: Vec<AtomicUsize>,
    /// `finished[slot]`: learners fully done with the slot's step (loss and
    /// compute span published).
    finished: Vec<AtomicUsize>,
    /// Wakes the engine's bucket scan when a bucket completes, a learner
    /// finishes a step, or a worker fails.
    event: ReadyEvent,
}

/// A sequence-counted wakeup for the engine's bucket scan: bumped by
/// workers on every bucket completion and step check-in, waited on (with a
/// short timeout as a missed-wakeup backstop) by the engine when a scan
/// pass finds nothing ready — the engine blocks instead of busy-spinning a
/// core away from the workers it is waiting on.
#[derive(Default)]
struct ReadyEvent {
    seq: Mutex<u64>,
    cv: Condvar,
}

impl ReadyEvent {
    fn bump(&self) {
        let mut s = self.seq.lock().unwrap();
        *s += 1;
        self.cv.notify_all();
    }

    fn current(&self) -> u64 {
        *self.seq.lock().unwrap()
    }

    /// Block until the sequence advances past `last` or a short timeout
    /// elapses; returns the sequence seen.
    fn wait_past(&self, last: u64) -> u64 {
        let mut s = self.seq.lock().unwrap();
        while *s == last {
            let (guard, timeout) = self
                .cv
                .wait_timeout(s, std::time::Duration::from_micros(500))
                .unwrap();
            s = guard;
            if timeout.timed_out() {
                break;
            }
        }
        *s
    }
}

/// Pool-worker body: advance this worker's learner chunk through the step
/// sequence, parking only when the next step would outrun the staleness
/// window, the epoch frontier, or the next membership event. Both exchange
/// modes run the same streamed learner phase — the mode only changes when
/// the engine consumes the buckets.
///
/// The chunk is recomputed from the **current** fleet size every step
/// (worker `widx` of `nworkers` owns an equal contiguous slice), so workers
/// stay balanced across a shrinking or growing pool; a worker whose slice
/// is empty after a shrink simply free-runs to the open limit and parks.
/// The fleet read guard is held only inside the step body — never across a
/// park — so the engine's write lock at a membership boundary cannot
/// deadlock against a parked worker.
fn worker_loop(shared: &Shared<'_>, ctl: &PoolCtl, widx: usize, nworkers: usize) {
    let mut step = 0u64;
    while ctl.wait_runnable(step) {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> Result<()> {
            let fleet = shared.fleet.read().unwrap();
            let n = fleet.learners.len();
            let chunk = n.div_ceil(nworkers);
            let lo = (widx * chunk).min(n);
            let hi = (lo + chunk).min(n);
            for i in lo..hi {
                shared.run_learner_step(&fleet, i, step as usize, None)?;
            }
            Ok(())
        }));
        match res {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                ctl.fail(format!("{e:#}"));
                shared.event.bump();
                return;
            }
            Err(p) => {
                ctl.fail(panic_message(p.as_ref()));
                shared.event.bump();
                return;
            }
        }
        step += 1;
    }
}

impl Shared<'_> {
    /// Param version step `t` reads: `θ_{max(0, t−K)}` — the freshest
    /// version the window deterministically guarantees to exist, at the
    /// *live* staleness bound.
    fn params_version(&self, step: usize) -> usize {
        step.saturating_sub(self.staleness.load(Ordering::Relaxed))
    }

    /// One full learner step for learner `i` at global step `step`: read
    /// the window's param version, run the streamed phase into the step's
    /// slot cells, publish per-bucket ready offsets and the step's
    /// loss/compute span. `exec` = the engine's shared local executor on
    /// the sequential path, `None` = the learner's own (worker path).
    /// Callers pass the fleet read guard they already hold.
    fn run_learner_step(
        &self,
        fleet: &Fleet,
        i: usize,
        step: usize,
        exec: Option<&mut dyn Executor>,
    ) -> Result<()> {
        let w = self.window;
        let slot = step % w;
        let params = self.hist[self.params_version(step) % w].read().unwrap();
        let mut l = fleet.learners[i].lock().unwrap();
        let t0 = Instant::now();
        let mut on_bucket = |bi: usize| self.bucket_packed(fleet, i, slot, bi, &t0);
        match exec {
            Some(e) => l.step_streamed_with(
                e,
                &params,
                self.dataset,
                self.layout,
                &fleet.plan,
                &fleet.cells[i][slot],
                &mut on_bucket,
            )?,
            None => l.step_streamed(
                &params,
                self.dataset,
                self.layout,
                &fleet.plan,
                &fleet.cells[i][slot],
                &mut on_bucket,
            )?,
        }
        let span = (t0.elapsed().as_nanos() as u64).max(1);
        let loss = l.loss;
        fleet.compute_ns[i * w + slot].store(span, Ordering::Relaxed);
        fleet.loss_bits[i * w + slot].store(loss.to_bits(), Ordering::Relaxed);
        drop(l);
        drop(params);
        // the Release bump publishes the stores above to the engine's
        // Acquire load of `finished`
        self.finished[slot].fetch_add(1, Ordering::Release);
        self.event.bump();
        Ok(())
    }

    /// Bucket-ready notification (both sequential and pooled): record this
    /// learner's publish offset, bump the bucket's counter; the completing
    /// learner wakes the engine.
    fn bucket_packed(&self, fleet: &Fleet, l: usize, slot: usize, bi: usize, t0: &Instant) {
        let ns = (t0.elapsed().as_nanos() as u64).max(1);
        let nb = fleet.plan.num_buckets();
        fleet.pub_ns[(l * self.window + slot) * nb + bi].store(ns, Ordering::Relaxed);
        let c = self.ready[slot * self.bucket_stride + bi].fetch_add(1, Ordering::Release) + 1;
        if c == fleet.learners.len() {
            self.event.bump();
        }
    }

    /// Simulated time bucket `bi` of the slot's step became exchangeable:
    /// max over learners of `start_l + publish_offset_l · jitter_mult_l`.
    /// Only valid once the bucket's ready counter reached `n` (the Acquire
    /// load of that counter publishes every learner's offset store).
    fn bucket_ready_s(
        &self,
        fleet: &Fleet,
        slot: usize,
        bi: usize,
        start: &[f64],
        jmult: &[f64],
    ) -> f64 {
        let mut r = 0.0f64;
        let nb = fleet.plan.num_buckets();
        for (l, (&s, &jm)) in start.iter().zip(jmult.iter()).enumerate() {
            let ns = fleet.pub_ns[(l * self.window + slot) * nb + bi].load(Ordering::Relaxed);
            r = r.max(s + ns as f64 * 1e-9 * jm);
        }
        r
    }

    /// Learner `l`'s simulated compute span for the slot's step (measured
    /// wall span of its own fwd/bwd+pack, scaled by the jitter model).
    /// Only valid once `finished[slot]` reached `n`.
    fn dur_s(&self, fleet: &Fleet, slot: usize, l: usize, jm: f64) -> f64 {
        fleet.compute_ns[l * self.window + slot].load(Ordering::Relaxed) as f64 * 1e-9 * jm
    }
}

/// Shuts the pool down on drop — including during an engine-thread unwind
/// (a panicking hook, a bug), where parked workers would otherwise deadlock
/// the `thread::scope`'s implicit join.
struct PoolShutdown<'a>(&'a PoolCtl);

impl Drop for PoolShutdown<'_> {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

impl<'a> Engine<'a> {
    pub fn new(
        factory: &'a dyn ExecutorFactory,
        dataset: &'a dyn Dataset,
        layout: &'a Layout,
    ) -> Engine<'a> {
        Engine {
            factory,
            dataset,
            layout,
        }
    }

    /// Resolve the worker-thread count for a run: honor `cfg.threads`, cap at
    /// n_learners, and force 1 when the backend cannot cross threads.
    fn resolve_threads(&self, cfg: &TrainConfig) -> usize {
        if !self.factory.parallel() || cfg.n_learners <= 1 {
            return 1;
        }
        let want = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        want.clamp(1, cfg.n_learners)
    }

    pub fn run(&mut self, cfg: &TrainConfig, init_params: &[f32]) -> Result<RunRecord> {
        self.run_with_hook(cfg, init_params, None)
    }

    pub fn run_with_hook(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<RunRecord> {
        Ok(self.run_full(cfg, init_params, hook)?.0)
    }

    /// Full training loop; `hook(epoch, learner0_compressor, last_dw)` runs
    /// at each epoch end before evaluation. Returns the record and the
    /// final trained parameters (for checkpointing).
    pub fn run_full(
        &mut self,
        cfg: &TrainConfig,
        init_params: &[f32],
        hook: Option<&mut EpochHook<'_>>,
    ) -> Result<(RunRecord, Vec<f32>)> {
        assert!(cfg.n_learners >= 1);
        let layout = self.layout;
        let dataset = self.dataset;
        let factory = self.factory;

        // Validate every by-name/by-range knob up front so a typo'd config
        // fails with the valid list, not a mid-run panic.
        let mode = ExchangeMode::parse(&cfg.exchange)?;
        validate_window(cfg.staleness, cfg.link.jitter)?;
        validate_kernel_threads(cfg.kernel_threads)?;
        let controller_on = super::control::parse_mode(&cfg.controller)?;
        super::churn::parse(&cfg.churn)?;
        let optimizer = optim::build(&cfg.optimizer, init_params.len(), cfg.momentum)
            .ok_or_else(|| {
                anyhow!(
                    "unknown optimizer '{}' (valid: sgd, adam, rmsprop)",
                    cfg.optimizer
                )
            })?;
        let topo = topology::build(&cfg.topology, cfg.n_learners)?;
        let threads = self.resolve_threads(cfg);
        let parallel = threads > 1;
        // Core budget for intra-GEMM parallelism: set once for the starting
        // fleet, re-derived inside run_loop at every membership epoch.
        crate::tensor::parallel::set_kernel_threads(kernel_thread_budget(cfg, cfg.n_learners));
        // Allocated window: exactly K + 1 with the controller off (the
        // classic ring — bit-identical to an engine without a controller),
        // or the staleness cap's worth of headroom with it on, so the live
        // K can widen mid-run without reallocating param history or cell
        // rings. The window size itself never changes results — only the
        // live K decides which θ version a step reads.
        let window = if controller_on {
            super::control::staleness_cap(cfg.staleness) + 1
        } else {
            cfg.staleness + 1
        };

        // The run's reduce plan: bucket coalescing + port partition, built
        // from the layout (DESIGN.md §Topologies) — and rebuilt at
        // membership epochs and controller re-tunes. The auto threshold is
        // ports-aware so a sharded-PS fabric starts with enough buckets to
        // feed every shard port.
        let threshold = if cfg.bucket_bytes == 0 {
            ReducePlan::auto_threshold_for(&cfg.link, topo.ports())
        } else {
            cfg.bucket_bytes
        };
        let plan = ReducePlan::build(layout, threshold, topo.ports());
        let num_buckets = plan.num_buckets();
        // `ready` row stride: one bucket per layer is the most any rebuilt
        // plan can ever need (coalescing only merges layers).
        let bucket_stride = layout.num_layers();

        let local = factory.build_local()?;
        let learners = (0..cfg.n_learners)
            .map(|id| -> Result<Mutex<Learner>> {
                let exec = if parallel {
                    Some(factory.build_worker()?)
                } else {
                    None
                };
                Ok(Mutex::new(Learner::new(
                    id,
                    cfg.n_learners,
                    dataset,
                    layout,
                    &cfg.compression,
                    cfg.batch_per_learner,
                    cfg.seed,
                    exec,
                )))
            })
            .collect::<Result<Vec<_>>>()?;

        let cells: Vec<Vec<Vec<BucketCell>>> = (0..cfg.n_learners)
            .map(|_| cell_ring_for_plan(&plan, window))
            .collect();
        let shared = Shared {
            dataset,
            layout,
            fleet: RwLock::new(Fleet {
                plan,
                learners,
                cells,
                pub_ns: (0..cfg.n_learners * window * num_buckets)
                    .map(|_| AtomicU64::new(0))
                    .collect(),
                compute_ns: (0..cfg.n_learners * window).map(|_| AtomicU64::new(0)).collect(),
                loss_bits: (0..cfg.n_learners * window).map(|_| AtomicU32::new(0)).collect(),
            }),
            hist: (0..window).map(|_| RwLock::new(init_params.to_vec())).collect(),
            window,
            staleness: AtomicUsize::new(cfg.staleness),
            bucket_stride,
            ready: (0..window * bucket_stride).map(|_| AtomicUsize::new(0)).collect(),
            finished: (0..window).map(|_| AtomicUsize::new(0)).collect(),
            event: ReadyEvent::default(),
        };

        let (record, final_slot) = if parallel {
            let ctl = PoolCtl::new(cfg.staleness);
            std::thread::scope(|scope| {
                for widx in 0..threads {
                    let (sh, c) = (&shared, &ctl);
                    scope.spawn(move || worker_loop(sh, c, widx, threads));
                }
                // Shut the pool down however run_loop exits (ok, error, or
                // panic) — parked workers would otherwise deadlock the
                // scope's implicit join.
                let _shutdown = PoolShutdown(&ctl);
                run_loop(
                    cfg,
                    layout,
                    dataset,
                    factory,
                    local,
                    &shared,
                    Some(&ctl),
                    mode,
                    topo,
                    optimizer,
                    hook,
                )
            })?
        } else {
            run_loop(
                cfg, layout, dataset, factory, local, &shared, None, mode, topo, optimizer,
                hook,
            )?
        };

        let mut hist = shared.hist;
        let params = hist.swap_remove(final_slot).into_inner().unwrap();
        Ok((record, params))
    }
}

/// Fold one packet into the per-kind compression stats. Single definition
/// so the normal exchange path and the diverged-barrier path (which counts
/// packed-but-unsent packets) can never drift apart.
fn tally_packet(
    layout: &Layout,
    p: &Packet,
    comp_conv: &mut CompStat,
    comp_fc: &mut CompStat,
    comp_all: &mut CompStat,
) {
    match layout.layers[p.layer].kind {
        LayerKind::Conv => comp_conv.add(p),
        _ => comp_fc.add(p),
    }
    comp_all.add(p);
}

/// Exchange one ready bucket over the **wire**: for every learner (in
/// learner-id order — the determinism contract) fold the bucket's packed
/// packets into the compression stats, then decode the learner's serialized
/// bucket frame (built at publish time; `learner::publish`) into `gather`
/// through the pooled wire buffers, and reduce the *decoded* packets over
/// the topology. Each decoded packet's `wire_bytes` is its measured
/// sub-message length, so the fabric round is charged exactly the frame's
/// real byte count — not the analytic estimate. The decoded values are
/// bit-identical to the packed ones (wire.rs classification contract), so
/// reduction results don't change; the originals stay in their slots for
/// the learner to recycle next step. Allocation-free in steady state
/// (`gather` reuses its per-learner vecs, `wire_pool` the idx/val buffers).
#[allow(clippy::too_many_arguments)]
fn exchange_one_bucket(
    fleet: &Fleet,
    slot: usize,
    layout: &Layout,
    layer_lens: &[usize],
    bucket: &Bucket,
    gather: &mut [Vec<Packet>],
    wire_pool: &mut compress::BufPool,
    sched: RoundSched,
    topo: &mut dyn Topology,
    fabric: &mut Fabric,
    reduced: &mut Reduced,
    comp_conv: &mut CompStat,
    comp_fc: &mut CompStat,
    comp_all: &mut CompStat,
    sig: &mut super::control::EpochSignals,
) -> crate::comm::RoundCost {
    let bi = bucket.id;
    for (l, ring) in fleet.cells.iter().enumerate() {
        let cell = ring[slot][bi].lock();
        for s in cell.slots.iter() {
            let p = s.as_ref().expect("ready bucket is missing a packet");
            tally_packet(layout, p, comp_conv, comp_fc, comp_all);
        }
        let fbi = compress::wire::decode_bucket_frame_into(&cell.frame, wire_pool, &mut gather[l])
            .expect("engine-encoded bucket frame must decode");
        assert_eq!(fbi, bi, "bucket frame id mismatch");
        // controller signal: each decoded packet's measured sub-message
        // bytes onto its layer (deterministic — the serialized frame is
        // bit-identical across thread counts and exchange modes)
        for p in gather[l].iter() {
            sig.note_packet(p.layer, p.wire_bytes);
        }
    }
    let cost = topo.exchange_bucket_into(bucket, &*gather, layer_lens, sched, fabric, reduced);
    for g in gather.iter_mut() {
        for p in g.drain(..) {
            wire_pool.put(p.idx, p.val);
        }
    }
    cost
}

/// Apply one membership event under the fleet write lock (all workers are
/// parked at the pool's open limit; the staleness window is drained).
/// Returns the rebuilt topology plus the event's timeline entry (the
/// caller fills in `drain_stall_s`), or `None` when the event had to be
/// skipped. With `rederive_auto` (auto `--bucket-bytes 0` and no
/// controller owning the knob) the coalescing threshold is re-derived from
/// the *post-event* topology's port count — a fleet that degraded from
/// `ps:4` to `ps` coarsens its plan to match, and a re-grown one splits
/// again; the threshold actually used is reported in the returned
/// [`MembershipChange`] and becomes the caller's live value.
#[allow(clippy::too_many_arguments)]
fn apply_membership_event(
    cfg: &TrainConfig,
    layout: &Layout,
    shared: &Shared<'_>,
    factory: &dyn ExecutorFactory,
    parallel: bool,
    threshold: usize,
    rederive_auto: bool,
    epoch: usize,
    ev: churn::Event,
    optimizer: &mut dyn Optimizer,
) -> Result<Option<(Box<dyn Topology>, MembershipChange)>> {
    use churn::EventKind;
    let mut fleet = shared.fleet.write().unwrap();
    let n = fleet.learners.len();
    let t0 = Instant::now();
    let (mut lost_l1, mut handover_l1) = (0.0f64, 0.0f64);
    let mut count = ev.count;
    match ev.kind {
        EventKind::Fail | EventKind::Leave => {
            if count >= n {
                if n == 1 {
                    eprintln!(
                        "churn: skipping {}@{}:{} — would leave no learners",
                        ev.kind.name(),
                        ev.step,
                        ev.count
                    );
                    return Ok(None);
                }
                eprintln!(
                    "churn: clamping {}@{}:{} to {} — would leave no learners",
                    ev.kind.name(),
                    ev.step,
                    ev.count,
                    n - 1
                );
                count = n - 1;
            }
            let departing = fleet.learners.split_off(n - count);
            if ev.kind == EventKind::Fail {
                // a crash loses the accumulated residual gradient mass —
                // account it so fail and leave are distinguishable
                for dm in &departing {
                    let d = dm.lock().unwrap();
                    for li in 0..layout.num_layers() {
                        lost_l1 += d
                            .compressor
                            .residue(li)
                            .iter()
                            .map(|x| x.abs() as f64)
                            .sum::<f64>();
                    }
                }
            } else {
                // graceful leave: departing residual + optimizer momentum
                // cross the same v2 checkpoint format an external
                // coordinator would use, then fold into the survivors
                // (round-robin) so no gradient mass is lost
                let mut ck =
                    Checkpoint::new(cfg.model_name.clone(), epoch as u32, Vec::new());
                for dm in &departing {
                    let d = dm.lock().unwrap();
                    let mut flat = Vec::with_capacity(layout.total);
                    // same per-layer summation order as the fail branch, so
                    // a matched fail/leave pair accounts the identical mass
                    for li in 0..layout.num_layers() {
                        let r = d.compressor.residue(li);
                        handover_l1 += r.iter().map(|x| x.abs() as f64).sum::<f64>();
                        flat.extend_from_slice(r);
                    }
                    ck.residues.push(flat);
                }
                ck.momentum = optimizer.state();
                let ck = Checkpoint::from_bytes(&ck.to_bytes())?;
                let survivors = fleet.learners.len();
                for (j, flat) in ck.residues.iter().enumerate() {
                    let mut s = fleet.learners[j % survivors].lock().unwrap();
                    for li in 0..layout.num_layers() {
                        if let Some(dst) = s.compressor.residue_mut(li) {
                            for (d, &x) in dst.iter_mut().zip(layout.view(li, flat)) {
                                *d += x;
                            }
                        }
                    }
                }
                if !ck.momentum.is_empty() {
                    optimizer.load_state(&ck.momentum);
                }
            }
            drop(departing);
        }
        EventKind::Join => {
            // joiners start cold: fresh residue and a fresh RNG stream,
            // decorrelated from any learner that ever held this id by
            // mixing the birth step into the seed
            let seed = cfg.seed ^ (ev.step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            for j in 0..count {
                let exec = if parallel {
                    Some(factory.build_worker()?)
                } else {
                    None
                };
                fleet.learners.push(Mutex::new(Learner::new(
                    n + j,
                    n + count,
                    shared.dataset,
                    layout,
                    &cfg.compression,
                    cfg.batch_per_learner,
                    seed,
                    exec,
                )));
            }
        }
    }

    // reindex: contiguous ids + data shards over the new fleet size
    let new_n = fleet.learners.len();
    for (i, lm) in fleet.learners.iter_mut().enumerate() {
        let l = lm.get_mut().unwrap();
        l.id = i;
        l.shard.learner = i;
        l.shard.n_learners = new_n;
    }

    // rebuild topology (graceful degradation — never abort mid-run on a
    // bound that the *requested* spec no longer satisfies; re-checked from
    // the original spec each event so a re-grown fleet restores it)
    let effective = topology::fallback(&cfg.topology, new_n);
    let degraded = effective != cfg.topology;
    if degraded {
        eprintln!(
            "churn: topology '{}' out of bounds for {new_n} learners at step {}; \
             degrading to '{effective}'",
            cfg.topology, ev.step
        );
    }
    let topo = topology::build(&effective, new_n)?;
    // An auto threshold tracks the *live* port count: a degraded topology
    // (fewer shard ports) coarsens the plan back toward the single-port
    // rule, a re-grown one refines it again. Fixed `--bucket-bytes` and
    // controller-owned thresholds pass through unchanged.
    let threshold = if rederive_auto {
        ReducePlan::auto_threshold_for(&cfg.link, topo.ports())
    } else {
        threshold
    };
    fleet.plan = ReducePlan::build(layout, threshold, topo.ports());
    let window = shared.window;
    let nb = fleet.plan.num_buckets();
    fleet.cells = (0..new_n)
        .map(|_| cell_ring_for_plan(&fleet.plan, window))
        .collect();
    fleet.pub_ns = (0..new_n * window * nb).map(|_| AtomicU64::new(0)).collect();
    fleet.compute_ns = (0..new_n * window).map(|_| AtomicU64::new(0)).collect();
    fleet.loss_bits = (0..new_n * window).map(|_| AtomicU32::new(0)).collect();
    for r in &shared.ready {
        r.store(0, Ordering::Relaxed);
    }
    for f in &shared.finished {
        f.store(0, Ordering::Relaxed);
    }

    Ok(Some((
        topo,
        MembershipChange {
            step: ev.step as u64,
            kind: ev.kind.name().to_string(),
            count,
            n_after: new_n,
            topology: effective,
            degraded,
            rebuild_s: t0.elapsed().as_secs_f64(),
            drain_stall_s: 0.0,
            lost_l1,
            handover_l1,
            threshold_bytes: threshold,
            n_buckets: nb,
        },
    )))
}

/// Engine-side wait for an atomic counter to reach `n`, surfacing worker
/// failures instead of deadlocking on a dead worker.
fn wait_counter(
    shared: &Shared<'_>,
    pool: Option<&PoolCtl>,
    counter: &AtomicUsize,
    n: usize,
) -> Result<()> {
    let mut event_seq = shared.event.current();
    while counter.load(Ordering::Acquire) < n {
        if let Some(ctl) = pool {
            if let Some(e) = ctl.failure() {
                bail!("learner phase failed: {e}");
            }
        }
        event_seq = shared.event.wait_past(event_seq);
    }
    Ok(())
}

/// The training loop proper, shared by all (sequential/pool ×
/// barrier/streamed × topology × staleness) combinations. `pool` carries
/// the window controller when a persistent pool is attached; `None` runs
/// every learner on the engine thread through `local`. Both modes run the
/// same streamed learner phase and the same per-bucket rounds — the mode
/// decides *when* the engine consumes buckets (mid-backward vs after the
/// step join) and how the rounds land on the simulated timeline. Returns
/// the record plus the param-ring slot holding the final weights.
#[allow(clippy::too_many_arguments)]
fn run_loop(
    cfg: &TrainConfig,
    layout: &Layout,
    dataset: &dyn Dataset,
    factory: &dyn ExecutorFactory,
    mut local: Box<dyn Executor>,
    shared: &Shared<'_>,
    pool: Option<&PoolCtl>,
    mode: ExchangeMode,
    mut topo: Box<dyn Topology>,
    mut optimizer: Box<dyn Optimizer>,
    mut hook: Option<&mut EpochHook<'_>>,
) -> Result<(RunRecord, usize)> {
    let mut n = cfg.n_learners;
    let stride = shared.bucket_stride;
    let w = shared.window;
    // The *live* staleness bound: starts at the configured K, re-tuned by
    // the controller at epoch boundaries (always ≤ w − 1, the allocation
    // bound — `control::staleness_cap` with the controller on, K itself
    // with it off).
    let mut k = cfg.staleness;
    let layer_lens = layout.layer_lens();
    let mut inv_learners = 1.0f32 / n as f32;
    let streamed = mode == ExchangeMode::Streamed;
    let mut fabric = Fabric::new(cfg.link);

    let steps_per_epoch = if cfg.steps_per_epoch > 0 {
        cfg.steps_per_epoch
    } else {
        (dataset.train_len() / (cfg.batch_per_learner * n)).max(1)
    };
    let total_steps = steps_per_epoch * cfg.epochs;

    // The run's full membership schedule, resolved before the first step
    // (scripted --churn events merged with the precomputed --mtbf draws) so
    // the pool's open limits — and therefore the window-drain points — are
    // identical at every thread count and exchange mode.
    // The *live* coalescing threshold: seeded like run_full's (ports-aware
    // auto rule when --bucket-bytes 0), then owned by the controller when
    // it is on — controller-tuned values survive membership rebuilds.
    let mut threshold = if cfg.bucket_bytes == 0 {
        ReducePlan::auto_threshold_for(&cfg.link, topo.ports())
    } else {
        cfg.bucket_bytes
    };
    // Adaptive control plane (--controller on): deterministic epoch-
    // boundary re-tuning of K / threshold / per-layer L_T from the epoch's
    // deterministic signals (see super::control). Signals are folded
    // unconditionally (a handful of adds per step); decisions only happen
    // with the controller on.
    let controller_on = super::control::parse_mode(&cfg.controller)?;
    let mut knobs = super::control::Knobs {
        staleness: k,
        bucket_bytes: threshold,
        lts: if cfg.compression.kind.has_lt()
            || cfg.compression.kind_conv.is_some_and(|kc| kc.has_lt())
        {
            layout.layers.iter().map(|l| cfg.compression.lt_for(l.kind).max(1)).collect()
        } else {
            Vec::new()
        },
    };
    let ctrl = controller_on.then(|| {
        super::control::Controller::new(
            layout,
            &knobs,
            super::control::staleness_cap(cfg.staleness),
            &cfg.link,
        )
    });
    let mut sig = super::control::EpochSignals::new(layout.num_layers());
    let events: Vec<churn::Event> =
        churn::schedule(&cfg.churn, cfg.mtbf, cfg.seed, total_steps)?
            .into_iter()
            .filter(|e| {
                if e.step >= total_steps {
                    eprintln!(
                        "churn: ignoring {}@{}:{} beyond the run's {total_steps} steps",
                        e.kind.name(),
                        e.step,
                        e.count
                    );
                    return false;
                }
                true
            })
            .collect();
    let mut next_event = 0usize;
    // Worker frontier cap: a worker may never enter a membership-event
    // step — by the time the engine reaches the event, every update before
    // it has been applied and every worker is parked (drained window).
    let open_limit = |next_event: usize, epoch_limit: usize| -> u64 {
        let ev = events.get(next_event).map(|e| e.step).unwrap_or(usize::MAX);
        epoch_limit.min(ev) as u64
    };

    let mut record = RunRecord {
        name: cfg.run_name.clone(),
        model: cfg.model_name.clone(),
        scheme: cfg.compression.kind.name().to_string(),
        learners: n,
        batch_per_learner: cfg.batch_per_learner,
        optimizer: cfg.optimizer.clone(),
        epochs: Vec::new(),
        diverged: false,
        fabric: Default::default(),
    };

    let mut grad_mean = vec![0.0f32; layout.total];
    let mut reduced = Reduced::new(&layer_lens);
    // The no-compression baseline: one coalesced whole-model dense round,
    // identical across topologies, exchange modes, bucket thresholds AND
    // staleness windows — `projected_speedup()` always measures against the
    // same synchronous "before" system. Recomputed only when churn changes
    // the learner count.
    let mut dense_round_s;
    // Engine scratch, reused every step (no allocation in the steady
    // state): per-learner bucket gathers, per-bucket done flags, and the
    // continuous per-port timeline. Resized at membership epochs.
    let mut gather: Vec<Vec<Packet>>;
    {
        let fleet = shared.fleet.read().unwrap();
        dense_round_s = fleet.plan.dense_round_s(&layer_lens, n, &cfg.link);
        let cap = fleet.plan.max_bucket_layers();
        gather = (0..n).map(|_| Vec::with_capacity(cap)).collect();
    }
    // idx/val buffers for decoding bucket frames on the exchange path —
    // grows to (learners x max bucket layers) pairs, then never allocates
    let mut wire_pool = compress::BufPool::default();
    // Sized to the stride bound so per-step resizes to the live plan's
    // bucket count never allocate.
    let mut done_flags = Vec::with_capacity(stride);
    let mut port_end = vec![0.0f64; topo.ports()];
    // Windowed-timeline state: per-learner availability/start times and
    // jitter draws for the step in flight, plus the ring of applied-update
    // frontier times (apply_ring[s % ring_cap] = when update s landed;
    // steps t−K−1..t are alive at once, and ring_cap = w + 1 ≥ K + 2 at
    // any live K the controller can set — with the controller off it is
    // exactly the classic K + 2).
    let ring_cap = w + 1;
    let mut avail = vec![0.0f64; n];
    let mut start = vec![0.0f64; n];
    let mut jmult = vec![1.0f64; n];
    let mut stalls = vec![0.0f64; n];
    let mut apply_ring = vec![0.0f64; ring_cap];
    let mut t = 0usize; // global step index (continuous across epochs)
    let mut cur_slot = 0usize; // param-ring slot of the newest version

    'epochs: for epoch in 0..cfg.epochs {
        let sw = Stopwatch::start();
        let lr = cfg.lr.at(epoch);
        let mut loss_sum = 0.0f64;
        let mut nloss = 0usize;
        let mut comp_conv = CompStat::default();
        let mut comp_fc = CompStat::default();
        let mut comp_all = CompStat::default();

        // Open this epoch's steps to the workers, capped at the next
        // membership event. The frontier never crosses an epoch boundary,
        // so evaluation and the epoch hook read quiescent learner state
        // even at K > 0.
        let epoch_limit = t + steps_per_epoch;
        if let Some(ctl) = pool {
            ctl.open(open_limit(next_event, epoch_limit));
        }

        for _step in 0..steps_per_epoch {
            // --- membership boundary (see DESIGN.md §Elastic fleet) ------
            // The open limit was capped at this step, so every worker is
            // parked in `wait_runnable` and every update < t has been
            // applied: the staleness window is drained to the frontier by
            // construction, and the fleet write lock is uncontended.
            while next_event < events.len() && events[next_event].step == t {
                let ev = events[next_event];
                next_event += 1;
                // drain accounting: every learner syncs to the frontier
                let sync_s = avail.iter().fold(
                    if t > 0 { apply_ring[(t - 1) % ring_cap] } else { 0.0 },
                    |a, &b| a.max(b),
                );
                let drain_stall: f64 = avail.iter().map(|&a| sync_s - a).sum();
                if let Some((new_topo, mut change)) = apply_membership_event(
                    cfg,
                    layout,
                    shared,
                    factory,
                    pool.is_some(),
                    threshold,
                    // an auto threshold is re-derived for the post-event
                    // topology unless the controller owns the knob
                    cfg.bucket_bytes == 0 && !controller_on,
                    epoch,
                    ev,
                    optimizer.as_mut(),
                )? {
                    topo = new_topo;
                    n = change.n_after;
                    threshold = change.threshold_bytes;
                    inv_learners = 1.0f32 / n as f32;
                    // Re-derive the intra-GEMM core budget for the new fleet
                    // size: helpers freed by a shrink (or claimed by a
                    // growth) rebalance across the survivors. Budget changes
                    // never change results (bit-identical at any count).
                    crate::tensor::parallel::set_kernel_threads(kernel_thread_budget(cfg, n));
                    change.drain_stall_s = drain_stall;
                    let resume = sync_s + change.rebuild_s;
                    {
                        let fleet = shared.fleet.read().unwrap();
                        dense_round_s = fleet.plan.dense_round_s(&layer_lens, n, &cfg.link);
                        let cap = fleet.plan.max_bucket_layers();
                        gather.resize_with(n, || Vec::with_capacity(cap));
                    }
                    // the rebuilt fleet resumes on a fresh, synchronized
                    // timeline: ports and learners all become free at the
                    // post-rebuild instant
                    port_end.clear();
                    port_end.resize(topo.ports(), resume);
                    avail.clear();
                    avail.resize(n, resume);
                    start.resize(n, 0.0);
                    jmult.resize(n, 1.0);
                    stalls.resize(n, 0.0);
                    fabric.record_membership(change);
                    // joiners were built from the *config's* compression —
                    // re-push the controller's live per-layer L_T so the
                    // whole fleet packs with one operating point (workers
                    // are still parked; the pool reopens below)
                    if controller_on && !knobs.lts.is_empty() {
                        let fleet = shared.fleet.read().unwrap();
                        push_lts(&fleet, &knobs.lts);
                    }
                }
                if let Some(ctl) = pool {
                    ctl.open(open_limit(next_event, epoch_limit));
                }
            }

            let slot = t % w;
            let fleet = shared.fleet.read().unwrap();
            // live bucket count: controller re-tunes (and auto-threshold
            // re-derivations at membership epochs) may have rebuilt the plan
            let nb = fleet.plan.num_buckets();

            // Sequential fallback: drive every learner through the shared
            // local executor for this step (same per-learner order of
            // operations as the pooled path — bit-identical results).
            if pool.is_none() {
                for i in 0..n {
                    shared.run_learner_step(&fleet, i, t, Some(local.as_mut()))?;
                }
            }

            // --- step entry: jitter draws + window-stall accounting ------
            let frontier = if t > k { apply_ring[(t - k - 1) % ring_cap] } else { 0.0 };
            for l in 0..n {
                jmult[l] = cfg.link.compute_mult(cfg.seed, l, t as u64);
                let s = avail[l].max(frontier);
                stalls[l] = s - avail[l];
                start[l] = s;
            }
            sig.note_step(&jmult[..n]);
            done_flags.clear();
            done_flags.resize(nb, false);
            let mut comm_serial = 0.0f64;
            let mut step_comm_end = 0.0f64;

            if streamed {
                // --- streamed: consume buckets as they complete ----------
                // (reverse layer order is the natural completion order);
                // reduce each over the topology while the rest of backward
                // — and, with staleness, later steps' compute — is still
                // running, pipelining rounds across the topology's ports.
                let mut pending = nb;
                let mut event_seq = shared.event.current();
                // set once the step has fully finished at every learner: a
                // full scan after that with buckets still unready is a
                // streaming-contract violation (an executor published fewer
                // layers than the layout), not a slow worker — bail instead
                // of spinning forever
                let mut saw_finished = false;
                loop {
                    let mut progressed = false;
                    for (bi, bucket) in fleet.plan.buckets.iter().enumerate() {
                        if done_flags[bi]
                            || shared.ready[slot * stride + bi].load(Ordering::Acquire) != n
                        {
                            continue;
                        }
                        let sched = RoundSched {
                            ready_s: shared.bucket_ready_s(&fleet, slot, bi, &start, &jmult),
                            port_free_s: port_end[bucket.port],
                        };
                        let cost = exchange_one_bucket(
                            &fleet,
                            slot,
                            layout,
                            &layer_lens,
                            bucket,
                            &mut gather,
                            &mut wire_pool,
                            sched,
                            topo.as_mut(),
                            &mut fabric,
                            &mut reduced,
                            &mut comp_conv,
                            &mut comp_fc,
                            &mut comp_all,
                            &mut sig,
                        );
                        comm_serial += cost.comm_s;
                        // rounds on one port serialize; disjoint ports
                        // overlap — the sharded-PS win
                        port_end[bucket.port] = cost.end_s;
                        step_comm_end = step_comm_end.max(cost.end_s);
                        done_flags[bi] = true;
                        pending -= 1;
                        progressed = true;
                    }
                    if pending == 0 {
                        break;
                    }
                    if !progressed {
                        if let Some(ctl) = pool {
                            if let Some(e) = ctl.failure() {
                                bail!("learner phase failed: {e}");
                            }
                        }
                        if saw_finished {
                            bail!(
                                "streamed exchange ended with {pending} buckets never ready"
                            );
                        }
                        saw_finished = shared.finished[slot].load(Ordering::Acquire) == n;
                        if !saw_finished {
                            event_seq = shared.event.wait_past(event_seq);
                        }
                    }
                }
            }
            // join the step: streamed after the scan (the loss/compute
            // spans publish with `finished`), barrier before anything else
            wait_counter(shared, pool, &shared.finished[slot], n)?;

            // loss accounting on the engine thread, learner-id order (the
            // f64 sum is order-sensitive)
            for l in 0..n {
                let loss = f32::from_bits(fleet.loss_bits[l * w + slot].load(Ordering::Relaxed));
                loss_sum += loss as f64;
                nloss += 1;
                if !loss.is_finite() || loss as f64 > cfg.divergence_loss {
                    record.diverged = true;
                }
            }

            if !streamed {
                if !record.diverged {
                    // the same bucket rounds, serialized after the join (no
                    // port-overlap credit — the classic placement)
                    let join_s = (0..n)
                        .map(|l| start[l] + shared.dur_s(&fleet, slot, l, jmult[l]))
                        .fold(0.0f64, f64::max);
                    let mut cursor = join_s;
                    for bucket in &fleet.plan.buckets {
                        let sched = RoundSched {
                            ready_s: cursor,
                            port_free_s: port_end[bucket.port],
                        };
                        let cost = exchange_one_bucket(
                            &fleet,
                            slot,
                            layout,
                            &layer_lens,
                            bucket,
                            &mut gather,
                            &mut wire_pool,
                            sched,
                            topo.as_mut(),
                            &mut fabric,
                            &mut reduced,
                            &mut comp_conv,
                            &mut comp_fc,
                            &mut comp_all,
                            &mut sig,
                        );
                        comm_serial += cost.comm_s;
                        cursor = cost.end_s;
                        port_end[bucket.port] = cost.end_s;
                    }
                    step_comm_end = cursor;
                } else {
                    // diverged: the final step's packets were packed but
                    // will not cross the wire — still fold them into the
                    // epoch's compression stats so the partial-epoch report
                    // matches the streamed mode's accounting (only fabric
                    // traffic differs across modes on a diverged run;
                    // module docs)
                    for ring in &fleet.cells {
                        for cell in ring[slot].iter() {
                            let cell = cell.lock();
                            for p in cell.slots.iter().flatten() {
                                tally_packet(
                                    layout, p, &mut comp_conv, &mut comp_fc, &mut comp_all,
                                );
                            }
                        }
                    }
                }
            }

            // --- fold the step onto the simulated timeline ---------------
            let mut compute_span = 0.0f64;
            let mut crit = 0usize;
            let mut crit_end = f64::MIN;
            for l in 0..n {
                let dur = shared.dur_s(&fleet, slot, l, jmult[l]);
                compute_span = compute_span.max(dur);
                let end = start[l] + dur;
                avail[l] = end;
                if end > crit_end {
                    crit_end = end;
                    crit = l;
                }
            }
            if !record.diverged || streamed {
                let prev_apply = if t > 0 { apply_ring[(t - 1) % ring_cap] } else { 0.0 };
                let apply_t = prev_apply.max(step_comm_end).max(crit_end);
                apply_ring[t % ring_cap] = apply_t;
                fabric.record_step(compute_span, comm_serial, apply_t - prev_apply, dense_round_s);
                fabric.record_stall(&stalls, crit);
            }

            if record.diverged {
                // Quiesce the window before snapshotting learner state:
                // with K > 0, steps t+1..=hi are already runnable (the
                // frontier stays at t), so pool workers will execute them
                // regardless of the abort. Drain them on both paths —
                // waiting on the pool, running them inline sequentially —
                // so the partial-epoch residue/gradient snapshot is taken
                // at the same deterministic point (after step `hi`) for
                // every thread count. `hi` is additionally capped below the
                // next membership event: the pool's open limit means no
                // worker can ever run a step past it, so waiting for one
                // would deadlock.
                let event_cap = events
                    .get(next_event)
                    .map(|e| e.step)
                    .unwrap_or(usize::MAX);
                let hi = (t + k).min(epoch_limit - 1).min(event_cap.saturating_sub(1));
                for s in (t + 1)..=hi {
                    if pool.is_none() {
                        for i in 0..n {
                            shared.run_learner_step(&fleet, i, s, Some(local.as_mut()))?;
                        }
                    }
                    wait_counter(shared, pool, &shared.finished[s % w], n)?;
                }
                // record the partial epoch and stop (no central update)
                let (err, tloss) = {
                    let params = shared.hist[cur_slot].read().unwrap();
                    test_error(local.as_mut(), dataset, &params).unwrap_or((100.0, f64::NAN))
                };
                let l0 = fleet.learners[0].lock().unwrap();
                record.epochs.push(epoch_record(
                    layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc,
                    comp_all, &l0, cfg, sw.secs(),
                ));
                break 'epochs;
            }

            // central update: unpack the dense mean, clip, optimizer step
            // into the next param-ring slot (dead by the window invariant)
            for (li, sum) in reduced.sums.iter().enumerate() {
                let dst = layout.view_mut(li, &mut grad_mean);
                for (d, &s) in dst.iter_mut().zip(sum.iter()) {
                    *d = s * inv_learners;
                }
            }
            if cfg.clip_norm > 0.0 {
                let norm = crate::tensor::ops::dot(&grad_mean, &grad_mean).sqrt();
                if norm > cfg.clip_norm {
                    let s = cfg.clip_norm / norm;
                    grad_mean.iter_mut().for_each(|g| *g *= s);
                }
            }
            let next_slot = (t + 1) % w;
            if w == 1 {
                let mut params = shared.hist[0].write().unwrap();
                optimizer.step(&mut params, &grad_mean, lr);
            } else {
                let cur = shared.hist[cur_slot].read().unwrap();
                let mut next = shared.hist[next_slot].write().unwrap();
                next.copy_from_slice(&cur);
                drop(cur);
                optimizer.step(&mut next, &grad_mean, lr);
            }
            cur_slot = next_slot;

            // hand the slot back to the window: reset its counters, then
            // publish the applied update (the PoolCtl mutex orders the
            // resets before any worker can re-enter the slot)
            for b in 0..nb {
                shared.ready[slot * stride + b].store(0, Ordering::Relaxed);
            }
            shared.finished[slot].store(0, Ordering::Relaxed);
            t += 1;
            if let Some(ctl) = pool {
                ctl.applied(t as u64);
            }
        }

        let fleet = shared.fleet.read().unwrap();
        if let Some(h) = hook.as_deref_mut() {
            let l0 = fleet.learners[0].lock().unwrap();
            h(epoch, l0.compressor.as_ref(), l0.grads());
        }

        let (err, tloss) = {
            let params = shared.hist[cur_slot].read().unwrap();
            test_error(local.as_mut(), dataset, &params)?
        };
        let l0 = fleet.learners[0].lock().unwrap();
        record.epochs.push(epoch_record(
            layout, epoch, loss_sum, nloss, err, tloss, lr, comp_conv, comp_fc, comp_all, &l0,
            cfg, sw.secs(),
        ));
        drop(l0);
        drop(fleet);

        // --- adaptive control plane: epoch-boundary re-tune --------------
        // The window is already drained to the frontier (workers park at
        // the epoch limit), so this is the same safe apply point a
        // membership epoch uses: swap K in the pool gate, rebuild the
        // plan/cell rings under the fleet write lock, push L_T into the
        // parked learners' compressors. The re-tune charges nothing to the
        // simulated timeline — it models a control decision piggybacked on
        // the epoch-boundary synchronization that already exists.
        if let Some(ctrl) = &ctrl {
            {
                let fleet = shared.fleet.read().unwrap();
                sig.n_buckets = fleet.plan.num_buckets();
            }
            sig.ports = topo.ports();
            let decisions = ctrl.retune(epoch, &sig, &mut knobs);
            let (mut replan, mut relts) = (false, false);
            for d in decisions {
                match d.knob.as_str() {
                    "staleness" => {
                        k = knobs.staleness;
                        shared.staleness.store(k, Ordering::Relaxed);
                        if let Some(ctl) = pool {
                            ctl.set_staleness(k);
                        }
                    }
                    "bucket_bytes" => replan = true,
                    _ => relts = true, // "lt:<layer>"
                }
                fabric.record_decision(d);
            }
            if replan || relts {
                threshold = knobs.bucket_bytes;
                let mut fleet = shared.fleet.write().unwrap();
                if replan {
                    fleet.plan = ReducePlan::build(layout, threshold, topo.ports());
                    let new_nb = fleet.plan.num_buckets();
                    let nn = fleet.learners.len();
                    fleet.cells =
                        (0..nn).map(|_| cell_ring_for_plan(&fleet.plan, w)).collect();
                    fleet.pub_ns =
                        (0..nn * w * new_nb).map(|_| AtomicU64::new(0)).collect();
                    for r in &shared.ready {
                        r.store(0, Ordering::Relaxed);
                    }
                    dense_round_s = fleet.plan.dense_round_s(&layer_lens, n, &cfg.link);
                    let cap = fleet.plan.max_bucket_layers();
                    for g in gather.iter_mut() {
                        g.reserve(cap);
                    }
                }
                if relts {
                    push_lts(&fleet, &knobs.lts);
                }
            }
        }
        sig.reset();
    }

    record.fabric = fabric.stats.clone();
    Ok((record, cur_slot))
}

/// Push the controller's live per-layer L_T table into every learner's
/// compressor (drained boundary only: workers parked, learner mutexes
/// free). No-op per layer for schemes without an L_T notion.
fn push_lts(fleet: &Fleet, lts: &[usize]) {
    for lm in &fleet.learners {
        let mut l = lm.lock().unwrap();
        for (li, &lt) in lts.iter().enumerate() {
            l.compressor.set_layer_lt(li, lt);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn epoch_record(
    layout: &Layout,
    epoch: usize,
    loss_sum: f64,
    nloss: usize,
    err: f64,
    tloss: f64,
    lr: f32,
    comp_conv: CompStat,
    comp_fc: CompStat,
    comp_all: CompStat,
    learner0: &Learner,
    cfg: &TrainConfig,
    wall: f64,
) -> EpochRecord {
    let (mut rg_p95, mut dw_p95) = (0.0f32, 0.0f32);
    if cfg.track_residue {
        let c = &learner0.compressor;
        let last_dw = learner0.grads();
        for li in 0..layout.num_layers() {
            rg_p95 = rg_p95.max(percentile(c.residue(li), 95.0));
        }
        if !last_dw.is_empty() {
            for li in 0..layout.num_layers() {
                dw_p95 = dw_p95.max(percentile(layout.view(li, last_dw), 95.0));
            }
        }
    }
    EpochRecord {
        epoch,
        train_loss: loss_sum / nloss.max(1) as f64,
        test_error_pct: err,
        test_loss: tloss,
        lr,
        comp_conv,
        comp_fc,
        comp_all,
        rg_p95,
        dw_p95,
        wall_secs: wall,
    }
}
