//! Persistent worker-pool coordination for the engine's learner phase.
//!
//! The engine used to spawn fresh `std::thread::scope` workers every step
//! (the documented follow-up in engine.rs); a pool now spawns once per run
//! and parks between steps on a condvar, so the per-step cost is one
//! notify + one wake instead of N thread spawns/joins.
//!
//! [`PoolCtl`] is the generation-counted step barrier the engine and the
//! workers rendezvous on:
//!
//! * engine: [`kick`](PoolCtl::kick) publishes a new step generation, then
//!   either blocks in [`wait_done`](PoolCtl::wait_done) (barrier exchange)
//!   or polls [`all_done`](PoolCtl::all_done) while it consumes per-layer
//!   grad-ready notifications (streamed exchange).
//! * worker: [`next_gen`](PoolCtl::next_gen) parks until the generation
//!   advances (or shutdown), runs its learner chunk, and checks in via
//!   [`report`](PoolCtl::report) — carrying any learner error back to the
//!   engine instead of unwinding through the pool.
//!
//! The data plane (learners, packet cells, ready counters, the parameter
//! vector) lives in the engine's run-scoped `Shared` state, not here: the
//! pool only sequences access so that workers touch it strictly inside
//! their own generation. All of this is run-scoped — the pool threads live
//! inside a `std::thread::scope` that wraps the training loop, so borrows
//! of run-local state need no `'static` gymnastics.

use std::sync::{Condvar, Mutex};

struct CtlState {
    /// Current step generation; 0 = nothing published yet.
    gen: u64,
    /// Workers that have checked in for `gen`.
    n_done: usize,
    shutdown: bool,
    /// First worker error of the current generation (formatted — the engine
    /// re-wraps it; `anyhow::Error` is not `Clone`).
    failed: Option<String>,
}

/// Generation-counted step barrier between the engine and its pool workers.
pub struct PoolCtl {
    state: Mutex<CtlState>,
    go: Condvar,
    done: Condvar,
}

impl Default for PoolCtl {
    fn default() -> Self {
        PoolCtl::new()
    }
}

impl PoolCtl {
    pub fn new() -> PoolCtl {
        PoolCtl {
            state: Mutex::new(CtlState {
                gen: 0,
                n_done: 0,
                shutdown: false,
                failed: None,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        }
    }

    /// Engine: publish the next step generation and wake all workers.
    pub fn kick(&self) {
        let mut s = self.state.lock().unwrap();
        s.gen += 1;
        s.n_done = 0;
        s.failed = None;
        self.go.notify_all();
    }

    /// Engine: block until all `workers` have checked in for the current
    /// generation; surfaces the first worker error.
    pub fn wait_done(&self, workers: usize) -> anyhow::Result<()> {
        let mut s = self.state.lock().unwrap();
        while s.n_done < workers {
            s = self.done.wait(s).unwrap();
        }
        match s.failed.take() {
            Some(e) => Err(anyhow::anyhow!("learner phase failed: {e}")),
            None => Ok(()),
        }
    }

    /// Engine: non-blocking check that every worker has checked in for the
    /// current generation (used while draining streamed grad-ready queues,
    /// so a failed worker cannot deadlock the engine's layer scan).
    pub fn all_done(&self, workers: usize) -> bool {
        self.state.lock().unwrap().n_done >= workers
    }

    /// Engine: stop the pool; parked workers wake and exit.
    pub fn shutdown(&self) {
        let mut s = self.state.lock().unwrap();
        s.shutdown = true;
        self.go.notify_all();
    }

    /// Worker: park until a generation newer than `last` is published.
    /// `None` means shutdown.
    pub fn next_gen(&self, last: u64) -> Option<u64> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.shutdown {
                return None;
            }
            if s.gen > last {
                return Some(s.gen);
            }
            s = self.go.wait(s).unwrap();
        }
    }

    /// Worker: check in for the current generation, carrying any error.
    pub fn report(&self, err: Option<String>) {
        let mut s = self.state.lock().unwrap();
        if let Some(e) = err {
            s.failed.get_or_insert(e);
        }
        s.n_done += 1;
        self.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_generations_and_shuts_down() {
        let ctl = PoolCtl::new();
        let hits = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let (ctl, hits) = (&ctl, &hits);
                scope.spawn(move || {
                    let mut gen = 0;
                    while let Some(g) = ctl.next_gen(gen) {
                        gen = g;
                        hits.fetch_add(1, Ordering::Relaxed);
                        ctl.report(None);
                    }
                });
            }
            for _ in 0..5 {
                ctl.kick();
                ctl.wait_done(3).unwrap();
            }
            ctl.shutdown();
        });
        assert_eq!(hits.load(Ordering::Relaxed), 15);
    }

    #[test]
    fn worker_errors_surface_to_the_engine() {
        let ctl = PoolCtl::new();
        std::thread::scope(|scope| {
            let c = &ctl;
            scope.spawn(move || {
                let mut gen = 0;
                while let Some(g) = c.next_gen(gen) {
                    gen = g;
                    c.report(Some("executor exploded".into()));
                }
            });
            ctl.kick();
            let err = ctl.wait_done(1).unwrap_err().to_string();
            assert!(err.contains("executor exploded"), "{err}");
            assert!(ctl.all_done(1));
            ctl.shutdown();
        });
    }
}
