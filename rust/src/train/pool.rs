//! Bounded-staleness window coordination for the engine's worker pool.
//!
//! The engine used to rendezvous with its workers on a per-step generation
//! barrier (kick → run chunk → check in): every learner's last bucket had
//! to land before any learner could start step t+1, so one slow learner
//! stalled the whole fleet. [`PoolCtl`] replaces the barrier with the
//! **staleness window**: workers free-run their learner chunks through the
//! step sequence and only block when a step would outrun the window.
//!
//! * worker: [`wait_runnable(s)`](PoolCtl::wait_runnable) parks until step
//!   `s` is inside the window — the engine has applied at least `s − K`
//!   updates (the param version θ_{s−K} that step `s` reads exists) and
//!   the epoch frontier has been opened past `s` — or the run is over
//!   (shutdown / a sibling worker failed).
//! * engine: [`open`](PoolCtl::open) raises the epoch frontier (workers
//!   never run ahead across an epoch boundary — evaluation and the epoch
//!   hook read quiescent learner state), [`applied`](PoolCtl::applied)
//!   publishes each central update (waking workers whose next step just
//!   entered the window), [`fail`](PoolCtl::fail) /
//!   [`failure`](PoolCtl::failure) carry the first worker error to the
//!   engine instead of unwinding through the pool, and
//!   [`shutdown`](PoolCtl::shutdown) ends the run.
//!
//! With `staleness = 0` the window degenerates to the old step barrier:
//! a worker may start step `s` only once update `s − 1` is applied, which
//! is exactly the synchronous engine. The data plane (learners, packet
//! cells, ready counters, the param-version ring) lives in the engine's
//! run-scoped `Shared` state, not here: the window only sequences access
//! so a slot is never reused while any in-flight step still needs it. All
//! of this is run-scoped — the pool threads live inside a
//! `std::thread::scope` that wraps the training loop, so borrows of
//! run-local state need no `'static` gymnastics.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};

struct CtlState {
    /// Central updates applied so far: θ_applied is the newest version.
    applied: u64,
    /// One past the last step workers may start (the epoch frontier).
    limit: u64,
    /// The live window bound K: a worker may start step `s` once `s − K`
    /// updates are applied (step `s` reads param version θ_{s−K}). The
    /// adaptive controller may re-tune it at drained epoch boundaries via
    /// [`PoolCtl::set_staleness`].
    staleness: u64,
    shutdown: bool,
    /// First worker error of the run (formatted — the engine re-wraps it;
    /// `anyhow::Error` is not `Clone`).
    failed: Option<String>,
}

/// Staleness-window gate between the engine and its pool workers.
pub struct PoolCtl {
    state: Mutex<CtlState>,
    go: Condvar,
    /// Lock-free mirror of `failed.is_some()`. The engine polls
    /// [`failure`](PoolCtl::failure) from inside its streamed bucket scan —
    /// the hot path — so the no-failure case must not contend on the state
    /// mutex a parked worker is about to reacquire. Set (Release) under the
    /// lock in [`fail`](PoolCtl::fail) *before* the wake, so an Acquire
    /// load that observes `true` is guaranteed to find the message.
    failed_flag: AtomicBool,
}

impl PoolCtl {
    pub fn new(staleness: usize) -> PoolCtl {
        PoolCtl {
            state: Mutex::new(CtlState {
                applied: 0,
                limit: 0,
                staleness: staleness as u64,
                shutdown: false,
                failed: None,
            }),
            go: Condvar::new(),
            failed_flag: AtomicBool::new(false),
        }
    }

    /// Worker: block until step `s` is inside the staleness window and the
    /// open epoch. Returns `false` when the run is over (shutdown or a
    /// worker failure) — the worker exits its loop.
    pub fn wait_runnable(&self, s: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown || st.failed.is_some() {
                return false;
            }
            if s < st.limit && s <= st.applied + st.staleness {
                return true;
            }
            st = self.go.wait(st).unwrap();
        }
    }

    /// Engine: open steps `[.., limit)` to the workers (the epoch
    /// frontier; monotone).
    pub fn open(&self, limit: u64) {
        let mut st = self.state.lock().unwrap();
        st.limit = st.limit.max(limit);
        self.go.notify_all();
    }

    /// Engine: publish that `applied` central updates have landed
    /// (θ_applied is now the newest param version).
    pub fn applied(&self, applied: u64) {
        let mut st = self.state.lock().unwrap();
        st.applied = applied;
        self.go.notify_all();
    }

    /// Engine: re-tune the live window bound K (adaptive controller, at a
    /// drained epoch boundary — every worker is parked at the epoch
    /// frontier, so no in-flight step observes the old bound). Widening
    /// wakes workers whose next step just entered the window.
    pub fn set_staleness(&self, staleness: usize) {
        let mut st = self.state.lock().unwrap();
        st.staleness = staleness as u64;
        self.go.notify_all();
    }

    /// Worker: record a learner-phase error; the first one wins. Sibling
    /// workers drain out of `wait_runnable` and the engine surfaces it.
    pub fn fail(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        st.failed.get_or_insert(msg);
        self.failed_flag.store(true, Ordering::Release);
        self.go.notify_all();
    }

    /// Engine: the first worker error, if any (checked inside the bucket
    /// scan so a dead worker can never deadlock the engine). The common
    /// no-failure poll is a single atomic load; the mutex is only taken
    /// once a failure actually exists.
    pub fn failure(&self) -> Option<String> {
        if !self.failed_flag.load(Ordering::Acquire) {
            return None;
        }
        self.state.lock().unwrap().failed.clone()
    }

    /// Engine: stop the pool; parked workers wake and exit.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.go.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    /// Spin-wait (bounded) until `cond` holds.
    fn eventually(cond: impl Fn() -> bool) -> bool {
        for _ in 0..2000 {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        false
    }

    #[test]
    fn window_gates_worker_progress() {
        // K = 1: a worker may run steps 0..=applied+1 (and only below the
        // epoch frontier); each `applied` bump releases exactly one more.
        let ctl = PoolCtl::new(1);
        let started = AtomicU64::new(0);
        std::thread::scope(|scope| {
            let (c, started) = (&ctl, &started);
            scope.spawn(move || {
                let mut s = 0u64;
                while c.wait_runnable(s) {
                    started.store(s + 1, Ordering::SeqCst);
                    s += 1;
                }
            });
            // nothing open yet: the worker must idle at 0
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 0);
            ctl.open(4);
            // applied = 0, K = 1 -> steps 0 and 1 may start, step 2 may not
            assert!(eventually(|| started.load(Ordering::SeqCst) == 2));
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 2);
            ctl.applied(1);
            assert!(eventually(|| started.load(Ordering::SeqCst) == 3));
            // the epoch frontier also gates: the window is wide open but
            // steps past the frontier (4) stay parked
            ctl.applied(5);
            assert!(eventually(|| started.load(Ordering::SeqCst) == 4));
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 4);
            ctl.open(5);
            assert!(eventually(|| started.load(Ordering::SeqCst) == 5));
            ctl.shutdown();
        });
    }

    #[test]
    fn staleness_zero_is_the_step_barrier() {
        // K = 0: each step waits for its predecessor's update — the old
        // synchronous generation barrier.
        let ctl = PoolCtl::new(0);
        let started = AtomicU64::new(0);
        std::thread::scope(|scope| {
            let (c, started) = (&ctl, &started);
            scope.spawn(move || {
                let mut s = 0u64;
                while c.wait_runnable(s) {
                    started.store(s + 1, Ordering::SeqCst);
                    s += 1;
                }
            });
            ctl.open(8);
            for t in 1..=4u64 {
                assert!(eventually(|| started.load(Ordering::SeqCst) == t));
                std::thread::sleep(Duration::from_millis(2));
                assert_eq!(started.load(Ordering::SeqCst), t);
                ctl.applied(t);
            }
            ctl.shutdown();
        });
    }

    #[test]
    fn set_staleness_retunes_the_live_window() {
        // start synchronous (K = 0), widen to K = 2 mid-run: parked
        // workers wake into the wider window; narrowing re-gates.
        let ctl = PoolCtl::new(0);
        let started = AtomicU64::new(0);
        std::thread::scope(|scope| {
            let (c, started) = (&ctl, &started);
            scope.spawn(move || {
                let mut s = 0u64;
                while c.wait_runnable(s) {
                    started.store(s + 1, Ordering::SeqCst);
                    s += 1;
                }
            });
            ctl.open(10);
            // K = 0: only step 0 may start
            assert!(eventually(|| started.load(Ordering::SeqCst) == 1));
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 1);
            // widen: steps 1 and 2 enter the window without a new update
            ctl.set_staleness(2);
            assert!(eventually(|| started.load(Ordering::SeqCst) == 3));
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 3);
            // narrow back: the next update releases exactly one step again
            ctl.set_staleness(1);
            ctl.applied(2);
            assert!(eventually(|| started.load(Ordering::SeqCst) == 4));
            std::thread::sleep(Duration::from_millis(5));
            assert_eq!(started.load(Ordering::SeqCst), 4);
            ctl.shutdown();
        });
    }

    #[test]
    fn worker_failure_drains_the_pool_and_surfaces() {
        let ctl = PoolCtl::new(2);
        std::thread::scope(|scope| {
            let c = &ctl;
            // a healthy worker parked on a far-future step
            let healthy = scope.spawn(move || c.wait_runnable(100));
            std::thread::sleep(Duration::from_millis(2));
            ctl.fail("executor exploded".into());
            // the parked sibling drains out with `false`
            assert!(!healthy.join().unwrap());
            // the engine sees the first error; later steps are not runnable
            assert_eq!(ctl.failure().as_deref(), Some("executor exploded"));
            assert!(!ctl.wait_runnable(0));
            ctl.shutdown();
        });
    }

    #[test]
    fn first_failure_wins_and_fast_path_sees_it() {
        // `failure()` must never observe the flag set without the message
        // (fail() publishes the message before the flag's Release store),
        // and concurrent failers must agree on a single winner.
        let ctl = PoolCtl::new(0);
        assert_eq!(ctl.failure(), None);
        std::thread::scope(|scope| {
            let c = &ctl;
            for i in 0..4 {
                scope.spawn(move || c.fail(format!("worker {i} panicked")));
            }
        });
        let first = ctl.failure().expect("a failure must be visible");
        assert!(first.starts_with("worker ") && first.ends_with(" panicked"));
        // later failers lost: the recorded error is stable
        ctl.fail("late loser".into());
        assert_eq!(ctl.failure().as_deref(), Some(first.as_str()));
    }
}
