//! Per-learner state: data shard, compressor (with its residual gradients),
//! the learner's batch-sampling RNG, reusable batch/gradient buffers, and —
//! in the parallel engine — the learner's own executor.
//!
//! A `Learner` is a self-contained unit of work: `step_streamed[_with]`
//! draws the next minibatch, runs forward+backward, packs each layout layer
//! the moment its gradient is final, and publishes the packet into its
//! reduce-plan **bucket cell** ([`BucketCell`] — one slot per bucket
//! layer); the engine exchanges a bucket the moment every learner has
//! completed it. All mutable state is owned by the learner, so the engine
//! can fan learners out across pool workers and still produce bit-identical
//! results to the sequential loop (the only cross-learner operations —
//! loss accounting and the packet reduce — happen on the engine thread in
//! learner-id order; see DESIGN.md §Threading, §Topologies).
//!
//! Below the learner, each GEMM may additionally fan its macro-tiles over
//! the shared compute pool (`tensor::parallel`): concurrent learners share
//! one pool of helper threads under the engine-derived core budget
//! (`threads / active_learners`), and because the parallel kernel is
//! bit-identical at every thread count, this never perturbs the
//! determinism contract above.

use std::sync::{Mutex, MutexGuard};

use anyhow::Result;

use crate::comm::ReducePlan;
use crate::compress::{self, wire, Compressor, Packet};
use crate::data::{draw_batch_into, Dataset, Shard, Split};
use crate::models::Layout;
use crate::runtime::{Batch, Executor};
use crate::util::rng::Pcg32;

/// One per-(learner, bucket) packet hand-off cell between a learner
/// (producer, worker thread) and the engine (consumer): one slot per layer
/// of the reduce-plan bucket, ascending layer order. The learner fills
/// slots as gradients complete during backward and reports the bucket done
/// when the last slot lands; the engine takes the packets for the exchange
/// and returns the spent ones to the same slots so the next step can
/// recycle their buffers — the cell never allocates in steady state.
pub struct BucketCell(Mutex<BucketSlots>);

/// The guarded state of a [`BucketCell`].
pub struct BucketSlots {
    /// One slot per bucket layer (ascending layer order within the bucket).
    pub slots: Vec<Option<Packet>>,
    /// Slots filled this step; the bucket is complete at `slots.len()`.
    pub filled: usize,
    /// The bucket's serialized wire frame, encoded by the learner the
    /// moment the last slot lands (still under the cell lock, before the
    /// bucket-ready callback). The engine decodes this — not the in-memory
    /// packets — so the fabric charges the *measured* frame length. Reused
    /// every step; never allocates in steady state.
    pub frame: Vec<u8>,
}

impl BucketCell {
    pub fn new(num_layers: usize) -> BucketCell {
        BucketCell(Mutex::new(BucketSlots {
            slots: (0..num_layers).map(|_| None).collect(),
            filled: 0,
            frame: Vec::new(),
        }))
    }

    pub fn lock(&self) -> MutexGuard<'_, BucketSlots> {
        self.0.lock().unwrap()
    }
}

/// Build one learner's cell row for a reduce plan (one cell per bucket).
pub fn cells_for_plan(plan: &ReducePlan) -> Vec<BucketCell> {
    plan.buckets
        .iter()
        .map(|b| BucketCell::new(b.num_layers()))
        .collect()
}

/// Build one learner's **slot ring** for the bounded-staleness window:
/// `window` independent cell rows (`ring[slot][bucket]`, slot = step %
/// window), so packets from up to `window = K + 1` in-flight steps coexist
/// without aliasing. Step t's cells are reused by step t + window only
/// after update t has been applied — the engine has emptied them and the
/// learner's compressor pool has recycled the buffers, so the ring never
/// allocates in steady state (rust/tests/alloc_free.rs pins K = 2).
pub fn cell_ring_for_plan(plan: &ReducePlan, window: usize) -> Vec<Vec<BucketCell>> {
    assert!(window >= 1);
    (0..window).map(|_| cells_for_plan(plan)).collect()
}

pub struct Learner {
    pub id: usize,
    pub shard: Shard,
    pub compressor: Box<dyn Compressor>,
    rng: Pcg32,
    batch: Batch,
    /// Reusable index buffer for batch sampling (no per-step allocation).
    idx_buf: Vec<usize>,
    /// This learner's own executor (parallel engine). `None` = the engine
    /// drives this learner through its shared local executor (`step_with`).
    exec: Option<Box<dyn Executor + Send>>,
    /// Flat gradient from the last `step` (moved out of the executor's
    /// `StepOut` — never cloned).
    grads: Vec<f32>,
    /// Loss from the last `step`.
    pub loss: f32,
}

impl Learner {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        n_learners: usize,
        dataset: &dyn Dataset,
        layout: &Layout,
        comp_cfg: &compress::Config,
        batch_size: usize,
        seed: u64,
        exec: Option<Box<dyn Executor + Send>>,
    ) -> Learner {
        let shard = Shard {
            learner: id,
            n_learners,
            train_len: dataset.train_len(),
        };
        let mut cfg = comp_cfg.clone();
        cfg.seed = seed ^ (id as u64) << 17; // decorrelate stochastic schemes
        let batch = if dataset.int_input() {
            Batch::i32(
                vec![0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        } else {
            Batch::f32(
                vec![0.0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        };
        Learner {
            id,
            shard,
            compressor: compress::build(&cfg, layout),
            rng: Pcg32::new(seed, 0xbea7 + id as u64),
            batch,
            idx_buf: Vec::with_capacity(batch_size),
            exec,
            grads: Vec::new(),
            loss: 0.0,
        }
    }

    /// Sample this learner's next minibatch into its reusable batch buffer.
    pub fn next_batch(&mut self, dataset: &dyn Dataset) -> &Batch {
        draw_batch_into(&mut self.rng, &self.shard, self.batch.batch_size, &mut self.idx_buf);
        let y = &mut self.batch.y;
        if self.batch.x_i32.is_empty() {
            dataset.fill(
                Split::Train,
                &self.idx_buf,
                crate::data::XBuf::F32(&mut self.batch.x_f32),
                y,
            );
        } else {
            dataset.fill(
                Split::Train,
                &self.idx_buf,
                crate::data::XBuf::I32(&mut self.batch.x_i32),
                y,
            );
        }
        &self.batch
    }

    /// Flat gradient from the last `step` (layout order; empty before the
    /// first step).
    pub fn grads(&self) -> &[f32] {
        &self.grads
    }

    /// One full learner phase on this learner's **own** executor: draw the
    /// next minibatch, forward+backward, pack every layer into `slots`.
    /// Safe to call from a worker thread.
    pub fn step(
        &mut self,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        slots: &mut Vec<Packet>,
    ) -> Result<()> {
        let mut exec = self
            .exec
            .take()
            .expect("learner was built without its own executor; use step_with");
        let r = self.step_with(exec.as_mut(), params, dataset, layout, slots);
        self.exec = Some(exec);
        r
    }

    /// Same as [`step`](Self::step) but on a caller-provided executor (the
    /// engine's sequential fallback shares one executor across learners).
    pub fn step_with(
        &mut self,
        exec: &mut dyn Executor,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        slots: &mut Vec<Packet>,
    ) -> Result<()> {
        self.next_batch(dataset);
        self.loss =
            exec.step_streamed_into(params, &self.batch, &mut self.grads, &mut |_, _| {})?;
        self.pack_into(layout, slots);
        Ok(())
    }

    /// One **streamed** learner phase on this learner's own executor: like
    /// [`step`](Self::step), but each layout layer is packed the moment its
    /// gradient span is final during backward (reverse graph order) and
    /// published into its reduce-plan bucket's cell slot; when a bucket's
    /// last slot lands, `on_bucket(bi)` fires — the engine's bucket-ready
    /// notification. Safe to call from a worker thread.
    pub fn step_streamed(
        &mut self,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        plan: &ReducePlan,
        cells: &[BucketCell],
        on_bucket: &mut dyn FnMut(usize),
    ) -> Result<()> {
        let mut exec = self
            .exec
            .take()
            .expect("learner was built without its own executor; use step_streamed_with");
        let r =
            self.step_streamed_with(exec.as_mut(), params, dataset, layout, plan, cells, on_bucket);
        self.exec = Some(exec);
        r
    }

    /// [`step_streamed`](Self::step_streamed) on a caller-provided executor
    /// (the engine's sequential path shares one executor across learners).
    ///
    /// Spent packets from the previous round are taken back out of `cells`
    /// and recycled first (resetting each bucket's fill count). Executors
    /// whose `streams()` is `false` (PJRT's opaque AOT program) produce no
    /// grad-ready callbacks; every layer is then packed after the step in
    /// ascending layer order — buckets complete in ascending-layer order
    /// instead of streamed order, behind the same API and with the same
    /// packets.
    #[allow(clippy::too_many_arguments)]
    pub fn step_streamed_with(
        &mut self,
        exec: &mut dyn Executor,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        plan: &ReducePlan,
        cells: &[BucketCell],
        on_bucket: &mut dyn FnMut(usize),
    ) -> Result<()> {
        assert_eq!(cells.len(), plan.num_buckets(), "one cell per plan bucket");
        for c in cells {
            let mut cell = c.lock();
            cell.filled = 0;
            for slot in cell.slots.iter_mut() {
                if let Some(spent) = slot.take() {
                    self.compressor.recycle(spent);
                }
            }
        }
        self.next_batch(dataset);
        let streams = exec.streams();
        self.loss = {
            let comp = &mut self.compressor;
            let batch = &self.batch;
            exec.step_streamed_into(params, batch, &mut self.grads, &mut |layers, grads| {
                for li in layers {
                    let p = comp.pack_layer(li, layout.view(li, grads));
                    publish(plan, cells, li, p, on_bucket);
                }
            })?
        };
        if !streams {
            for li in 0..layout.num_layers() {
                let p = self.compressor.pack_layer(li, layout.view(li, &self.grads));
                publish(plan, cells, li, p, on_bucket);
            }
        }
        Ok(())
    }

    /// Compress the last gradient into `slots` (one packet per layer, layer
    /// order), recycling the previous round's packet buffers through the
    /// compressor pool first — steady state allocates nothing. (Tests and
    /// figure harnesses; the engine drives `step_streamed_with` in both
    /// exchange modes.)
    pub fn pack_into(&mut self, layout: &Layout, slots: &mut Vec<Packet>) {
        for spent in slots.drain(..) {
            self.compressor.recycle(spent);
        }
        for li in 0..layout.num_layers() {
            let p = self.compressor.pack_layer(li, layout.view(li, &self.grads));
            slots.push(p);
        }
    }

    /// Compress a flat gradient into per-layer packets (Algorithm 1 pack()).
    pub fn pack(&mut self, layout: &Layout, grads: &[f32]) -> Vec<Packet> {
        (0..layout.num_layers())
            .map(|li| self.compressor.pack_layer(li, layout.view(li, grads)))
            .collect()
    }
}

/// Publish one packed layer into its bucket cell slot; fires `on_bucket`
/// when the bucket's last slot lands. Completing a bucket also serializes
/// its wire frame into the cell's reusable frame buffer (this learner's
/// contribution as it would cross the fabric — the engine decodes the frame
/// and charges its measured length). The cell lock is dropped before the
/// callback (the engine's notification path takes its own locks).
fn publish(
    plan: &ReducePlan,
    cells: &[BucketCell],
    li: usize,
    p: Packet,
    on_bucket: &mut dyn FnMut(usize),
) {
    let (bi, pos) = plan.slot_of(li);
    let done = {
        let mut cell = cells[bi].lock();
        debug_assert!(cell.slots[pos].is_none(), "layer {li} packed twice");
        cell.slots[pos] = Some(p);
        cell.filled += 1;
        let done = cell.filled == cell.slots.len();
        if done {
            let BucketSlots { slots, frame, .. } = &mut *cell;
            wire::encode_bucket_frame_packets_into(bi, slots, frame)
                .expect("bucket frame encode");
        }
        done
    };
    if done {
        on_bucket(bi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Config, Kind};
    use crate::data::synth::GaussianMixture;
    use crate::models::{LayerKind, Layout};
    use crate::runtime::native::NativeMlp;
    use crate::runtime::ExecutorFactory;

    #[test]
    fn learner_batches_stay_in_shard() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[("w", &[8, 4], LayerKind::Fc)]);
        let mut l = Learner::new(
            1,
            4,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            42,
            None,
        );
        let b = l.next_batch(&ds);
        assert_eq!(b.x_f32.len(), 4 * 8);
        assert_eq!(b.y.len(), 4);
    }

    #[test]
    fn pack_covers_all_layers() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[
            ("w1", &[8, 4], LayerKind::Fc),
            ("b1", &[4], LayerKind::Fc),
        ]);
        let mut l = Learner::new(0, 1, &ds, &layout, &Config::with_kind(Kind::None), 4, 1, None);
        let grads = vec![0.5f32; layout.total];
        let packets = l.pack(&layout, &grads);
        assert_eq!(packets.len(), 2);
        assert_eq!(packets[0].n, 32);
        assert_eq!(packets[1].n, 4);
    }

    #[test]
    fn step_streamed_matches_step_packets_in_reverse_order() {
        // the streamed phase must produce the same packets as the legacy
        // barrier phase (per layer: same idx/val/wire bytes), publish
        // buckets in reverse graph order, and recycle cleanly across steps
        let ds = GaussianMixture::new(2, 8, 4, 100, 20, 0.3);
        let exe = NativeMlp::new(&[8, 6, 4], 16);
        let layout = exe.layout().clone();
        let params = exe.init_params(5);
        // threshold 1: every layer its own bucket — bucket order is then
        // exactly reverse layer order (bucket 0 = last layer)
        let plan = ReducePlan::build(&layout, 1, 1);
        assert_eq!(plan.num_buckets(), layout.num_layers());

        let mk = |seed| {
            Learner::new(
                0,
                2,
                &ds,
                &layout,
                &Config::with_kind(Kind::AdaComp),
                4,
                seed,
                Some(exe.build_worker().unwrap()),
            )
        };
        let mut streamed = mk(9);
        let mut barrier = mk(9);

        let cells = cells_for_plan(&plan);
        let mut slots = Vec::new();
        for _ in 0..3 {
            let mut order = Vec::new();
            streamed
                .step_streamed(&params, &ds, &layout, &plan, &cells, &mut |bi| {
                    order.push(plan.buckets[bi].layers.start)
                })
                .unwrap();
            barrier.step(&params, &ds, &layout, &mut slots).unwrap();
            // fc2 layers (2, 3) ready before fc1 layers (0, 1)
            assert_eq!(order, vec![2, 3, 0, 1]);
            assert_eq!(streamed.loss.to_bits(), barrier.loss.to_bits());
            for (li, b) in slots.iter().enumerate() {
                let (bi, pos) = plan.slot_of(li);
                let guard = cells[bi].lock();
                let s = guard.slots[pos].as_ref().expect("cell filled");
                assert_eq!(s.idx, b.idx, "layer {li}");
                assert_eq!(s.val, b.val, "layer {li}");
                assert_eq!(s.wire_bytes, b.wire_bytes, "layer {li}");
            }
        }
        assert_eq!(streamed.grads(), barrier.grads());
    }

    #[test]
    fn bucket_cells_fire_once_per_completed_bucket() {
        // a whole-model bucket: the callback must fire exactly once, when
        // the bucket's LAST layer lands; fill counts must reset across steps
        let ds = GaussianMixture::new(2, 8, 4, 100, 20, 0.3);
        let exe = NativeMlp::new(&[8, 6, 4], 16);
        let layout = exe.layout().clone();
        let params = exe.init_params(5);
        // coalesce everything below 1 MiB -> a single whole-model bucket
        let plan = ReducePlan::build(&layout, 1 << 20, 1);
        assert_eq!(plan.num_buckets(), 1);
        let cells = cells_for_plan(&plan);
        let mut l = Learner::new(
            0,
            1,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            3,
            Some(exe.build_worker().unwrap()),
        );
        for _ in 0..2 {
            let mut fired = Vec::new();
            l.step_streamed(&params, &ds, &layout, &plan, &cells, &mut |bi| fired.push(bi))
                .unwrap();
            // single bucket: fires once, only after ALL layers packed
            assert_eq!(fired, vec![0]);
            let cell = cells[0].lock();
            assert_eq!(cell.filled, layout.num_layers());
            assert!(cell.slots.iter().all(|s| s.is_some()));
            // publish serialized the completed bucket's wire frame; it must
            // decode back to exactly the packets sitting in the slots
            let (bi, decoded) = wire::decode_bucket_frame(&cell.frame).unwrap();
            assert_eq!(bi, 0);
            assert_eq!(decoded.len(), layout.num_layers());
            for (d, s) in decoded.iter().zip(cell.slots.iter()) {
                let s = s.as_ref().unwrap();
                assert_eq!(d.layer, s.layer);
                assert_eq!(d.idx, s.idx);
                assert_eq!(d.val, s.val);
            }
        }
    }

    #[test]
    fn step_fills_slots_and_step_with_matches() {
        // A learner stepping on its own executor must be bit-identical to
        // the same learner driven through a shared executor.
        let ds = GaussianMixture::new(2, 8, 4, 100, 20, 0.3);
        let exe = NativeMlp::new(&[8, 6, 4], 16);
        let layout = exe.layout().clone();
        let params = exe.init_params(5);

        let mut own = Learner::new(
            0,
            2,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            9,
            Some(exe.build_worker().unwrap()),
        );
        let mut shared_exec = exe.build_local().unwrap();
        let mut shared = Learner::new(
            0,
            2,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            9,
            None,
        );

        let mut slots_a = Vec::new();
        let mut slots_b = Vec::new();
        for _ in 0..3 {
            own.step(&params, &ds, &layout, &mut slots_a).unwrap();
            shared
                .step_with(shared_exec.as_mut(), &params, &ds, &layout, &mut slots_b)
                .unwrap();
            assert_eq!(own.loss, shared.loss);
            assert_eq!(slots_a.len(), layout.num_layers());
            for (a, b) in slots_a.iter().zip(slots_b.iter()) {
                assert_eq!(a.idx, b.idx);
                assert_eq!(a.val, b.val);
                assert_eq!(a.wire_bytes, b.wire_bytes);
            }
        }
        assert_eq!(own.grads(), shared.grads());
    }
}
