//! Per-learner state: data shard, compressor (with its residual gradients),
//! the learner's batch-sampling RNG, reusable batch/gradient buffers, and —
//! in the parallel engine — the learner's own executor.
//!
//! A `Learner` is a self-contained unit of work: `step`/`step_with` draws
//! the next minibatch, runs forward+backward, and packs every layer into the
//! caller's packet slots. All mutable state is owned by the learner, so the
//! engine can fan learners out across `std::thread::scope` workers and still
//! produce bit-identical results to the sequential loop (the only cross-
//! learner operations — loss accounting and the packet reduce — happen on
//! the engine thread in learner-id order; see DESIGN.md §Threading).

use anyhow::Result;

use crate::compress::{self, Compressor, Packet};
use crate::data::{draw_batch_into, Dataset, Shard, Split};
use crate::models::Layout;
use crate::runtime::{Batch, Executor};
use crate::util::rng::Pcg32;

pub struct Learner {
    pub id: usize,
    pub shard: Shard,
    pub compressor: Box<dyn Compressor>,
    rng: Pcg32,
    batch: Batch,
    /// Reusable index buffer for batch sampling (no per-step allocation).
    idx_buf: Vec<usize>,
    /// This learner's own executor (parallel engine). `None` = the engine
    /// drives this learner through its shared local executor (`step_with`).
    exec: Option<Box<dyn Executor + Send>>,
    /// Flat gradient from the last `step` (moved out of the executor's
    /// `StepOut` — never cloned).
    grads: Vec<f32>,
    /// Loss from the last `step`.
    pub loss: f32,
}

impl Learner {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        n_learners: usize,
        dataset: &dyn Dataset,
        layout: &Layout,
        comp_cfg: &compress::Config,
        batch_size: usize,
        seed: u64,
        exec: Option<Box<dyn Executor + Send>>,
    ) -> Learner {
        let shard = Shard {
            learner: id,
            n_learners,
            train_len: dataset.train_len(),
        };
        let mut cfg = comp_cfg.clone();
        cfg.seed = seed ^ (id as u64) << 17; // decorrelate stochastic schemes
        let batch = if dataset.int_input() {
            Batch::i32(
                vec![0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        } else {
            Batch::f32(
                vec![0.0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        };
        Learner {
            id,
            shard,
            compressor: compress::build(&cfg, layout),
            rng: Pcg32::new(seed, 0xbea7 + id as u64),
            batch,
            idx_buf: Vec::with_capacity(batch_size),
            exec,
            grads: Vec::new(),
            loss: 0.0,
        }
    }

    /// Sample this learner's next minibatch into its reusable batch buffer.
    pub fn next_batch(&mut self, dataset: &dyn Dataset) -> &Batch {
        draw_batch_into(&mut self.rng, &self.shard, self.batch.batch_size, &mut self.idx_buf);
        let y = &mut self.batch.y;
        if self.batch.x_i32.is_empty() {
            dataset.fill(
                Split::Train,
                &self.idx_buf,
                crate::data::XBuf::F32(&mut self.batch.x_f32),
                y,
            );
        } else {
            dataset.fill(
                Split::Train,
                &self.idx_buf,
                crate::data::XBuf::I32(&mut self.batch.x_i32),
                y,
            );
        }
        &self.batch
    }

    /// Flat gradient from the last `step` (layout order; empty before the
    /// first step).
    pub fn grads(&self) -> &[f32] {
        &self.grads
    }

    /// One full learner phase on this learner's **own** executor: draw the
    /// next minibatch, forward+backward, pack every layer into `slots`.
    /// Safe to call from a worker thread.
    pub fn step(
        &mut self,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        slots: &mut Vec<Packet>,
    ) -> Result<()> {
        let mut exec = self
            .exec
            .take()
            .expect("learner was built without its own executor; use step_with");
        let r = self.step_with(exec.as_mut(), params, dataset, layout, slots);
        self.exec = Some(exec);
        r
    }

    /// Same as [`step`](Self::step) but on a caller-provided executor (the
    /// engine's sequential fallback shares one executor across learners).
    pub fn step_with(
        &mut self,
        exec: &mut dyn Executor,
        params: &[f32],
        dataset: &dyn Dataset,
        layout: &Layout,
        slots: &mut Vec<Packet>,
    ) -> Result<()> {
        self.next_batch(dataset);
        let out = exec.step(params, &self.batch)?;
        self.loss = out.loss;
        self.grads = out.grads;
        self.pack_into(layout, slots);
        Ok(())
    }

    /// Compress the last gradient into `slots` (one packet per layer, layer
    /// order), recycling the previous round's packet buffers through the
    /// compressor pool first — steady state allocates nothing.
    pub fn pack_into(&mut self, layout: &Layout, slots: &mut Vec<Packet>) {
        for spent in slots.drain(..) {
            self.compressor.recycle(spent);
        }
        for li in 0..layout.num_layers() {
            let p = self.compressor.pack_layer(li, layout.view(li, &self.grads));
            slots.push(p);
        }
    }

    /// Compress a flat gradient into per-layer packets (Algorithm 1 pack()).
    pub fn pack(&mut self, layout: &Layout, grads: &[f32]) -> Vec<Packet> {
        (0..layout.num_layers())
            .map(|li| self.compressor.pack_layer(li, layout.view(li, grads)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Config, Kind};
    use crate::data::synth::GaussianMixture;
    use crate::models::{LayerKind, Layout};
    use crate::runtime::native::NativeMlp;
    use crate::runtime::ExecutorFactory;

    #[test]
    fn learner_batches_stay_in_shard() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[("w", &[8, 4], LayerKind::Fc)]);
        let mut l = Learner::new(
            1,
            4,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            42,
            None,
        );
        let b = l.next_batch(&ds);
        assert_eq!(b.x_f32.len(), 4 * 8);
        assert_eq!(b.y.len(), 4);
    }

    #[test]
    fn pack_covers_all_layers() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[
            ("w1", &[8, 4], LayerKind::Fc),
            ("b1", &[4], LayerKind::Fc),
        ]);
        let mut l = Learner::new(0, 1, &ds, &layout, &Config::with_kind(Kind::None), 4, 1, None);
        let grads = vec![0.5f32; layout.total];
        let packets = l.pack(&layout, &grads);
        assert_eq!(packets.len(), 2);
        assert_eq!(packets[0].n, 32);
        assert_eq!(packets[1].n, 4);
    }

    #[test]
    fn step_fills_slots_and_step_with_matches() {
        // A learner stepping on its own executor must be bit-identical to
        // the same learner driven through a shared executor.
        let ds = GaussianMixture::new(2, 8, 4, 100, 20, 0.3);
        let exe = NativeMlp::new(&[8, 6, 4], 16);
        let layout = exe.layout().clone();
        let params = exe.init_params(5);

        let mut own = Learner::new(
            0,
            2,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            9,
            Some(exe.build_worker().unwrap()),
        );
        let mut shared_exec = exe.build_local().unwrap();
        let mut shared = Learner::new(
            0,
            2,
            &ds,
            &layout,
            &Config::with_kind(Kind::AdaComp),
            4,
            9,
            None,
        );

        let mut slots_a = Vec::new();
        let mut slots_b = Vec::new();
        for _ in 0..3 {
            own.step(&params, &ds, &layout, &mut slots_a).unwrap();
            shared
                .step_with(shared_exec.as_mut(), &params, &ds, &layout, &mut slots_b)
                .unwrap();
            assert_eq!(own.loss, shared.loss);
            assert_eq!(slots_a.len(), layout.num_layers());
            for (a, b) in slots_a.iter().zip(slots_b.iter()) {
                assert_eq!(a.idx, b.idx);
                assert_eq!(a.val, b.val);
                assert_eq!(a.wire_bytes, b.wire_bytes);
            }
        }
        assert_eq!(own.grads(), shared.grads());
    }
}
