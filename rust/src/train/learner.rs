//! Per-learner state: data shard, compressor (with its residual gradients),
//! and the learner's batch-sampling RNG.

use crate::compress::{self, Compressor, Packet};
use crate::data::{draw_batch, Dataset, Shard, Split};
use crate::models::Layout;
use crate::runtime::Batch;
use crate::util::rng::Pcg32;

pub struct Learner {
    pub id: usize,
    pub shard: Shard,
    pub compressor: Box<dyn Compressor>,
    rng: Pcg32,
    batch: Batch,
}

impl Learner {
    pub fn new(
        id: usize,
        n_learners: usize,
        dataset: &dyn Dataset,
        layout: &Layout,
        comp_cfg: &compress::Config,
        batch_size: usize,
        seed: u64,
    ) -> Learner {
        let shard = Shard {
            learner: id,
            n_learners,
            train_len: dataset.train_len(),
        };
        let mut cfg = comp_cfg.clone();
        cfg.seed = seed ^ (id as u64) << 17; // decorrelate stochastic schemes
        let batch = if dataset.int_input() {
            Batch::i32(
                vec![0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        } else {
            Batch::f32(
                vec![0.0; batch_size * dataset.x_elems()],
                vec![0; batch_size * dataset.y_elems()],
                batch_size,
            )
        };
        Learner {
            id,
            shard,
            compressor: compress::build(&cfg, layout),
            rng: Pcg32::new(seed, 0xbea7 + id as u64),
            batch,
        }
    }

    /// Sample this learner's next minibatch into its reusable batch buffer.
    pub fn next_batch(&mut self, dataset: &dyn Dataset) -> &Batch {
        let idx = draw_batch(&mut self.rng, &self.shard, self.batch.batch_size);
        let y = &mut self.batch.y;
        if self.batch.x_i32.is_empty() {
            dataset.fill(
                Split::Train,
                &idx,
                crate::data::XBuf::F32(&mut self.batch.x_f32),
                y,
            );
        } else {
            dataset.fill(
                Split::Train,
                &idx,
                crate::data::XBuf::I32(&mut self.batch.x_i32),
                y,
            );
        }
        &self.batch
    }

    /// Compress a flat gradient into per-layer packets (Algorithm 1 pack()).
    pub fn pack(&mut self, layout: &Layout, grads: &[f32]) -> Vec<Packet> {
        (0..layout.num_layers())
            .map(|li| self.compressor.pack_layer(li, layout.view(li, grads)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Config, Kind};
    use crate::data::synth::GaussianMixture;
    use crate::models::{LayerKind, Layout};

    #[test]
    fn learner_batches_stay_in_shard() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[("w", &[8, 4], LayerKind::Fc)]);
        let mut l = Learner::new(1, 4, &ds, &layout, &Config::with_kind(Kind::AdaComp), 4, 42);
        let b = l.next_batch(&ds);
        assert_eq!(b.x_f32.len(), 4 * 8);
        assert_eq!(b.y.len(), 4);
    }

    #[test]
    fn pack_covers_all_layers() {
        let ds = GaussianMixture::new(1, 8, 4, 100, 20, 0.3);
        let layout = Layout::from_specs(&[
            ("w1", &[8, 4], LayerKind::Fc),
            ("b1", &[4], LayerKind::Fc),
        ]);
        let mut l = Learner::new(0, 1, &ds, &layout, &Config::with_kind(Kind::None), 4, 1);
        let grads = vec![0.5f32; layout.total];
        let packets = l.pack(&layout, &grads);
        assert_eq!(packets.len(), 2);
        assert_eq!(packets[0].n, 32);
        assert_eq!(packets[1].n, 4);
    }
}
