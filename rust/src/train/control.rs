//! Adaptive control plane: epoch-boundary re-tuning of the run knobs.
//!
//! Every run-level knob used to be static for the whole run — per-layer
//! AdaComp bin size L_T, the bucket-coalescing threshold `--bucket-bytes`,
//! the staleness window `--staleness K` — each hand-picked per scenario.
//! With `--controller on` a deterministic feedback controller re-tunes all
//! three at epoch boundaries from the epoch's measurements, L-GreCo-style
//! for the per-layer rates (PAPERS.md: "L-GreCo: Layerwise-Adaptive
//! Gradient Compression"):
//!
//! * **staleness** — widen the window only while stragglers dominate
//!   (the seeded jitter model's max-over-learners excess per step), shrink
//!   back once they don't. Bounded by the allocated window headroom
//!   ([`staleness_cap`]): the engine allocates the param-version ring once
//!   at run start, so the live K can move without reallocating history.
//! * **bucket_bytes** — split buckets while topology ports sit idle
//!   (`n_buckets < ports`), coalesce while the mean on-wire bucket frame
//!   is too small to amortize its per-message latency (below half the
//!   link's α·β break-even).
//! * **per-layer L_T** — raise a layer's bin size (compress harder) while
//!   its share of wire bytes dwarfs its share of backward compute (element
//!   count as the deterministic compute proxy), lower it when the layer is
//!   communication-cold. Clamped to a multiplicative band around the
//!   starting point so the controller can explore but not run away.
//!
//! **Determinism contract.** Decisions are a pure function of
//! ([`EpochSignals`], current [`Knobs`]) — and every signal folded into
//! `EpochSignals` is itself deterministic: wire bytes come from the
//! serialized packet frames (bit-identical across thread counts and
//! exchange modes), straggler pressure from the seeded
//! [`LinkModel::compute_mult`] draws, bucket/port counts from the plan.
//! Wall-clock measurements (`stall_per_step_s`, `crit_share`, measured
//! comm tails) are *reported* in FabricStats but deliberately never feed a
//! decision: they are the same quantities the signals above project
//! deterministically (jitter excess ⇒ stall pressure, frame bytes vs α·β
//! ⇒ per-port comm tail), and consuming the measured versions would make
//! knob trajectories differ run to run. Hysteresis bands, bounded ×2 / ±1
//! step sizes, and clamps to the validated ranges keep the trajectory
//! stable; the decision timeline lands in
//! [`FabricStats::control`](crate::comm::fabric::FabricStats::control).
//!
//! The *apply* path reuses the membership-epoch machinery: at an epoch
//! boundary the window is already drained to the frontier (workers park at
//! the epoch limit), so the engine can swap K in the pool gate, push L_T
//! into the learners' compressors, and rebuild the `ReducePlan`/cell rings
//! under the fleet write lock exactly as a churn event would.

use crate::comm::fabric::{ControlDecision, LinkModel};
use crate::comm::plan::ReducePlan;
use crate::compress::wire::dense_f32_wire_len;
use crate::models::Layout;

/// Valid `--controller` modes (the `topology::build` fail-fast pattern).
pub const MODES: &[&str] = &["off", "on"];

/// Parse + validate a controller mode; `Ok(true)` means the controller is
/// on. Config JSON, CLI/harness, and the engine all validate through here.
pub fn parse_mode(mode: &str) -> anyhow::Result<bool> {
    match mode {
        "off" => Ok(false),
        "on" => Ok(true),
        other => anyhow::bail!("unknown controller mode '{other}' (valid: off, on)"),
    }
}

/// Allocated staleness headroom for a controller-managed run: the live K
/// may widen up to this bound without reallocating the param-version ring.
/// Twice the starting K with at least two slots of headroom, capped at the
/// engine-wide [`MAX_STALENESS`](crate::train::engine::MAX_STALENESS).
pub fn staleness_cap(k0: usize) -> usize {
    crate::train::engine::MAX_STALENESS.min((2 * k0).max(k0 + 2))
}

/// The controller's live operating point — the three knobs it owns.
#[derive(Debug, Clone, PartialEq)]
pub struct Knobs {
    /// Staleness window bound K (live; ≤ the run's allocated cap).
    pub staleness: usize,
    /// Bucket-coalescing threshold in dense wire bytes.
    pub bucket_bytes: usize,
    /// Per-layer AdaComp bin size L_T. Empty when the active compression
    /// scheme has no L_T notion (the L_T rule is skipped).
    pub lts: Vec<usize>,
}

/// Deterministic measurements folded over one epoch — the controller's
/// only inputs (see the module docs for why wall-clock measurements are
/// excluded).
#[derive(Debug, Clone)]
pub struct EpochSignals {
    /// Steps folded this epoch.
    pub steps: u64,
    /// Fleet size at the last folded step.
    pub learners: usize,
    /// Σ over steps of `max_l mult − mean_l mult` from the seeded jitter
    /// draws: the deterministic projection of straggler stall pressure.
    pub jitter_excess: f64,
    /// Per-layer serialized wire bytes this epoch (summed over learners,
    /// steps, and directions charged to the learner's packet).
    pub layer_bytes: Vec<u64>,
    /// Bucket count of the plan in force at the epoch boundary.
    pub n_buckets: usize,
    /// Topology ports in force at the epoch boundary.
    pub ports: usize,
}

impl EpochSignals {
    pub fn new(num_layers: usize) -> EpochSignals {
        EpochSignals {
            steps: 0,
            learners: 0,
            jitter_excess: 0.0,
            layer_bytes: vec![0; num_layers],
            n_buckets: 0,
            ports: 0,
        }
    }

    /// Zero the accumulators for the next epoch.
    pub fn reset(&mut self) {
        self.steps = 0;
        self.jitter_excess = 0.0;
        self.layer_bytes.iter_mut().for_each(|b| *b = 0);
    }

    /// Fold one step's per-learner jitter multipliers.
    pub fn note_step(&mut self, mults: &[f64]) {
        if mults.is_empty() {
            return;
        }
        let max = mults.iter().cloned().fold(f64::MIN, f64::max);
        let mean = mults.iter().sum::<f64>() / mults.len() as f64;
        self.jitter_excess += max - mean;
        self.learners = mults.len();
        self.steps += 1;
    }

    /// Fold one serialized packet's wire bytes onto its layer.
    #[inline]
    pub fn note_packet(&mut self, layer: usize, wire_bytes: usize) {
        self.layer_bytes[layer] += wire_bytes as u64;
    }

    /// Mean straggler excess per step (0 with jitter off).
    pub fn straggler_excess(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.jitter_excess / self.steps as f64
        }
    }

    /// Mean on-wire bucket frame payload this epoch, bytes.
    pub fn mean_frame_bytes(&self) -> f64 {
        let frames = self.steps * self.learners.max(1) as u64 * self.n_buckets.max(1) as u64;
        if frames == 0 {
            0.0
        } else {
            self.layer_bytes.iter().sum::<u64>() as f64 / frames as f64
        }
    }
}

/// Hysteresis band for the staleness rule: widen above, narrow below,
/// hold in between.
const WIDEN_EXCESS: f64 = 0.10;
const NARROW_EXCESS: f64 = 0.04;
/// Coalesce while the mean frame fills less than this fraction of α·β.
const COALESCE_FILL: f64 = 0.5;
/// L_T rule band: a layer is comm-hot above, comm-cold below (its wire
/// share relative to its compute-proxy share).
const LT_HOT_RATIO: f64 = 2.0;
const LT_COLD_RATIO: f64 = 0.5;
/// A layer must carry at least this wire share before it is worth
/// compressing harder (don't churn L_T on noise-sized layers).
const LT_MIN_SHARE: f64 = 0.05;
/// Absolute L_T ceiling (matches the CLI/config validated range).
pub const LT_ABS_MAX: usize = 100_000;
/// Multiplicative exploration band around each layer's starting L_T.
const LT_BAND: usize = 8;

/// The deterministic feedback controller. Construction captures the
/// clamp ranges (from the starting knobs, the layout, and the link);
/// [`retune`](Controller::retune) is a pure function of
/// (epoch signals, current knobs).
#[derive(Debug, Clone)]
pub struct Controller {
    /// Hard cap on the live staleness window (allocation bound).
    k_cap: usize,
    /// α·β for the run's link: the latency-amortization break-even.
    auto_bytes: usize,
    /// Largest useful threshold: whole-model dense wire bytes (one bucket).
    thr_max: usize,
    /// Per-layer L_T clamp band.
    lt_lo: Vec<usize>,
    lt_hi: Vec<usize>,
    /// Per-layer element counts: the deterministic backward-compute proxy.
    layer_elems: Vec<usize>,
}

impl Controller {
    pub fn new(layout: &Layout, knobs: &Knobs, k_cap: usize, link: &LinkModel) -> Controller {
        let lt_lo = knobs.lts.iter().map(|&l| (l / LT_BAND).max(1)).collect();
        let lt_hi = knobs
            .lts
            .iter()
            .map(|&l| (l.saturating_mul(LT_BAND)).min(LT_ABS_MAX).max(l))
            .collect();
        let layer_elems = layout.layer_lens();
        let thr_max = layer_elems
            .iter()
            .map(|&len| dense_f32_wire_len(len))
            .sum::<usize>()
            .max(1);
        Controller {
            k_cap,
            auto_bytes: ReducePlan::auto_threshold(link),
            thr_max,
            lt_lo,
            lt_hi,
            layer_elems,
        }
    }

    /// Re-tune the knobs from one epoch's measurements. Mutates `knobs` to
    /// the new operating point and returns the applied decisions (empty =
    /// every rule held). Pure: identical (signals, knobs) in ⇒ identical
    /// decisions and knobs out.
    pub fn retune(
        &self,
        epoch: usize,
        sig: &EpochSignals,
        knobs: &mut Knobs,
    ) -> Vec<ControlDecision> {
        let mut out = Vec::new();
        if sig.steps == 0 {
            return out;
        }

        // 1. Staleness window ← straggler pressure (±1 per epoch).
        let excess = sig.straggler_excess();
        if excess > WIDEN_EXCESS && knobs.staleness < self.k_cap {
            let new = knobs.staleness + 1;
            out.push(decision(
                epoch,
                "staleness",
                knobs.staleness as f64,
                new as f64,
                format!("straggler_excess={excess:.3}>{WIDEN_EXCESS}"),
            ));
            knobs.staleness = new;
        } else if excess < NARROW_EXCESS && knobs.staleness > 0 {
            let new = knobs.staleness - 1;
            out.push(decision(
                epoch,
                "staleness",
                knobs.staleness as f64,
                new as f64,
                format!("straggler_excess={excess:.3}<{NARROW_EXCESS}"),
            ));
            knobs.staleness = new;
        }

        // 2. Bucket threshold ← port occupancy, then latency fill (×2 / ÷2
        // per epoch). Splitting to feed idle ports takes priority over
        // coalescing for latency; coalescing never drops below port count.
        let mean = sig.mean_frame_bytes();
        if sig.n_buckets < sig.ports && knobs.bucket_bytes > 1 {
            let new = (knobs.bucket_bytes / 2).max(1);
            out.push(decision(
                epoch,
                "bucket_bytes",
                knobs.bucket_bytes as f64,
                new as f64,
                format!("n_buckets={}<ports={}", sig.n_buckets, sig.ports),
            ));
            knobs.bucket_bytes = new;
        } else if sig.n_buckets > sig.ports.max(1)
            && mean < COALESCE_FILL * self.auto_bytes as f64
            && knobs.bucket_bytes < self.thr_max
        {
            let new = knobs.bucket_bytes.saturating_mul(2).min(self.thr_max);
            out.push(decision(
                epoch,
                "bucket_bytes",
                knobs.bucket_bytes as f64,
                new as f64,
                format!(
                    "mean_frame={mean:.0}B<{:.0}B (α·β fill)",
                    COALESCE_FILL * self.auto_bytes as f64
                ),
            ));
            knobs.bucket_bytes = new;
        }

        // 3. Per-layer L_T ← wire share vs compute-proxy share (×2 / ÷2
        // per layer per epoch, clamped to the exploration band).
        let total_bytes: u64 = sig.layer_bytes.iter().sum();
        let total_elems: usize = self.layer_elems.iter().sum();
        if total_bytes > 0
            && total_elems > 0
            && knobs.lts.len() == self.layer_elems.len()
            && sig.layer_bytes.len() == self.layer_elems.len()
        {
            for l in 0..knobs.lts.len() {
                let comm = sig.layer_bytes[l] as f64 / total_bytes as f64;
                let elems = self.layer_elems[l] as f64 / total_elems as f64;
                let lt = knobs.lts[l];
                if comm > LT_HOT_RATIO * elems && comm > LT_MIN_SHARE && lt < self.lt_hi[l] {
                    let new = lt.saturating_mul(2).min(self.lt_hi[l]);
                    out.push(decision(
                        epoch,
                        &format!("lt:{l}"),
                        lt as f64,
                        new as f64,
                        format!("comm_share={comm:.3} vs elems_share={elems:.3} (hot)"),
                    ));
                    knobs.lts[l] = new;
                } else if comm < LT_COLD_RATIO * elems && lt > self.lt_lo[l] {
                    let new = (lt / 2).max(self.lt_lo[l]);
                    out.push(decision(
                        epoch,
                        &format!("lt:{l}"),
                        lt as f64,
                        new as f64,
                        format!("comm_share={comm:.3} vs elems_share={elems:.3} (cold)"),
                    ));
                    knobs.lts[l] = new;
                }
            }
        }
        out
    }
}

fn decision(epoch: usize, knob: &str, old: f64, new: f64, signal: String) -> ControlDecision {
    ControlDecision {
        epoch,
        knob: knob.to_string(),
        old,
        new,
        signal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LayerKind;

    fn layout() -> Layout {
        Layout::from_specs(&[
            ("w1", &[2000], LayerKind::Fc),
            ("b1", &[20], LayerKind::Fc),
            ("w2", &[1500], LayerKind::Fc),
            ("b2", &[10], LayerKind::Fc),
        ])
    }

    fn knobs() -> Knobs {
        Knobs {
            staleness: 1,
            bucket_bytes: 4096,
            lts: vec![50, 50, 50, 50],
        }
    }

    fn quiet_signals() -> EpochSignals {
        // an epoch with no straggler pressure, balanced layers, and frames
        // big enough to amortize latency: every rule holds
        let mut sig = EpochSignals::new(4);
        sig.steps = 10;
        sig.learners = 4;
        sig.jitter_excess = 10.0 * 0.06; // inside the [0.04, 0.10] band
        sig.n_buckets = 2;
        sig.ports = 1;
        // shares proportional to element counts (scaled ×100 bytes/elem so
        // mean_frame clears the coalesce band)
        sig.layer_bytes = vec![200_000, 2_000, 150_000, 1_000];
        sig
    }

    #[test]
    fn mode_parse_validates_with_valid_list() {
        assert!(!parse_mode("off").unwrap());
        assert!(parse_mode("on").unwrap());
        for bad in ["ON", "auto", ""] {
            let err = parse_mode(bad).unwrap_err().to_string();
            assert!(err.contains("valid: off, on"), "{bad}: {err}");
        }
    }

    #[test]
    fn staleness_cap_bounds() {
        assert_eq!(staleness_cap(0), 2);
        assert_eq!(staleness_cap(1), 3);
        assert_eq!(staleness_cap(2), 4);
        assert_eq!(staleness_cap(4), 8);
        // capped at MAX_STALENESS
        assert_eq!(staleness_cap(12), crate::train::engine::MAX_STALENESS);
    }

    #[test]
    fn hysteresis_band_holds_every_rule() {
        let layout = layout();
        let mut k = knobs();
        let ctrl = Controller::new(&layout, &k, 4, &LinkModel::default());
        let sig = quiet_signals();
        let before = k.clone();
        assert!(ctrl.retune(0, &sig, &mut k).is_empty());
        assert_eq!(k, before);
        // an empty epoch (no steps folded) never decides anything
        let mut empty = EpochSignals::new(4);
        empty.n_buckets = 1;
        empty.ports = 4; // would trip the split rule if steps > 0
        assert!(ctrl.retune(1, &empty, &mut k).is_empty());
    }

    #[test]
    fn staleness_widens_narrows_and_clamps() {
        let layout = layout();
        let mut k = knobs();
        let ctrl = Controller::new(&layout, &k, 2, &LinkModel::default());
        let mut sig = quiet_signals();
        // heavy straggler pressure: widen +1 per epoch up to the cap
        sig.jitter_excess = sig.steps as f64 * 0.3;
        let d = ctrl.retune(0, &sig, &mut k);
        assert_eq!(k.staleness, 2);
        assert_eq!(d[0].knob, "staleness");
        assert!(d[0].signal.contains("straggler_excess"), "{}", d[0].signal);
        // at the cap: hold
        assert!(ctrl
            .retune(1, &sig, &mut k)
            .iter()
            .all(|d| d.knob != "staleness"));
        // pressure gone: narrow back one per epoch, clamp at 0
        sig.jitter_excess = 0.0;
        for want in [1usize, 0, 0] {
            ctrl.retune(2, &sig, &mut k);
            assert_eq!(k.staleness, want);
        }
    }

    #[test]
    fn bucket_rule_splits_for_idle_ports_and_coalesces_small_frames() {
        let layout = layout();
        let mut k = knobs();
        let ctrl = Controller::new(&layout, &k, 4, &LinkModel::default());
        // idle ports: 2 buckets on a 4-port fabric -> halve the threshold
        let mut sig = quiet_signals();
        sig.ports = 4;
        let d = ctrl.retune(0, &sig, &mut k);
        assert_eq!(k.bucket_bytes, 2048);
        assert!(d.iter().any(|d| d.knob == "bucket_bytes"
            && d.signal.contains("n_buckets=2<ports=4")));
        // latency-starved frames on a saturated fabric -> double it
        let mut sig = quiet_signals();
        sig.layer_bytes = vec![4000, 40, 3000, 20]; // mean frame ~88B << α·β/2
        let d = ctrl.retune(1, &sig, &mut k);
        assert_eq!(k.bucket_bytes, 4096);
        assert!(d.iter().any(|d| d.knob == "bucket_bytes"
            && d.signal.contains("α·β fill")));
        // never coalesces past the whole-model dense size
        let mut big = Knobs {
            bucket_bytes: usize::MAX / 4,
            ..knobs()
        };
        let before = big.bucket_bytes;
        ctrl.retune(2, &sig, &mut big);
        assert!(big.bucket_bytes <= before, "clamped at whole-model bytes");
        // never splits below 1, and never coalesces below the port count
        let mut sig = quiet_signals();
        sig.n_buckets = 1;
        sig.ports = 1;
        sig.layer_bytes = vec![40, 4, 30, 2];
        let before = k.clone();
        assert!(ctrl
            .retune(3, &sig, &mut k)
            .iter()
            .all(|d| d.knob != "bucket_bytes"));
        assert_eq!(k.bucket_bytes, before.bucket_bytes);
    }

    #[test]
    fn lt_adapts_per_layer_within_the_band() {
        let layout = layout();
        let mut k = knobs();
        let ctrl = Controller::new(&layout, &k, 4, &LinkModel::default());
        // layer 1 (tiny bias) carries half the wire bytes: comm-hot, its
        // L_T doubles; layer 0 (big weight) is comm-cold, its L_T halves
        let mut sig = quiet_signals();
        sig.layer_bytes = vec![10_000, 200_000, 150_000, 40_000];
        let d = ctrl.retune(0, &sig, &mut k);
        assert_eq!(k.lts, vec![25, 100, 50, 50]);
        assert!(d.iter().any(|d| d.knob == "lt:1" && d.signal.contains("hot")));
        assert!(d.iter().any(|d| d.knob == "lt:0" && d.signal.contains("cold")));
        // repeated pressure saturates at the 8x band, never beyond
        for e in 1..12 {
            ctrl.retune(e, &sig, &mut k);
        }
        assert_eq!(k.lts[1], 400); // 50 * 8
        assert_eq!(k.lts[0], 6); // 50 / 8
        // schemes without L_T (empty table): rule skipped entirely
        let mut none = Knobs {
            lts: Vec::new(),
            ..knobs()
        };
        assert!(ctrl
            .retune(0, &sig, &mut none)
            .iter()
            .all(|d| !d.knob.starts_with("lt:")));
    }

    #[test]
    fn retune_is_a_pure_function_of_its_inputs() {
        let layout = layout();
        let ctrl = Controller::new(&layout, &knobs(), 4, &LinkModel::default());
        let mut sig = quiet_signals();
        sig.jitter_excess = sig.steps as f64 * 0.2;
        sig.layer_bytes = vec![10_000, 200_000, 150_000, 40_000];
        let (mut a, mut b) = (knobs(), knobs());
        let da = ctrl.retune(3, &sig, &mut a);
        let db = ctrl.retune(3, &sig, &mut b);
        assert_eq!(a, b);
        assert_eq!(da, db);
        assert!(!da.is_empty());
    }

    #[test]
    fn signals_fold_steps_and_packets() {
        let mut sig = EpochSignals::new(2);
        sig.note_step(&[1.0, 1.3, 1.1]);
        sig.note_step(&[1.2, 1.0, 1.1]);
        assert_eq!(sig.steps, 2);
        assert_eq!(sig.learners, 3);
        // per-step max − mean, summed
        let expect = (1.3 - (1.0 + 1.3 + 1.1) / 3.0) + (1.2 - (1.2 + 1.0 + 1.1) / 3.0);
        assert!((sig.jitter_excess - expect).abs() < 1e-12);
        sig.note_packet(0, 100);
        sig.note_packet(1, 50);
        sig.note_packet(0, 25);
        assert_eq!(sig.layer_bytes, vec![125, 50]);
        sig.n_buckets = 1;
        // 2 steps * 3 learners * 1 bucket = 6 frames, 175 bytes total
        assert!((sig.mean_frame_bytes() - 175.0 / 6.0).abs() < 1e-12);
        sig.reset();
        assert_eq!(sig.steps, 0);
        assert_eq!(sig.layer_bytes, vec![0, 0]);
    }
}
