//! The distributed training coordinator (paper Algorithm 1).

pub mod checkpoint;
pub mod churn;
pub mod control;
pub mod engine;
pub mod eval;
pub mod learner;
pub mod pool;

pub use control::{Controller, EpochSignals, Knobs};
pub use engine::{
    kernel_thread_budget, validate_kernel_threads, validate_window, Engine, ExchangeMode,
    TrainConfig, MAX_STALENESS,
};
