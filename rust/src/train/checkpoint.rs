//! Checkpointing: save/restore flat parameters (+ run provenance) so long
//! paper-scale runs can resume across sessions, and — since the elastic
//! fleet — carry per-learner residual gradients and central optimizer
//! momentum so a departing learner can hand its error-feedback state to the
//! survivors instead of losing it.
//!
//! Format (little-endian):
//!   magic  "ADCK"  u32
//!   version        u32   (1 = params only, 2 = + state sections)
//!   epoch          u32
//!   model name     u32 len + bytes
//!   params         u64 count + count x f32
//!   checksum       u64 (FNV-1a over the param bytes)
//! v2 appends, after the param checksum:
//!   residues       u32 learner count, then per learner u64 count + f32s
//!   momentum       u64 count + count x f32
//!   checksum       u64 (FNV-1a over the section bytes)
//!
//! A checkpoint with no state sections always writes version 1, so plain
//! `--save` files stay readable by older builds; version-1 files load with
//! empty sections. Versions above 2 are rejected (future-format guard).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: u32 = 0x4144_434b; // "ADCK"
const VERSION: u32 = 1;
const VERSION_STATE: u32 = 2;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub epoch: u32,
    pub params: Vec<f32>,
    /// Per-learner residual-gradient state (flat, layout order); empty for
    /// plain parameter checkpoints.
    pub residues: Vec<Vec<f32>>,
    /// Central optimizer state (e.g. SGD velocity, Adam moments); empty for
    /// plain parameter checkpoints.
    pub momentum: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_to_bytes(vals: &[f32], out: &mut Vec<u8>) {
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn f32s_from_bytes(body: &[u8]) -> Vec<f32> {
    body.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

impl Checkpoint {
    /// A plain parameter checkpoint (no handover state sections).
    pub fn new(model: String, epoch: u32, params: Vec<f32>) -> Checkpoint {
        Checkpoint {
            model,
            epoch,
            params,
            residues: Vec::new(),
            momentum: Vec::new(),
        }
    }

    fn has_state(&self) -> bool {
        !self.residues.is_empty() || !self.momentum.is_empty()
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let version = if self.has_state() { VERSION_STATE } else { VERSION };
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&version.to_le_bytes())?;
        w.write_all(&self.epoch.to_le_bytes())?;
        w.write_all(&(self.model.len() as u32).to_le_bytes())?;
        w.write_all(self.model.as_bytes())?;
        w.write_all(&(self.params.len() as u64).to_le_bytes())?;
        let mut body = Vec::with_capacity(self.params.len() * 4);
        f32s_to_bytes(&self.params, &mut body);
        w.write_all(&body)?;
        w.write_all(&fnv1a(&body).to_le_bytes())?;
        if version >= VERSION_STATE {
            let mut sect = Vec::new();
            sect.extend_from_slice(&(self.residues.len() as u32).to_le_bytes());
            for r in &self.residues {
                sect.extend_from_slice(&(r.len() as u64).to_le_bytes());
                f32s_to_bytes(r, &mut sect);
            }
            sect.extend_from_slice(&(self.momentum.len() as u64).to_le_bytes());
            f32s_to_bytes(&self.momentum, &mut sect);
            w.write_all(&sect)?;
            w.write_all(&fnv1a(&sect).to_le_bytes())?;
        }
        Ok(())
    }

    /// `src` labels errors (a path for files, a placeholder for in-memory
    /// handover bytes).
    pub fn read_from<R: Read>(f: &mut R, src: &str) -> Result<Checkpoint> {
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u32buf)?;
        if u32::from_le_bytes(u32buf) != MAGIC {
            bail!("{src}: not an adacomp checkpoint");
        }
        f.read_exact(&mut u32buf)?;
        let version = u32::from_le_bytes(u32buf);
        if version < VERSION || version > VERSION_STATE {
            bail!("{src}: unsupported checkpoint version {version}");
        }
        f.read_exact(&mut u32buf)?;
        let epoch = u32::from_le_bytes(u32buf);
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        if name_len > 4096 {
            bail!("{src}: implausible model-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        f.read_exact(&mut u64buf)?;
        let count = u64::from_le_bytes(u64buf) as usize;
        let mut body = vec![0u8; count * 4];
        f.read_exact(&mut body)?;
        f.read_exact(&mut u64buf)?;
        let want = u64::from_le_bytes(u64buf);
        if want != fnv1a(&body) {
            bail!("{src}: checksum mismatch (corrupt checkpoint)");
        }
        let params = f32s_from_bytes(&body);

        let mut residues = Vec::new();
        let mut momentum = Vec::new();
        if version >= VERSION_STATE {
            // re-serialize while reading so the section checksum covers
            // exactly the bytes the writer hashed
            let mut sect = Vec::new();
            f.read_exact(&mut u32buf)?;
            sect.extend_from_slice(&u32buf);
            let n_res = u32::from_le_bytes(u32buf) as usize;
            if n_res > 1 << 20 {
                bail!("{src}: implausible residue-section count {n_res}");
            }
            for _ in 0..n_res {
                f.read_exact(&mut u64buf)?;
                sect.extend_from_slice(&u64buf);
                let rc = u64::from_le_bytes(u64buf) as usize;
                let mut rb = vec![0u8; rc * 4];
                f.read_exact(&mut rb)?;
                residues.push(f32s_from_bytes(&rb));
                sect.extend_from_slice(&rb);
            }
            f.read_exact(&mut u64buf)?;
            sect.extend_from_slice(&u64buf);
            let mc = u64::from_le_bytes(u64buf) as usize;
            let mut mb = vec![0u8; mc * 4];
            f.read_exact(&mut mb)?;
            momentum = f32s_from_bytes(&mb);
            sect.extend_from_slice(&mb);
            f.read_exact(&mut u64buf)?;
            if u64::from_le_bytes(u64buf) != fnv1a(&sect) {
                bail!("{src}: state-section checksum mismatch (corrupt checkpoint)");
            }
        }
        Ok(Checkpoint {
            model: String::from_utf8(name)?,
            epoch,
            params,
            residues,
            momentum,
        })
    }

    /// Serialize to the exact on-disk byte format (handover paths round-trip
    /// state through real checkpoint bytes, not a shortcut copy).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        // Vec<u8> writes are infallible
        self.write_to(&mut out).expect("in-memory checkpoint write");
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut cur = bytes;
        Self::read_from(&mut cur, "<bytes>")
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        self.write_to(&mut f)
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::read_from(&mut f, &path.display().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adacomp-ckpt-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("roundtrip");
        let ck = Checkpoint::new(
            "cifar_cnn".into(),
            17,
            (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect(),
        );
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_state_sections_roundtrip() {
        let p = tmp("v2");
        let ck = Checkpoint {
            model: "mnist_dnn".into(),
            epoch: 3,
            params: vec![1.0, -2.0, 0.5],
            residues: vec![vec![0.25, -0.5, 0.0], vec![1.5, 0.0, -0.125]],
            momentum: vec![0.1, 0.2, 0.3],
        };
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        // in-memory bytes are the same format
        let back2 = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn plain_checkpoints_stay_version_1() {
        // no state sections -> v1 bytes, so older readers still load them
        let ck = Checkpoint::new("m".into(), 0, vec![1.0; 8]);
        let bytes = ck.to_bytes();
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);
        // and a v1 file loads with empty sections
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert!(back.residues.is_empty() && back.momentum.is_empty());
        // state sections bump to v2
        let ck2 = Checkpoint {
            residues: vec![vec![0.5; 8]],
            ..ck
        };
        let bytes2 = ck2.to_bytes();
        assert_eq!(u32::from_le_bytes([bytes2[4], bytes2[5], bytes2[6], bytes2[7]]), 2);
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        let ck = Checkpoint::new("m".into(), 0, vec![1.0; 64]);
        ck.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_state_section_corruption() {
        let ck = Checkpoint {
            model: "m".into(),
            epoch: 0,
            params: vec![1.0; 16],
            residues: vec![vec![2.0; 16]],
            momentum: vec![3.0; 16],
        };
        let mut bytes = ck.to_bytes();
        // flip a byte inside the momentum data (after params + their checksum)
        let in_momentum = bytes.len() - 8 - 16 * 4 + 2;
        bytes[in_momentum] ^= 0xff;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("not an adacomp checkpoint"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let p = tmp("truncated");
        let ck = Checkpoint::new("m".into(), 2, vec![0.5; 128]);
        ck.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // cut mid-params and mid-header
        for cut in [bytes.len() - 20, 10] {
            std::fs::write(&p, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&p).is_err(), "cut at {cut}");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_magic_and_future_version() {
        let ck = Checkpoint::new("m".into(), 0, vec![1.0; 4]);
        let good = ck.to_bytes();
        // wrong magic
        let mut bad = good.clone();
        bad[0] ^= 0x55;
        let err = Checkpoint::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("not an adacomp checkpoint"), "{err}");
        // future version (3) must be rejected, not misparsed
        let mut fut = good;
        fut[4..8].copy_from_slice(&3u32.to_le_bytes());
        let err = Checkpoint::from_bytes(&fut).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 3"), "{err}");
    }
}
