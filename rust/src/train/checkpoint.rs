//! Checkpointing: save/restore flat parameters (+ run provenance) so long
//! paper-scale runs can resume across sessions.
//!
//! Format (little-endian):
//!   magic  "ADCK"  u32
//!   version        u32
//!   epoch          u32
//!   model name     u32 len + bytes
//!   params         u64 count + count x f32
//!   checksum       u64 (FNV-1a over the param bytes)

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: u32 = 0x4144_434b; // "ADCK"
const VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub epoch: u32,
    pub params: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&MAGIC.to_le_bytes())?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.epoch.to_le_bytes())?;
        f.write_all(&(self.model.len() as u32).to_le_bytes())?;
        f.write_all(self.model.as_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        let mut body = Vec::with_capacity(self.params.len() * 4);
        for &v in &self.params {
            body.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&body)?;
        f.write_all(&fnv1a(&body).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u32buf)?;
        if u32::from_le_bytes(u32buf) != MAGIC {
            bail!("{}: not an adacomp checkpoint", path.display());
        }
        f.read_exact(&mut u32buf)?;
        let version = u32::from_le_bytes(u32buf);
        if version != VERSION {
            bail!("{}: unsupported checkpoint version {version}", path.display());
        }
        f.read_exact(&mut u32buf)?;
        let epoch = u32::from_le_bytes(u32buf);
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        if name_len > 4096 {
            bail!("{}: implausible model-name length {name_len}", path.display());
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        f.read_exact(&mut u64buf)?;
        let count = u64::from_le_bytes(u64buf) as usize;
        let mut body = vec![0u8; count * 4];
        f.read_exact(&mut body)?;
        f.read_exact(&mut u64buf)?;
        let want = u64::from_le_bytes(u64buf);
        let got = fnv1a(&body);
        if want != got {
            bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
        }
        let params = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint {
            model: String::from_utf8(name)?,
            epoch,
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adacomp-ckpt-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("roundtrip");
        let ck = Checkpoint {
            model: "cifar_cnn".into(),
            epoch: 17,
            params: (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect(),
        };
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        let ck = Checkpoint {
            model: "m".into(),
            epoch: 0,
            params: vec![1.0; 64],
        };
        ck.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
