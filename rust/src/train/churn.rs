//! Deterministic membership schedules for the elastic fleet.
//!
//! Two sources of membership events, both resolved to an explicit sorted
//! event list **before** the run starts so every exchange mode and thread
//! count sees the identical schedule:
//!
//! * `--churn "fail@120:2,join@300:1"` — explicit scripted events. `fail`
//!   drops learners and their residual state (gradient mass is lost),
//!   `leave` drops learners after handing their residual state to the
//!   survivors through a v2 [`Checkpoint`](super::checkpoint::Checkpoint),
//!   `join` adds cold learners.
//! * `--mtbf M` — a seeded random-failure process: each step fails one
//!   learner with probability 1/M. The draw is a pure function of
//!   (seed, step) — the same xorshift64* generator family the jitter model
//!   uses, under a distinct salt — so an MTBF run is exactly as
//!   reproducible as a scripted one.
//!
//! An event at step `s` is applied at the step boundary **before** step `s`
//! runs; the engine drains the staleness window to the frontier first (all
//! updates `< s` applied, no step `>= s` started).

use anyhow::{bail, Result};

/// Valid-form list for churn spec errors (the `topology::build` pattern).
pub const VALID: &str =
    "valid: comma-separated fail@STEP:K | join@STEP:K | leave@STEP:K, K >= 1";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Learners vanish; their residual + momentum state is lost.
    Fail,
    /// Cold learners join the fleet.
    Join,
    /// Learners depart gracefully, handing state to the survivors.
    Leave,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Fail => "fail",
            EventKind::Join => "join",
            EventKind::Leave => "leave",
        }
    }
}

/// One membership event: `count` learners `kind` at the boundary before
/// global step `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub step: usize,
    pub kind: EventKind,
    pub count: usize,
}

/// Parse a `--churn` spec into events sorted by step (stable — same-step
/// events keep their spec order). Empty spec = no events. Errors carry the
/// valid-form list.
pub fn parse(spec: &str) -> Result<Vec<Event>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (kind_s, rest) = part.split_once('@').ok_or_else(|| {
            anyhow::anyhow!("churn event '{part}': missing '@' ({VALID})")
        })?;
        let kind = match kind_s {
            "fail" => EventKind::Fail,
            "join" => EventKind::Join,
            "leave" => EventKind::Leave,
            other => bail!("churn event '{part}': unknown kind '{other}' ({VALID})"),
        };
        let (step_s, count_s) = rest.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("churn event '{part}': missing ':COUNT' ({VALID})")
        })?;
        let step: usize = step_s.parse().map_err(|_| {
            anyhow::anyhow!("churn event '{part}': '{step_s}' is not a step number ({VALID})")
        })?;
        let count: usize = count_s.parse().map_err(|_| {
            anyhow::anyhow!("churn event '{part}': '{count_s}' is not a learner count ({VALID})")
        })?;
        if count < 1 {
            bail!("churn event '{part}': count must be >= 1 ({VALID})");
        }
        out.push(Event { step, kind, count });
    }
    out.sort_by_key(|e| e.step);
    Ok(out)
}

fn xorshift64star(mut x: u64) -> u64 {
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// Whether the MTBF process fails one learner at step `step`: a
/// deterministic draw with probability `1/mtbf`, salted away from the
/// jitter stream (`mtbf == 0` disables the process).
pub fn mtbf_fails(mtbf: u64, seed: u64, step: u64) -> bool {
    if mtbf == 0 {
        return false;
    }
    let x = xorshift64star(
        seed ^ 0x6d74_6266 ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1),
    );
    let u = (x >> 11) as f64 / (1u64 << 53) as f64;
    u < 1.0 / mtbf as f64
}

/// Resolve the run's full membership schedule: scripted `--churn` events
/// merged with the MTBF failure draws for every step in `0..total_steps`,
/// sorted by step. Materializing the MTBF draws up front keeps the worker
/// pool's epoch frontier computable before the steps run.
pub fn schedule(spec: &str, mtbf: u64, seed: u64, total_steps: usize) -> Result<Vec<Event>> {
    let mut events = parse(spec)?;
    if mtbf > 0 {
        for step in 0..total_steps {
            if mtbf_fails(mtbf, seed, step as u64) {
                events.push(Event {
                    step,
                    kind: EventKind::Fail,
                    count: 1,
                });
            }
        }
        events.sort_by_key(|e| e.step);
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_sorts_events() {
        let ev = parse("join@300:1, fail@120:2,leave@500:1").unwrap();
        assert_eq!(
            ev,
            vec![
                Event { step: 120, kind: EventKind::Fail, count: 2 },
                Event { step: 300, kind: EventKind::Join, count: 1 },
                Event { step: 500, kind: EventKind::Leave, count: 1 },
            ]
        );
        assert!(parse("").unwrap().is_empty());
        assert!(parse("  ").unwrap().is_empty());
        // same-step events keep spec order (stable sort)
        let ev = parse("fail@10:1,join@10:2").unwrap();
        assert_eq!(ev[0].kind, EventKind::Fail);
        assert_eq!(ev[1].kind, EventKind::Join);
    }

    #[test]
    fn rejects_malformed_specs_with_valid_forms() {
        for bad in [
            "fail@120",      // missing count
            "fail:120:2",    // missing @
            "explode@9:1",   // unknown kind
            "fail@x:1",      // bad step
            "fail@9:x",      // bad count
            "fail@9:0",      // zero count
            "join@:1",       // empty step
        ] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(err.contains("fail@STEP:K"), "{bad}: {err}");
            assert!(err.contains(bad.split(',').next().unwrap().trim()), "{bad}: {err}");
        }
    }

    #[test]
    fn mtbf_draws_are_deterministic_and_rate_plausible() {
        assert!(!mtbf_fails(0, 7, 3), "mtbf 0 disables the process");
        let fails: Vec<bool> = (0..10_000).map(|s| mtbf_fails(100, 42, s)).collect();
        let again: Vec<bool> = (0..10_000).map(|s| mtbf_fails(100, 42, s)).collect();
        assert_eq!(fails, again, "same (seed, step) must draw the same");
        let n = fails.iter().filter(|&&f| f).count();
        // expectation 100 over 10k steps; allow a generous band
        assert!(n > 40 && n < 250, "observed {n} failures at mtbf 100");
        // a different seed draws a different timeline
        let other: Vec<bool> = (0..10_000).map(|s| mtbf_fails(100, 43, s)).collect();
        assert_ne!(fails, other);
    }

    #[test]
    fn schedule_merges_scripted_and_mtbf_events() {
        let ev = schedule("fail@5:1", 0, 1, 100).unwrap();
        assert_eq!(ev.len(), 1);
        // tiny mtbf: most steps fail — merged list stays step-sorted
        let ev = schedule("join@50:2", 3, 9, 100).unwrap();
        assert!(ev.iter().any(|e| e.kind == EventKind::Join));
        assert!(ev.iter().any(|e| e.kind == EventKind::Fail));
        for w in ev.windows(2) {
            assert!(w[0].step <= w[1].step);
        }
        assert!(schedule("bogus", 0, 1, 10).is_err());
    }
}
