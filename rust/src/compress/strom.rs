//! Strom 2015 — fixed absolute-threshold residual compression.
//!
//! Elements of G = residue + dW with |G| > tau are transmitted as +/- tau;
//! the residue keeps G -/+ tau (only tau is subtracted, not the full value).
//! The paper's critique: tau is a brittle global hyper-parameter ("these
//! papers do not discuss techniques for determining an optimal threshold").

use super::{residue::ResidueStore, wire, BufPool, Compressor, Config, Kind, Packet};
use crate::models::Layout;

pub struct Strom {
    residues: ResidueStore,
    tau: f32,
    pool: BufPool,
}

impl Strom {
    pub fn new(cfg: &Config, layout: &Layout) -> Strom {
        Strom {
            residues: ResidueStore::new(layout),
            tau: cfg.strom_tau,
            pool: BufPool::default(),
        }
    }
}

impl Compressor for Strom {
    fn kind(&self) -> Kind {
        Kind::Strom
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        self.residues.fold(layer, dw);
        let r = self.residues.layer_mut(layer);
        let n = r.len();
        let tau = self.tau;

        let (mut idx, mut val) = self.pool.take();
        for (i, g) in r.iter_mut().enumerate() {
            if *g > tau {
                idx.push(i as u32);
                val.push(tau);
                *g -= tau;
            } else if *g < -tau {
                idx.push(i as u32);
                val.push(-tau);
                *g += tau;
            }
        }

        let wire_bytes = wire::sparse_sign_wire_len(idx.len());
        let paper_bits = idx.len() * 32 + 32;
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes,
            paper_bits,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.residues.layer(layer)
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        Some(self.residues.layer_mut(layer))
    }

    fn reset(&mut self) {
        self.residues.reset();
    }

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};

    fn make(n: usize, tau: f32) -> Strom {
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Fc)]);
        let cfg = Config {
            strom_tau: tau,
            ..Config::with_kind(Kind::Strom)
        };
        Strom::new(&cfg, &layout)
    }

    #[test]
    fn wire_roundtrip_bitwise() {
        // strom packets are +/- tau (shared magnitude): both v2 sparse
        // forms apply and the encoder picks the smaller; bit-exact
        // round-trip, measured <= analytic
        let mut c = make(5000, 0.8);
        let mut rng = crate::util::rng::Pcg32::seeded(22);
        let dw = rng.normal_vec(5000, 1.0);
        let p = c.pack_layer(0, &dw);
        assert!(p.sent() > 0);
        let bytes = super::super::wire::encode_packet(&p).unwrap();
        let q = super::super::wire::decode(&bytes).unwrap();
        assert_eq!(q.idx, p.idx);
        assert_eq!(q.val, p.val);
        assert!(bytes.len() <= p.wire_bytes, "measured {} > analytic {}", bytes.len(), p.wire_bytes);
    }

    #[test]
    fn only_above_threshold_sent() {
        let mut c = make(5, 1.0);
        let p = c.pack_layer(0, &[0.5, 1.5, -2.0, -0.9, 1.0]);
        assert_eq!(p.idx, vec![1, 2]);
        assert_eq!(p.val, vec![1.0, -1.0]);
    }

    #[test]
    fn residue_keeps_excess() {
        let mut c = make(2, 1.0);
        c.pack_layer(0, &[2.5, -3.0]);
        assert!((c.residue(0)[0] - 1.5).abs() < 1e-6);
        assert!((c.residue(0)[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn repeated_steps_drain_residue() {
        // large one-off gradient drains tau per step
        let mut c = make(1, 1.0);
        c.pack_layer(0, &[5.0]); // sends tau, residue 4.0
        for _ in 0..3 {
            let p = c.pack_layer(0, &[0.0]);
            assert_eq!(p.sent(), 1);
        }
        // residue is now exactly tau; |G| > tau is strict, so nothing moves
        let p = c.pack_layer(0, &[0.0]);
        assert_eq!(p.sent(), 0);
        assert!((c.residue(0)[0] - 1.0).abs() < 1e-6);
    }
}
