//! Local Selection (LS) — the paper's ablation of AdaComp (Fig 4/5/6):
//! identical bin structure and ternary quantization, but *no soft threshold*.
//! Each bin transmits exactly its max-|G| element. The paper shows this
//! scheme's residual gradients explode at high compression rates because the
//! fixed one-per-bin budget cannot adapt to layers/steps that need more.

use super::{residue::ResidueStore, wire, BufPool, Compressor, Config, Kind, Packet};
use crate::models::Layout;

pub struct LocalSelect {
    residues: ResidueStore,
    lts: Vec<usize>,
    per_bin_scale: bool,
    gmax: Vec<f32>,
    arg: Vec<u32>,
    pool: BufPool,
}

impl LocalSelect {
    pub fn new(cfg: &Config, layout: &Layout) -> LocalSelect {
        LocalSelect {
            residues: ResidueStore::new(layout),
            lts: layout.layers.iter().map(|l| cfg.lt_for(l.kind).max(1)).collect(),
            per_bin_scale: cfg.per_bin_scale,
            gmax: Vec::new(),
            arg: Vec::new(),
            pool: BufPool::default(),
        }
    }
}

impl Compressor for LocalSelect {
    fn kind(&self) -> Kind {
        Kind::LocalSelect
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        let lt = self.lts[layer];
        let r = self.residues.layer_mut(layer);
        let n = r.len();
        assert_eq!(dw.len(), n);
        let nbins = n.div_ceil(lt);

        self.gmax.clear();
        self.arg.clear();
        for b in 0..nbins {
            let lo = b * lt;
            let hi = ((b + 1) * lt).min(n);
            let mut m = 0.0f32;
            let mut am = lo;
            for i in lo..hi {
                let g = r[i] + dw[i];
                r[i] = g;
                if g.abs() > m {
                    m = g.abs();
                    am = i;
                }
            }
            self.gmax.push(m);
            self.arg.push(am as u32);
        }
        let scale = self.gmax.iter().sum::<f32>() / nbins as f32;

        let (mut idx, mut val) = self.pool.take();
        for b in 0..nbins {
            let gm = self.gmax[b];
            if gm <= 0.0 {
                continue;
            }
            let i = self.arg[b] as usize;
            let q = if self.per_bin_scale { gm } else { scale };
            let sent = if r[i] > 0.0 { q } else { -q }; // |r[i]| = gm > 0
            idx.push(i as u32);
            val.push(sent);
            r[i] -= sent;
        }

        let wire_bytes = wire::adacomp_wire_len(n, lt, idx.len());
        let paper_bits = idx.len() * wire::slot_bits(lt) + 32;
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes,
            paper_bits,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.residues.layer(layer)
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        Some(self.residues.layer_mut(layer))
    }

    fn reset(&mut self) {
        self.residues.reset();
    }

    fn set_layer_lt(&mut self, layer: usize, lt: usize) {
        self.lts[layer] = lt.max(1);
    }

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};
    use crate::util::rng::Pcg32;

    #[test]
    fn wire_roundtrip_bitwise() {
        // ls packets are ternary like adacomp's; the engine's v2 sparse
        // wire form must reproduce them bit-exactly
        let layout = Layout::from_specs(&[("w", &[2000], LayerKind::Fc)]);
        let cfg = Config { lt_override: 500, ..Config::with_kind(Kind::LocalSelect) };
        let mut c = LocalSelect::new(&cfg, &layout);
        let mut rng = Pcg32::seeded(23);
        let dw = rng.normal_vec(2000, 1.0);
        let p = c.pack_layer(0, &dw);
        assert!(p.sent() > 0);
        let bytes = super::super::wire::encode_packet(&p).unwrap();
        let q = super::super::wire::decode(&bytes).unwrap();
        assert_eq!(q.idx, p.idx);
        assert_eq!(q.val, p.val);
        assert!(bytes.len() <= p.wire_bytes, "measured {} > analytic {}", bytes.len(), p.wire_bytes);
    }

    #[test]
    fn sends_exactly_one_per_nonzero_bin() {
        let layout = Layout::from_specs(&[("w", &[1000], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: 10,
            ..Config::with_kind(Kind::LocalSelect)
        };
        let mut c = LocalSelect::new(&cfg, &layout);
        let mut rng = Pcg32::seeded(1);
        let dw = rng.normal_vec(1000, 1.0);
        let p = c.pack_layer(0, &dw);
        assert_eq!(p.sent(), 100); // one per bin
    }

    #[test]
    fn conservation() {
        let layout = Layout::from_specs(&[("w", &[512], LayerKind::Fc)]);
        let cfg = Config {
            lt_override: 64,
            ..Config::with_kind(Kind::LocalSelect)
        };
        let mut c = LocalSelect::new(&cfg, &layout);
        let mut rng = Pcg32::seeded(2);
        let dw = rng.normal_vec(512, 0.3);
        let p = c.pack_layer(0, &dw);
        let mut recon = c.residue(0).to_vec();
        p.add_into(&mut recon);
        for (a, b) in recon.iter().zip(dw.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn residue_grows_without_adaptation() {
        // Feed a gradient whose bins have many similar-magnitude elements:
        // LS sends 1/bin so unsent mass accumulates linearly (the Fig 5
        // mechanism, before the divergence feedback kicks in via training).
        let layout = Layout::from_specs(&[("w", &[100], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: 50,
            ..Config::with_kind(Kind::LocalSelect)
        };
        let mut c = LocalSelect::new(&cfg, &layout);
        let dw: Vec<f32> = (0..100).map(|i| 1.0 + 0.001 * i as f32).collect();
        let mut prev = 0.0;
        for _ in 0..10 {
            c.pack_layer(0, &dw);
            let norm: f32 = c.residue(0).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm >= prev * 0.9);
            prev = norm;
        }
        assert!(prev > 5.0, "residue norm should accumulate, got {prev}");
    }
}
