//! Dryden et al. 2016 — global top-pi% residual-gradient selection with
//! 1-bit quantization (positive/negative reconstruction means).
//!
//! The paper's critique: requires (approximately) sorting the full residue
//! vector. We implement the threshold search with quickselect over a scratch
//! copy — O(N) expected, no full sort — which is the strongest practical
//! version of the baseline (an exact top-k).

use super::{quantize, residue::ResidueStore, wire, BufPool, Compressor, Config, Kind, Packet};
use crate::models::Layout;
use crate::util::rng::Pcg32;

pub struct Dryden {
    residues: ResidueStore,
    fraction: f64,
    rng: Pcg32,
    scratch: Vec<f32>,
    pool: BufPool,
}

impl Dryden {
    pub fn new(cfg: &Config, layout: &Layout) -> Dryden {
        Dryden {
            residues: ResidueStore::new(layout),
            fraction: cfg.topk_fraction,
            rng: Pcg32::new(cfg.seed, 77),
            scratch: Vec::new(),
            pool: BufPool::default(),
        }
    }

    /// k-th largest |value| via iterative quickselect (k >= 1).
    fn kth_abs(&mut self, k: usize) -> f32 {
        let s = &mut self.scratch;
        let n = s.len();
        debug_assert!(k >= 1 && k <= n);
        let target = k - 1; // index in descending order
        let (mut lo, mut hi) = (0usize, n);
        loop {
            if hi - lo <= 1 {
                return s[lo];
            }
            // random pivot to dodge adversarial orderings
            let p = lo + (self.rng.below((hi - lo) as u32) as usize);
            let pivot = s[p];
            // 3-way partition by descending |value|
            let (mut i, mut j, mut m) = (lo, lo, hi);
            while j < m {
                if s[j] > pivot {
                    s.swap(i, j);
                    i += 1;
                    j += 1;
                } else if s[j] < pivot {
                    m -= 1;
                    s.swap(j, m);
                } else {
                    j += 1;
                }
            }
            if target < i {
                hi = i;
            } else if target < m {
                return pivot;
            } else {
                lo = m;
            }
        }
    }
}

impl Compressor for Dryden {
    fn kind(&self) -> Kind {
        Kind::Dryden
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        self.residues.fold(layer, dw);
        let n = self.residues.layer(layer).len();
        let k = ((n as f64 * self.fraction).round() as usize).clamp(1, n);

        // threshold = k-th largest |G|
        self.scratch.clear();
        self.scratch
            .extend(self.residues.layer(layer).iter().map(|x| x.abs()));
        let thresh = self.kth_abs(k);

        // Collect the sent set (>= threshold, capped at k by scanning order to
        // keep an exact top-k even with ties).
        let (mut idx, mut val) = self.pool.take();
        let r = self.residues.layer(layer);
        for (i, &g) in r.iter().enumerate() {
            if g.abs() >= thresh && idx.len() < k && g != 0.0 {
                idx.push(i as u32);
            }
        }
        let (pos, neg) = quantize::signed_means(idx.iter().map(|&i| r[i as usize]));

        let rm = self.residues.layer_mut(layer);
        for &i in idx.iter() {
            let g = rm[i as usize];
            let sent = if g >= 0.0 { pos } else { neg };
            val.push(sent);
            rm[i as usize] = g - sent;
        }

        let wire_bytes = wire::sparse_sign_wire_len(idx.len());
        let paper_bits = idx.len() * 32 + 64; // 32-bit index + sign, 2 means
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes,
            paper_bits,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.residues.layer(layer)
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        Some(self.residues.layer_mut(layer))
    }

    fn reset(&mut self) {
        self.residues.reset();
    }

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};

    fn make(n: usize, fraction: f64) -> Dryden {
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Fc)]);
        let cfg = Config {
            topk_fraction: fraction,
            ..Config::with_kind(Kind::Dryden)
        };
        Dryden::new(&cfg, &layout)
    }

    #[test]
    fn wire_roundtrip_bitwise() {
        // dryden packets carry two distinct values (+mean / -mean) -> the
        // v2 two-value sparse form; the real wire bytes must round-trip
        // bit-exactly and never exceed the analytic sparse-sign length
        let mut c = make(1000, 0.01);
        let mut rng = Pcg32::seeded(21);
        let dw = rng.normal_vec(1000, 1.0);
        let p = c.pack_layer(0, &dw);
        let bytes = super::super::wire::encode_packet(&p).unwrap();
        let q = super::super::wire::decode(&bytes).unwrap();
        assert_eq!(q.idx, p.idx);
        assert_eq!(q.val, p.val);
        assert_eq!(q.wire_bytes, bytes.len());
        assert!(bytes.len() <= p.wire_bytes, "measured {} > analytic {}", bytes.len(), p.wire_bytes);
    }

    #[test]
    fn sends_top_fraction() {
        let mut c = make(1000, 0.01);
        let mut rng = Pcg32::seeded(9);
        let dw = rng.normal_vec(1000, 1.0);
        let p = c.pack_layer(0, &dw);
        assert_eq!(p.sent(), 10);
        // every sent |G| must be >= every unsent |G|
        let min_sent = p
            .idx
            .iter()
            .map(|&i| dw[i as usize].abs())
            .fold(f32::INFINITY, f32::min);
        let sent_set: std::collections::HashSet<u32> = p.idx.iter().copied().collect();
        let max_unsent = dw
            .iter()
            .enumerate()
            .filter(|(i, _)| !sent_set.contains(&(*i as u32)))
            .map(|(_, x)| x.abs())
            .fold(0.0f32, f32::max);
        assert!(min_sent >= max_unsent);
    }

    #[test]
    fn one_bit_values() {
        let mut c = make(500, 0.02);
        let mut rng = Pcg32::seeded(10);
        let dw = rng.normal_vec(500, 2.0);
        let p = c.pack_layer(0, &dw);
        // at most two distinct magnitudes (pos mean, neg mean)
        let mut mags: Vec<f32> = p.val.iter().map(|v| *v).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        mags.dedup();
        assert!(mags.len() <= 2, "{mags:?}");
    }

    #[test]
    fn conservation() {
        let mut c = make(256, 0.05);
        let mut rng = Pcg32::seeded(11);
        let dw = rng.normal_vec(256, 0.7);
        let p = c.pack_layer(0, &dw);
        let mut recon = c.residue(0).to_vec();
        p.add_into(&mut recon);
        for (a, b) in recon.iter().zip(dw.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn kth_abs_exact() {
        let layout = Layout::from_specs(&[("w", &[8], LayerKind::Fc)]);
        let mut d = Dryden::new(&Config::with_kind(Kind::Dryden), &layout);
        d.scratch = vec![5.0, 1.0, 3.0, 9.0, 7.0, 2.0, 8.0, 4.0];
        assert_eq!(d.kth_abs(1), 9.0);
        d.scratch = vec![5.0, 1.0, 3.0, 9.0, 7.0, 2.0, 8.0, 4.0];
        assert_eq!(d.kth_abs(3), 7.0);
        d.scratch = vec![5.0, 1.0, 3.0, 9.0, 7.0, 2.0, 8.0, 4.0];
        assert_eq!(d.kth_abs(8), 1.0);
    }

    #[test]
    fn fraction_clamps_to_one_element() {
        let mut c = make(100, 1e-9);
        let dw = vec![1.0; 100];
        let p = c.pack_layer(0, &dw);
        assert_eq!(p.sent(), 1);
    }
}
