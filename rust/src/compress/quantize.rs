//! Quantization codecs shared by the compression schemes.

/// Ternary value code carried in the 2-bit slot field of the AdaComp wire
/// format: 0, +scale, -scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tern {
    Zero,
    Pos,
    Neg,
}

impl Tern {
    #[inline]
    pub fn of(x: f32) -> Tern {
        if x > 0.0 {
            Tern::Pos
        } else if x < 0.0 {
            Tern::Neg
        } else {
            Tern::Zero
        }
    }
    #[inline]
    pub fn code(self) -> u8 {
        match self {
            Tern::Zero => 0,
            Tern::Pos => 1,
            Tern::Neg => 2,
        }
    }
    #[inline]
    pub fn from_code(c: u8) -> Tern {
        match c & 3 {
            1 => Tern::Pos,
            2 => Tern::Neg,
            _ => Tern::Zero,
        }
    }
    #[inline]
    pub fn apply(self, scale: f32) -> f32 {
        match self {
            Tern::Zero => 0.0,
            Tern::Pos => scale,
            Tern::Neg => -scale,
        }
    }
}

/// sign(x) * scale with sign(0) = 0 (matches jnp.sign semantics in ref.py).
#[inline]
pub fn ternarize(x: f32, scale: f32) -> f32 {
    Tern::of(x).apply(scale)
}

/// Means of the positive and negative parts of a slice (1-bit reconstruction
/// values, Seide'14 / Dryden'16). Returns (pos_mean, neg_mean) with 0.0 when
/// a side is empty.
pub fn signed_means(xs: impl Iterator<Item = f32>) -> (f32, f32) {
    let (mut ps, mut pn, mut ns, mut nn) = (0.0f64, 0usize, 0.0f64, 0usize);
    for x in xs {
        if x >= 0.0 {
            ps += x as f64;
            pn += 1;
        } else {
            ns += x as f64;
            nn += 1;
        }
    }
    (
        if pn > 0 { (ps / pn as f64) as f32 } else { 0.0 },
        if nn > 0 { (ns / nn as f64) as f32 } else { 0.0 },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tern_roundtrip() {
        for t in [Tern::Zero, Tern::Pos, Tern::Neg] {
            assert_eq!(Tern::from_code(t.code()), t);
        }
        assert_eq!(Tern::of(3.0), Tern::Pos);
        assert_eq!(Tern::of(-0.1), Tern::Neg);
        assert_eq!(Tern::of(0.0), Tern::Zero);
    }

    #[test]
    fn ternarize_values() {
        assert_eq!(ternarize(5.0, 0.5), 0.5);
        assert_eq!(ternarize(-0.001, 0.5), -0.5);
        assert_eq!(ternarize(0.0, 0.5), 0.0);
    }

    #[test]
    fn means() {
        let (p, n) = signed_means([1.0, 3.0, -2.0, -4.0].into_iter());
        assert_eq!(p, 2.0);
        assert_eq!(n, -3.0);
        let (p, n) = signed_means([1.0, 2.0].into_iter());
        assert_eq!(p, 1.5);
        assert_eq!(n, 0.0);
    }
}
