//! Vectorized AdaComp bin kernels — the per-bin abs-max scan (pass 1b) and
//! the soft-threshold select (pass 2) behind `adacomp::pack_layer`.
//!
//! Same dispatch discipline as `tensor::gemm` / `compress::vbyte`: a runtime
//! AVX2 path (honoring `ADACOMP_NO_SIMD=1`) and a scalar mirror that is
//! **bit-identical** by construction:
//!
//! * abs-max — `max` over non-negative finite values is order-insensitive,
//!   so the 8-lane reduction and the scalar 4-lane unroll produce the same
//!   bits no matter how the reduction tree is shaped.
//! * select — both paths compute `h = g + c1 * d` as one IEEE-754 multiply
//!   then one add per lane (deliberately NOT fused: the scalar reference —
//!   and the golden vectors pinned by rust/tests/golden.rs — use mul+add,
//!   and `_mm256_mul_ps`/`_mm256_add_ps` are the exact per-lane mirror).
//!   The threshold compare uses sign-stripped bits (`|h| >= gmax`) in both.
//!
//! The vector path is a *prefilter*: 8 lanes are compared at once and the
//! (rare) hits are emitted by a scalar drain of the movemask, so the common
//! no-send path never branches per element. Emission order stays ascending
//! within the bin — packet indices remain strictly increasing.

use std::sync::OnceLock;

/// True when the AVX2 select/scan path is in use (x86_64 + runtime AVX2,
/// `ADACOMP_NO_SIMD` unset/empty). Independent of the GEMM gate: selection
/// needs AVX2 only (no FMA — the kernel is mul+add by contract).
pub fn simd_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let forced_off = std::env::var_os("ADACOMP_NO_SIMD")
            .map(|v| !v.is_empty())
            .unwrap_or(false);
        if forced_off {
            return false;
        }
        #[cfg(target_arch = "x86_64")]
        {
            std::is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Per-bin max |x| (pass 1b). Returns 0.0 for an empty bin.
#[inline]
pub fn bin_absmax(bin: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() && bin.len() >= 8 {
        // SAFETY: AVX2 detected at runtime; reads stay within `bin`.
        return unsafe { bin_absmax_avx2(bin) };
    }
    bin_absmax_scalar(bin)
}

/// Scalar abs-max: 4-lane unrolled to break the reduction dependency chain
/// (LLVM autovectorizes the quads). Bit-identical to the AVX2 reduction —
/// max over the non-negative |x| values is order-insensitive.
pub fn bin_absmax_scalar(bin: &[f32]) -> f32 {
    let mut m = [0.0f32; 4];
    let (quads, tail) = bin.split_at(bin.len() & !3);
    for q in quads.chunks_exact(4) {
        m[0] = m[0].max(q[0].abs());
        m[1] = m[1].max(q[1].abs());
        m[2] = m[2].max(q[2].abs());
        m[3] = m[3].max(q[3].abs());
    }
    let mut mm = m[0].max(m[1]).max(m[2].max(m[3]));
    for &x in tail {
        mm = mm.max(x.abs());
    }
    mm
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bin_absmax_avx2(bin: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let mut acc = _mm256_setzero_ps();
    let n8 = bin.len() & !7;
    let p = bin.as_ptr();
    for i in (0..n8).step_by(8) {
        let v = _mm256_and_ps(_mm256_loadu_ps(p.add(i)), abs_mask);
        acc = _mm256_max_ps(acc, v);
    }
    // horizontal max of the 8 lanes
    let hi = _mm256_extractf128_ps(acc, 1);
    let m4 = _mm_max_ps(_mm256_castps256_ps128(acc), hi);
    let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
    let m1 = _mm_max_ss(m2, _mm_shuffle_ps(m2, m2, 1));
    let mut mm = _mm_cvtss_f32(m1);
    for &x in &bin[n8..] {
        mm = mm.max(x.abs());
    }
    mm
}

/// Pass 2 for one bin: soft-threshold select, ternarize, residue update.
///
/// For each element j of the bin: `h = g + c1 * d` (g = folded residue
/// `rb[j]`, d = raw gradient `db[j]`); where `|h| >= gm`, emit
/// `(base + j, sign(g) * q)` and set `rb[j] = g - sent`. Emission order is
/// ascending j. Callers guarantee `gm > 0` (all-zero bins are skipped).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn select_bin_into(
    rb: &mut [f32],
    db: &[f32],
    gm: f32,
    q: f32,
    c1: f32,
    base: u32,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    debug_assert_eq!(rb.len(), db.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() && rb.len() >= 8 {
        // SAFETY: AVX2 detected at runtime; loads/stores stay within rb/db.
        unsafe { select_bin_avx2(rb, db, gm, q, c1, base, idx, val) };
        return;
    }
    select_bin_scalar_into(rb, db, gm, q, c1, base, idx, val);
}

/// Scalar reference for [`select_bin_into`] — the exact semantics of the
/// original pack loop (and of `python/compile/kernels/ref.py`); the AVX2
/// path must match it bit-for-bit (rust/tests/kernel_equivalence.rs).
#[allow(clippy::too_many_arguments)]
pub fn select_bin_scalar_into(
    rb: &mut [f32],
    db: &[f32],
    gm: f32,
    q: f32,
    c1: f32,
    base: u32,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    for (j, (ri, &di)) in rb.iter_mut().zip(db.iter()).enumerate() {
        let g = *ri;
        // NB: not mul_add — the contract is one multiply then one add (and
        // without the fma target-feature mul_add is a libm call anyway).
        let h = g + c1 * di;
        if h.abs() >= gm {
            let sent = if g > 0.0 {
                q
            } else if g < 0.0 {
                -q
            } else {
                0.0
            };
            idx.push(base + j as u32);
            val.push(sent);
            *ri = g - sent;
        }
    }
}

/// AVX2 prefilter: compare 8 thresholds at once, drain hits scalar-side.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn select_bin_avx2(
    rb: &mut [f32],
    db: &[f32],
    gm: f32,
    q: f32,
    c1: f32,
    base: u32,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    use std::arch::x86_64::*;
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let gmv = _mm256_set1_ps(gm);
    let c1v = _mm256_set1_ps(c1);
    let n = rb.len();
    let n8 = n & !7;
    // one mutable pointer serves both the vector loads and the hit
    // write-backs (a fresh `rb[j]` access would invalidate it)
    let rp = rb.as_mut_ptr();
    let dp = db.as_ptr();
    for i in (0..n8).step_by(8) {
        let g = _mm256_loadu_ps(rp.add(i));
        let d = _mm256_loadu_ps(dp.add(i));
        // h = g + c1 * d — mul then add, the scalar reference's exact ops
        let h = _mm256_add_ps(g, _mm256_mul_ps(c1v, d));
        let habs = _mm256_and_ps(h, abs_mask);
        let hit = _mm256_cmp_ps::<_CMP_GE_OQ>(habs, gmv);
        let mut mask = _mm256_movemask_ps(hit) as u32;
        while mask != 0 {
            let lane = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let j = i + lane;
            let gj = *rp.add(j);
            let sent = if gj > 0.0 {
                q
            } else if gj < 0.0 {
                -q
            } else {
                0.0
            };
            idx.push(base + j as u32);
            val.push(sent);
            *rp.add(j) = gj - sent;
        }
    }
    select_bin_scalar_into(
        &mut rb[n8..],
        &db[n8..],
        gm,
        q,
        c1,
        base + n8 as u32,
        idx,
        val,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn absmax_scalar_matches_plain_fold() {
        let mut rng = Pcg32::seeded(1);
        for n in [0usize, 1, 3, 7, 8, 13, 64, 100] {
            let v = rng.normal_vec(n, 1.0);
            let want = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            assert_eq!(bin_absmax_scalar(&v).to_bits(), want.to_bits(), "n={n}");
            assert_eq!(bin_absmax(&v).to_bits(), want.to_bits(), "n={n} dispatch");
        }
    }

    #[test]
    fn select_scalar_semantics() {
        // residue [2, -2, 0.1, 0], dw 0, gm 1, q 0.5: first two selected
        let mut rb = vec![2.0f32, -2.0, 0.1, 0.0];
        let db = vec![0.0f32; 4];
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        select_bin_scalar_into(&mut rb, &db, 1.0, 0.5, 1.0, 100, &mut idx, &mut val);
        assert_eq!(idx, vec![100, 101]);
        assert_eq!(val, vec![0.5, -0.5]);
        assert_eq!(rb, vec![1.5, -1.5, 0.1, 0.0]);
    }

    #[test]
    fn dispatch_matches_scalar_bitwise() {
        // whatever path dispatch picks must equal the scalar reference
        let mut rng = Pcg32::seeded(2);
        for n in [1usize, 7, 8, 9, 31, 64, 257] {
            let r0 = rng.normal_vec(n, 1.0);
            let db = rng.normal_vec(n, 1.0);
            let gm = bin_absmax(&r0.iter().zip(&db).map(|(a, b)| a + b).collect::<Vec<_>>());
            let mut ra = r0.clone();
            let (mut ia, mut va) = (Vec::new(), Vec::new());
            select_bin_into(&mut ra, &db, gm, 0.25, 1.0, 7, &mut ia, &mut va);
            let mut rs = r0.clone();
            let (mut is_, mut vs) = (Vec::new(), Vec::new());
            select_bin_scalar_into(&mut rs, &db, gm, 0.25, 1.0, 7, &mut is_, &mut vs);
            assert_eq!(ia, is_, "n={n}");
            assert_eq!(
                va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                vs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
            assert_eq!(
                ra.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                rs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
        }
    }
}
