//! Delta + group-varint codec for sparse gradient indices (the v2 wire
//! format's index stream), stream-vbyte style.
//!
//! A packet's indices are strictly increasing, so they are first
//! delta-encoded (`d_0 = idx_0`, `d_i = idx_i - idx_{i-1}`) and the small
//! deltas then variable-byte packed in groups of four:
//!
//! ```text
//! [control stream: ceil(count/4) bytes] [data stream: 1..=4 bytes per delta]
//! ```
//!
//! Each control byte holds four 2-bit length codes (`code = bytes - 1`,
//! value `j`'s code at bits `2 * (j % 4)`, little-endian within the byte);
//! the data stream is the deltas' little-endian bytes, truncated to the
//! coded length and concatenated in order. Splitting control from data is
//! what makes the format SIMD-friendly: four values are packed or unpacked
//! with a single SSSE3 `pshufb` whose shuffle mask is looked up by the
//! control byte in a 256-entry table (one entry per 4-code combination).
//! The tables are generated deterministically at first use into a
//! `OnceLock`, so the hot path is allocation-free after warm-up.
//!
//! The scalar fallback produces **bit-identical** streams (pinned by the
//! tests here and by rust/tests/wire_property.rs, which cross-compares the
//! two paths on random inputs). Dispatch is cached: x86_64 with SSSE3
//! detected at runtime takes the SIMD kernels unless the `ADACOMP_NO_SIMD`
//! environment variable is set non-empty (the CI switch that keeps the
//! scalar path exercised).

use anyhow::{bail, Result};
use std::sync::OnceLock;

/// 2-bit length code for one delta: encoded byte count minus one.
#[inline]
fn code(d: u32) -> u8 {
    3u8.saturating_sub((d.leading_zeros() / 8) as u8)
}

/// True when the SSSE3 kernels are in use: compiled for x86_64, the CPU
/// reports SSSE3, and `ADACOMP_NO_SIMD` is unset/empty. Cached after the
/// first call (which reads the environment once).
pub fn simd_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let forced_off = std::env::var_os("ADACOMP_NO_SIMD")
            .map(|v| !v.is_empty())
            .unwrap_or(false);
        if forced_off {
            return false;
        }
        #[cfg(target_arch = "x86_64")]
        {
            std::is_x86_feature_detected!("ssse3")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Exact encoded byte length of `idx`'s delta stream (control + data),
/// without materializing it — the analytic cross-check for v2 wire lens.
pub fn encoded_len(idx: &[u32]) -> usize {
    if idx.is_empty() {
        return 0;
    }
    let mut prev = 0u32;
    let mut data = 0usize;
    for &v in idx {
        data += code(v.wrapping_sub(prev)) as usize + 1;
        prev = v;
    }
    idx.len().div_ceil(4) + data
}

/// Worst-case encoded length for `count` values (every delta 4 bytes).
pub fn max_encoded_len(count: usize) -> usize {
    count.div_ceil(4) + 4 * count
}

/// Append `idx`'s delta-vbyte stream to `out`. `idx` must be strictly
/// increasing (the wire layer validates; garbage in, garbage out here).
/// Dispatches to the SSSE3 kernel when available, scalar otherwise — the
/// two produce bit-identical bytes.
pub fn encode_into(idx: &[u32], out: &mut Vec<u8>) {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: simd_enabled() verified SSSE3 support at runtime.
        unsafe { encode_ssse3(idx, out) };
        return;
    }
    encode_scalar_into(idx, out);
}

/// Decode `count` values from the front of `bytes`, appending the
/// prefix-summed (absolute) indices to `out`. Returns the number of bytes
/// consumed. Errors (never panics) on a truncated stream.
pub fn decode_into(count: usize, bytes: &[u8], out: &mut Vec<u32>) -> Result<usize> {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: simd_enabled() verified SSSE3 support at runtime.
        return unsafe { decode_ssse3(count, bytes, out) };
    }
    decode_scalar_into(count, bytes, out)
}

/// Scalar reference encoder (bit-identical to the SIMD kernel; public so
/// tests and benches can cross-compare the two paths explicitly).
pub fn encode_scalar_into(idx: &[u32], out: &mut Vec<u8>) {
    let n = idx.len();
    if n == 0 {
        return;
    }
    let ctrl_at = out.len();
    out.resize(ctrl_at + n.div_ceil(4), 0);
    let mut prev = 0u32;
    for (j, &v) in idx.iter().enumerate() {
        let d = v.wrapping_sub(prev);
        prev = v;
        let c = code(d);
        out[ctrl_at + j / 4] |= c << (2 * (j % 4));
        out.extend_from_slice(&d.to_le_bytes()[..c as usize + 1]);
    }
}

/// Scalar reference decoder (bounds-checked per value; public for
/// cross-comparison like [`encode_scalar_into`]).
pub fn decode_scalar_into(count: usize, bytes: &[u8], out: &mut Vec<u32>) -> Result<usize> {
    if count == 0 {
        return Ok(0);
    }
    let ctrl_len = count.div_ceil(4);
    if bytes.len() < ctrl_len {
        bail!("vbyte underrun (control stream)");
    }
    let mut di = ctrl_len;
    let mut prev = 0u32;
    for j in 0..count {
        let w = ((bytes[j / 4] >> (2 * (j % 4))) & 3) as usize + 1;
        if di + w > bytes.len() {
            bail!("vbyte underrun (data stream)");
        }
        let mut b = [0u8; 4];
        b[..w].copy_from_slice(&bytes[di..di + w]);
        prev = prev.wrapping_add(u32::from_le_bytes(b));
        out.push(prev);
        di += w;
    }
    Ok(di)
}

/// Shuffle-mask tables for the SSSE3 kernels, one entry per control byte.
/// `enc[c]` gathers the valid little-endian bytes of four u32 lanes into a
/// contiguous prefix; `dec[c]` scatters a packed prefix back into four
/// lanes (0x80 lanes shuffle in zero); `len[c]` is the packed byte count.
#[cfg(target_arch = "x86_64")]
struct VbTables {
    enc: [[u8; 16]; 256],
    dec: [[u8; 16]; 256],
    len: [u8; 256],
}

#[cfg(target_arch = "x86_64")]
fn tables() -> &'static VbTables {
    static T: OnceLock<VbTables> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = VbTables {
            enc: [[0x80; 16]; 256],
            dec: [[0x80; 16]; 256],
            len: [0; 256],
        };
        #[allow(clippy::needless_range_loop)]
        for ctrl in 0..256usize {
            let mut src = 0usize;
            for lane in 0..4 {
                let w = ((ctrl >> (2 * lane)) & 3) + 1;
                for k in 0..w {
                    t.enc[ctrl][src] = (4 * lane + k) as u8;
                    t.dec[ctrl][4 * lane + k] = src as u8;
                    src += 1;
                }
            }
            t.len[ctrl] = src as u8;
        }
        t
    })
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn encode_ssse3(idx: &[u32], out: &mut Vec<u8>) {
    use std::arch::x86_64::*;
    let n = idx.len();
    if n == 0 {
        return;
    }
    let t = tables();
    let ctrl_at = out.len();
    out.resize(ctrl_at + n.div_ceil(4), 0);
    let mut prev = 0u32;
    let mut q = 0usize;
    while q + 4 <= n {
        let d = [
            idx[q].wrapping_sub(prev),
            idx[q + 1].wrapping_sub(idx[q]),
            idx[q + 2].wrapping_sub(idx[q + 1]),
            idx[q + 3].wrapping_sub(idx[q + 2]),
        ];
        prev = idx[q + 3];
        let ctrl = code(d[0]) | (code(d[1]) << 2) | (code(d[2]) << 4) | (code(d[3]) << 6);
        out[ctrl_at + q / 4] = ctrl;
        let v = _mm_loadu_si128(d.as_ptr() as *const __m128i);
        let mask = _mm_loadu_si128(t.enc[ctrl as usize].as_ptr() as *const __m128i);
        let mut tmp = [0u8; 16];
        _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, _mm_shuffle_epi8(v, mask));
        out.extend_from_slice(&tmp[..t.len[ctrl as usize] as usize]);
        q += 4;
    }
    // tail group (< 4 values): scalar, byte-identical to encode_scalar_into
    let mut ctrl = 0u8;
    for (j, &v) in idx[q..].iter().enumerate() {
        let d = v.wrapping_sub(prev);
        prev = v;
        let c = code(d);
        ctrl |= c << (2 * j);
        out.extend_from_slice(&d.to_le_bytes()[..c as usize + 1]);
    }
    if q < n {
        out[ctrl_at + q / 4] = ctrl;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn decode_ssse3(count: usize, bytes: &[u8], out: &mut Vec<u32>) -> Result<usize> {
    use std::arch::x86_64::*;
    if count == 0 {
        return Ok(0);
    }
    let t = tables();
    let ctrl_len = count.div_ceil(4);
    if bytes.len() < ctrl_len {
        bail!("vbyte underrun (control stream)");
    }
    let mut di = ctrl_len;
    let mut prev = 0u32;
    let mut j = 0usize;
    // the 16-byte pshufb load over-reads past the group's own data, so the
    // SIMD path runs only while a full vector fits; the scalar tail takes
    // over near the end of the buffer (bounds-checked per value)
    while j + 4 <= count && di + 16 <= bytes.len() {
        let ctrl = bytes[j / 4];
        let d = _mm_loadu_si128(bytes.as_ptr().add(di) as *const __m128i);
        let mask = _mm_loadu_si128(t.dec[ctrl as usize].as_ptr() as *const __m128i);
        let mut tmp = [0u32; 4];
        _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, _mm_shuffle_epi8(d, mask));
        for v in tmp {
            prev = prev.wrapping_add(v);
            out.push(prev);
        }
        di += t.len[ctrl as usize] as usize;
        j += 4;
    }
    for k in j..count {
        let w = ((bytes[k / 4] >> (2 * (k % 4))) & 3) as usize + 1;
        if di + w > bytes.len() {
            bail!("vbyte underrun (data stream)");
        }
        let mut b = [0u8; 4];
        b[..w].copy_from_slice(&bytes[di..di + w]);
        prev = prev.wrapping_add(u32::from_le_bytes(b));
        out.push(prev);
        di += w;
    }
    Ok(di)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random strictly-increasing index set with deltas spanning all four
    /// byte widths (gap magnitude drawn log-uniform-ish per element).
    fn random_idx(rng: &mut Pcg32, count: usize) -> Vec<u32> {
        let mut idx = Vec::with_capacity(count);
        let mut cur = 0u64;
        for _ in 0..count {
            let shift = rng.below(25); // gaps 1..=2^25: 1-to-4-byte deltas
            cur += 1 + rng.below(1u32 << shift) as u64;
            if cur > u32::MAX as u64 {
                break;
            }
            idx.push(cur as u32);
        }
        idx
    }

    #[test]
    fn vbyte_code_widths() {
        assert_eq!(code(0), 0);
        assert_eq!(code(255), 0);
        assert_eq!(code(256), 1);
        assert_eq!(code(65535), 1);
        assert_eq!(code(65536), 2);
        assert_eq!(code((1 << 24) - 1), 2);
        assert_eq!(code(1 << 24), 3);
        assert_eq!(code(u32::MAX), 3);
    }

    #[test]
    fn vbyte_scalar_roundtrip_known() {
        // first delta is idx[0] itself; later deltas cross width boundaries
        let idx = vec![0u32, 1, 255, 256, 65535, 1 << 20, 1 << 26, u32::MAX];
        let mut bytes = Vec::new();
        encode_scalar_into(&idx, &mut bytes);
        assert_eq!(bytes.len(), encoded_len(&idx));
        let mut back = Vec::new();
        let used = decode_scalar_into(idx.len(), &bytes, &mut back).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, idx);
    }

    #[test]
    fn vbyte_dispatch_roundtrip_and_scalar_bit_identity() {
        let mut rng = Pcg32::seeded(0xb17e);
        for count in [0usize, 1, 2, 3, 4, 5, 7, 8, 63, 256, 1000] {
            let idx = random_idx(&mut rng, count);
            let mut fast = Vec::new();
            encode_into(&idx, &mut fast);
            let mut slow = Vec::new();
            encode_scalar_into(&idx, &mut slow);
            assert_eq!(fast, slow, "count {count}: SIMD and scalar streams differ");
            assert_eq!(fast.len(), encoded_len(&idx), "count {count}");

            let mut a = Vec::new();
            assert_eq!(decode_into(idx.len(), &fast, &mut a).unwrap(), fast.len());
            assert_eq!(a, idx, "count {count}: dispatch decode");
            let mut b = Vec::new();
            assert_eq!(decode_scalar_into(idx.len(), &fast, &mut b).unwrap(), fast.len());
            assert_eq!(b, idx, "count {count}: scalar decode");
        }
    }

    #[test]
    fn vbyte_decode_appends_and_reports_consumed() {
        // two streams back to back: consumed lets the caller advance
        let first = vec![3u32, 9, 700];
        let second = vec![1u32, 1 << 17];
        let mut bytes = Vec::new();
        encode_into(&first, &mut bytes);
        let mid = bytes.len();
        encode_into(&second, &mut bytes);
        let mut out = Vec::new();
        let used = decode_into(first.len(), &bytes, &mut out).unwrap();
        assert_eq!(used, mid);
        let used2 = decode_into(second.len(), &bytes[mid..], &mut out).unwrap();
        assert_eq!(mid + used2, bytes.len());
        assert_eq!(out, vec![3, 9, 700, 1, 1 << 17]);
    }

    #[test]
    fn vbyte_truncation_errors_not_panics() {
        let mut rng = Pcg32::seeded(7);
        let idx = random_idx(&mut rng, 300);
        let mut bytes = Vec::new();
        encode_into(&idx, &mut bytes);
        for cut in 0..bytes.len() {
            let mut out = Vec::new();
            assert!(
                decode_into(idx.len(), &bytes[..cut], &mut out).is_err(),
                "cut {cut} decoded from a truncated stream"
            );
            let mut out = Vec::new();
            assert!(decode_scalar_into(idx.len(), &bytes[..cut], &mut out).is_err());
        }
    }

    #[test]
    fn vbyte_empty_stream() {
        let mut bytes = Vec::new();
        encode_into(&[], &mut bytes);
        assert!(bytes.is_empty());
        assert_eq!(encoded_len(&[]), 0);
        let mut out = Vec::new();
        assert_eq!(decode_into(0, &[], &mut out).unwrap(), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn vbyte_worst_case_bound_holds() {
        let mut rng = Pcg32::seeded(11);
        for count in [1usize, 5, 64, 333] {
            let idx = random_idx(&mut rng, count);
            assert!(encoded_len(&idx) <= max_encoded_len(idx.len()));
        }
    }
}
