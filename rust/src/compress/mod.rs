//! Gradient compression engines — the paper's contribution (AdaComp) plus
//! every baseline its evaluation compares against.
//!
//! All residual-gradient schemes share the error-feedback skeleton from the
//! paper's Background section: each learner keeps an *accumulated residual
//! gradient* per parameter; each step folds the fresh gradient `dW` into the
//! residue, transmits a compressed subset, and keeps the untransmitted mass
//! locally:
//!
//! ```text
//! G        = residue + dW
//! sent     = select(G, ...)          // scheme-specific
//! Gq       = quantize(G[sent])       // scheme-specific
//! residue' = G - Gq  on sent, G elsewhere
//! ```
//!
//! | scheme       | select                                  | quantize            |
//! |--------------|------------------------------------------|---------------------|
//! | `adacomp`    | per-bin soft threshold |H|>=max|G| (bin) | ternary, layer scale|
//! | `ls`         | per-bin max only (ablation of adacomp)   | ternary, layer scale|
//! | `dryden`     | global top-pi% of |G| (quickselect)      | 1-bit, +/- means    |
//! | `onebit`     | everything (dense)                       | 1-bit, +/- means    |
//! | `terngrad`   | stochastic (no residue — unbiased)       | ternary, max scale  |
//! | `strom`      | fixed absolute threshold tau             | +/- tau             |
//! | `none`       | everything                               | raw f32             |

pub mod adacomp;
pub mod dryden;
pub mod identity;
pub mod local_select;
pub mod mixed;
pub mod onebit;
pub mod quantize;
pub mod residue;
pub mod select;
pub mod strom;
pub mod terngrad;
pub mod vbyte;
pub mod wire;

use crate::models::Layout;

/// A compressed gradient for one layer, ready for exchange.
///
/// `idx`/`val` is the canonical in-memory form every topology understands;
/// `wire` is the scheme's actual byte encoding (what the simulated fabric
/// charges for, and what `wire::decode` round-trips in tests).
#[derive(Debug, Clone)]
pub struct Packet {
    pub layer: usize,
    /// Dense length of the layer.
    pub n: usize,
    /// Indices of transmitted elements (strictly increasing). Empty for
    /// dense packets.
    pub idx: Vec<u32>,
    /// Transmitted values; for dense packets has length `n` and `idx` is empty.
    pub val: Vec<f32>,
    /// Scheme wire-format size in bytes (header + payload).
    pub wire_bytes: usize,
    /// The paper's idealized accounting (bits): 8 or 16 bits per sparse
    /// element depending on L_T, 32 per dense f32, etc. Used for the
    /// "Effective Compression Rate" the figures report.
    pub paper_bits: usize,
}

impl Packet {
    pub fn dense(layer: usize, val: Vec<f32>) -> Packet {
        let n = val.len();
        Packet {
            layer,
            n,
            idx: Vec::new(),
            val,
            wire_bytes: 4 * n + wire::HEADER_BYTES,
            paper_bits: 32 * n,
        }
    }

    pub fn is_dense(&self) -> bool {
        self.idx.is_empty() && self.val.len() == self.n
    }

    /// Number of transmitted elements.
    pub fn sent(&self) -> usize {
        if self.is_dense() {
            self.n
        } else {
            self.idx.len()
        }
    }

    /// Accumulate this packet into a dense buffer (the reduction primitive).
    pub fn add_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.n, "layer {} length mismatch", self.layer);
        if self.is_dense() {
            crate::tensor::ops::axpy(1.0, &self.val, acc);
        } else {
            for (&i, &v) in self.idx.iter().zip(self.val.iter()) {
                acc[i as usize] += v;
            }
        }
    }

    /// Effective compression rate vs 32-bit floats, from real wire bytes.
    pub fn rate_wire(&self) -> f64 {
        4.0 * self.n as f64 / self.wire_bytes as f64
    }

    /// Effective compression rate under the paper's idealized accounting.
    pub fn rate_paper(&self) -> f64 {
        32.0 * self.n as f64 / self.paper_bits.max(1) as f64
    }
}

/// Reusable `(idx, val)` buffer pairs: packets hand their vectors back here
/// via [`Compressor::recycle`] once the exchange has consumed them, and the
/// next `pack_layer` draws from the pool instead of allocating — the
/// steady-state pack/exchange loop performs no heap allocation (pinned by
/// rust/tests/alloc_free.rs).
#[derive(Debug, Default)]
pub struct BufPool {
    bufs: Vec<(Vec<u32>, Vec<f32>)>,
    bytes: Vec<Vec<u8>>,
}

impl BufPool {
    /// Pop a cleared buffer pair (capacity preserved), or fresh empty ones.
    pub fn take(&mut self) -> (Vec<u32>, Vec<f32>) {
        let (mut idx, mut val) = self.bufs.pop().unwrap_or_default();
        idx.clear();
        val.clear();
        (idx, val)
    }

    pub fn put(&mut self, idx: Vec<u32>, val: Vec<f32>) {
        self.bufs.push((idx, val));
    }

    /// Pop a cleared byte buffer (capacity preserved), or a fresh empty one.
    /// The wire path uses these for encoded bucket frames.
    pub fn take_bytes(&mut self) -> Vec<u8> {
        let mut b = self.bytes.pop().unwrap_or_default();
        b.clear();
        b
    }

    pub fn put_bytes(&mut self, b: Vec<u8>) {
        self.bytes.push(b);
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty() && self.bytes.is_empty()
    }
}

/// A gradient compressor bound to a model layout. Stateful: owns the
/// per-layer residual gradients (and any scheme-specific state).
pub trait Compressor: Send {
    fn kind(&self) -> Kind;

    /// Fold `dw` into layer `layer`'s residue, select + quantize, and return
    /// the packet to exchange. `dw` must have the layer's dense length.
    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet;

    /// Residual gradient for metrics (Fig 5/6). Dense, layer length.
    fn residue(&self, layer: usize) -> &[f32];

    /// Mutable residual access for state handover when a learner departs
    /// (elastic fleet). Schemes with no carried residue return None.
    fn residue_mut(&mut self, _layer: usize) -> Option<&mut [f32]> {
        None
    }

    /// Drop all state (new training run).
    fn reset(&mut self);

    /// Re-tune one layer's bin size L_T in place (the adaptive controller's
    /// per-layer apply path, at a drained epoch boundary). Residues are
    /// kept: error feedback is robust to a changed selection granularity.
    /// Default no-op — schemes without an L_T notion ignore it.
    fn set_layer_lt(&mut self, _layer: usize, _lt: usize) {}

    /// Hand a spent packet's `idx`/`val` vectors back for reuse by later
    /// `pack_layer` calls (zero-alloc steady state). Callers that drop
    /// packets instead of recycling them lose nothing but the capacity.
    fn recycle(&mut self, _spent: Packet) {}
}

/// Scheme selector, CLI-parsable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    AdaComp,
    LocalSelect,
    Dryden,
    OneBit,
    TernGrad,
    Strom,
    None,
}

impl Kind {
    /// Canonical scheme names, for CLI/config error messages.
    pub const NAMES: &'static [&'static str] =
        &["adacomp", "ls", "dryden", "onebit", "terngrad", "strom", "none"];

    /// [`parse`](Self::parse) that errors with the valid-name list — the
    /// one place CLI/config "unknown scheme" messages come from.
    pub fn parse_or_err(s: &str) -> anyhow::Result<Kind> {
        Self::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown scheme '{s}' (valid: {})", Self::NAMES.join(", "))
        })
    }

    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "adacomp" => Kind::AdaComp,
            "ls" | "local_select" => Kind::LocalSelect,
            "dryden" | "topk" => Kind::Dryden,
            "onebit" | "1bit" => Kind::OneBit,
            "terngrad" => Kind::TernGrad,
            "strom" | "threshold" => Kind::Strom,
            "none" | "identity" => Kind::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kind::AdaComp => "adacomp",
            Kind::LocalSelect => "ls",
            Kind::Dryden => "dryden",
            Kind::OneBit => "onebit",
            Kind::TernGrad => "terngrad",
            Kind::Strom => "strom",
            Kind::None => "none",
        }
    }

    /// Whether the scheme has a per-layer bin size L_T the adaptive
    /// controller can re-tune ([`Compressor::set_layer_lt`] is a no-op for
    /// every other scheme).
    pub fn has_lt(&self) -> bool {
        matches!(self, Kind::AdaComp | Kind::LocalSelect)
    }
}

/// Per-scheme knobs; unused fields are ignored by other schemes.
#[derive(Debug, Clone)]
pub struct Config {
    pub kind: Kind,
    /// AdaComp / LS: bin length for conv layers (paper default 50).
    pub lt_conv: usize,
    /// AdaComp / LS: bin length for fc layers (paper default 500); also the
    /// lstm/embed default when their own overrides are 0.
    pub lt_fc: usize,
    /// AdaComp / LS: bin length for lstm layers; 0 = inherit `lt_fc`.
    pub lt_lstm: usize,
    /// AdaComp / LS: bin length for embedding layers; 0 = inherit `lt_fc`.
    pub lt_embed: usize,
    /// AdaComp: override L_T for *all* layers (Fig 4 sweeps this); 0 = per-kind.
    pub lt_override: usize,
    /// AdaComp: soft-threshold scale factor (paper studied 1.5-3.0, chose 2).
    pub scale_factor: f32,
    /// Dryden: fraction of elements sent (paper example: 0.003 = top 0.3%).
    pub topk_fraction: f64,
    /// Strom: absolute threshold tau.
    pub strom_tau: f32,
    /// TernGrad: rng seed (stochastic quantization).
    pub seed: u64,
    /// Quantize per-bin instead of per-layer (ablation; paper uses per-layer).
    pub per_bin_scale: bool,
    /// Override scheme for conv layers only (Fig 1 mixes schemes per kind);
    /// `None` = use `kind` everywhere.
    pub kind_conv: Option<Kind>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            kind: Kind::AdaComp,
            lt_conv: 50,
            lt_fc: 500,
            lt_lstm: 0,
            lt_embed: 0,
            lt_override: 0,
            scale_factor: 2.0,
            topk_fraction: 0.003,
            strom_tau: 0.01,
            seed: 0x5eed,
            per_bin_scale: false,
            kind_conv: None,
        }
    }
}

impl Config {
    pub fn with_kind(kind: Kind) -> Config {
        Config {
            kind,
            ..Default::default()
        }
    }

    /// Effective L_T for a layer kind. Paper defaults: conv 50, fc/lstm 500
    /// (Table 1). `Embed` is documented to share the fc/lstm default of 500:
    /// embedding gradients are row-sparse like fc/lstm gradients (only the
    /// minibatch's token rows are nonzero), so the fine conv bin length
    /// would waste header bytes without improving selection. Pinned by
    /// `mixed::tests::lt_defaults_cover_all_kinds`.
    pub fn lt_for(&self, kind: crate::models::LayerKind) -> usize {
        if self.lt_override > 0 {
            return self.lt_override;
        }
        let inherit = |own: usize| if own > 0 { own } else { self.lt_fc };
        match kind {
            crate::models::LayerKind::Conv => self.lt_conv,
            crate::models::LayerKind::Fc => self.lt_fc,
            crate::models::LayerKind::Lstm => inherit(self.lt_lstm),
            crate::models::LayerKind::Embed => inherit(self.lt_embed),
        }
    }

    /// Parse an `--lt` / config `"lt"` spec into this config, failing fast
    /// with the valid forms on anything malformed (the `--churn` /
    /// `--topology` error-message precedent). Two forms:
    ///
    /// * a plain integer `L` — one L_T for every layer (`lt_override`),
    /// * a per-kind list `conv=64,fc=500[,lstm=N][,embed=N]` — each entry
    ///   sets that layer kind's bin size; omitted lstm/embed inherit fc.
    ///
    /// Values must be in `1..=100_000` (the controller's absolute band).
    pub fn parse_lt_spec(&mut self, spec: &str) -> anyhow::Result<()> {
        const VALID: &str =
            "valid: an integer L (all layers), or a per-kind list conv=64,fc=500[,lstm=N][,embed=N]";
        const LT_RANGE: std::ops::RangeInclusive<usize> = 1..=100_000;
        let spec = spec.trim();
        if spec.is_empty() {
            anyhow::bail!("empty --lt spec ({VALID})");
        }
        let parse_val = |kind: &str, v: &str| -> anyhow::Result<usize> {
            let lt: usize = v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad L_T '{v}' for '{kind}' in --lt spec ({VALID})"))?;
            if !LT_RANGE.contains(&lt) {
                anyhow::bail!(
                    "L_T {lt} for '{kind}' out of range (valid: {}..={})",
                    LT_RANGE.start(),
                    LT_RANGE.end()
                );
            }
            Ok(lt)
        };
        if !spec.contains('=') {
            self.lt_override = parse_val("all layers", spec)?;
            return Ok(());
        }
        for entry in spec.split(',') {
            let (kind, v) = entry.trim().split_once('=').ok_or_else(|| {
                anyhow::anyhow!("bad --lt entry '{entry}' ({VALID})")
            })?;
            let lt = parse_val(kind, v)?;
            match kind {
                "conv" => self.lt_conv = lt,
                "fc" => self.lt_fc = lt,
                "lstm" => self.lt_lstm = lt,
                "embed" => self.lt_embed = lt,
                other => anyhow::bail!(
                    "unknown layer kind '{other}' in --lt spec (valid kinds: conv, fc, lstm, embed)"
                ),
            }
        }
        // a per-kind list overrides any previous all-layer override
        self.lt_override = 0;
        Ok(())
    }
}

/// Instantiate a compressor for a model layout, honoring a per-kind mix.
pub fn build(cfg: &Config, layout: &Layout) -> Box<dyn Compressor> {
    if let Some(conv_kind) = cfg.kind_conv {
        if conv_kind != cfg.kind {
            let conv_cfg = Config {
                kind: conv_kind,
                kind_conv: None,
                ..cfg.clone()
            };
            let other_cfg = Config {
                kind_conv: None,
                ..cfg.clone()
            };
            return Box::new(mixed::Mixed::new(&conv_cfg, &other_cfg, layout));
        }
    }
    build_single(cfg, layout)
}

/// Instantiate a single-scheme compressor (no mixing).
pub(crate) fn build_single(cfg: &Config, layout: &Layout) -> Box<dyn Compressor> {
    match cfg.kind {
        Kind::AdaComp => Box::new(adacomp::AdaComp::new(cfg, layout)),
        Kind::LocalSelect => Box::new(local_select::LocalSelect::new(cfg, layout)),
        Kind::Dryden => Box::new(dryden::Dryden::new(cfg, layout)),
        Kind::OneBit => Box::new(onebit::OneBit::new(layout)),
        Kind::TernGrad => Box::new(terngrad::TernGrad::new(cfg, layout)),
        Kind::Strom => Box::new(strom::Strom::new(cfg, layout)),
        Kind::None => Box::new(identity::Identity::new(layout)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            Kind::AdaComp,
            Kind::LocalSelect,
            Kind::Dryden,
            Kind::OneBit,
            Kind::TernGrad,
            Kind::Strom,
            Kind::None,
        ] {
            assert_eq!(Kind::parse(k.name()), Some(k));
        }
        assert_eq!(Kind::parse("bogus"), None);
    }

    #[test]
    fn lt_spec_parses_both_forms() {
        use crate::models::LayerKind;
        // plain integer: one override for every layer
        let mut cfg = Config::default();
        cfg.parse_lt_spec("64").unwrap();
        assert_eq!(cfg.lt_override, 64);
        for k in [LayerKind::Conv, LayerKind::Fc, LayerKind::Lstm, LayerKind::Embed] {
            assert_eq!(cfg.lt_for(k), 64);
        }
        // per-kind list: sets each kind, clears the override
        cfg.parse_lt_spec("conv=32, fc=400,lstm=250").unwrap();
        assert_eq!(cfg.lt_override, 0);
        assert_eq!(cfg.lt_for(LayerKind::Conv), 32);
        assert_eq!(cfg.lt_for(LayerKind::Fc), 400);
        assert_eq!(cfg.lt_for(LayerKind::Lstm), 250);
        // omitted embed inherits fc
        assert_eq!(cfg.lt_for(LayerKind::Embed), 400);
        cfg.parse_lt_spec("embed=120").unwrap();
        assert_eq!(cfg.lt_for(LayerKind::Embed), 120);
    }

    #[test]
    fn lt_spec_fails_fast_with_valid_forms() {
        let mut cfg = Config::default();
        for bad in ["", "conv", "conv=", "conv=abc", "12abc", "=64"] {
            let err = cfg.parse_lt_spec(bad).unwrap_err().to_string();
            assert!(
                err.contains("valid:") || err.contains("valid kinds:"),
                "{bad}: {err}"
            );
        }
        // unknown kinds name the valid ones
        let err = cfg.parse_lt_spec("pool=64").unwrap_err().to_string();
        assert!(err.contains("valid kinds: conv, fc, lstm, embed"), "{err}");
        // out-of-range values name the range
        for bad in ["0", "conv=0", "fc=100001"] {
            let err = cfg.parse_lt_spec(bad).unwrap_err().to_string();
            assert!(err.contains("1..=100000"), "{bad}: {err}");
        }
        // a failed parse leaves the config untouched where possible
        assert_eq!(cfg.lt_conv, 50);
        assert_eq!(cfg.lt_fc, 500);
    }

    #[test]
    fn packet_dense_roundtrip() {
        let p = Packet::dense(0, vec![1.0, -2.0, 3.0]);
        assert!(p.is_dense());
        assert_eq!(p.sent(), 3);
        let mut acc = vec![1.0, 1.0, 1.0];
        p.add_into(&mut acc);
        assert_eq!(acc, vec![2.0, -1.0, 4.0]);
    }

    #[test]
    fn bufpool_recycles_capacity() {
        let mut pool = BufPool::default();
        assert!(pool.is_empty());
        let (mut i, mut v) = pool.take(); // empty pool -> fresh buffers
        i.reserve(100);
        v.reserve(100);
        let (ic, vc) = (i.capacity(), v.capacity());
        i.push(1);
        v.push(1.0);
        pool.put(i, v);
        assert_eq!(pool.len(), 1);
        let (i2, v2) = pool.take();
        assert!(i2.is_empty() && v2.is_empty(), "pooled buffers come back cleared");
        assert!(i2.capacity() >= ic && v2.capacity() >= vc, "capacity survives the pool");
    }

    #[test]
    fn bufpool_recycles_byte_buffers() {
        // the wire path's frame buffers ride the same pool
        let mut pool = BufPool::default();
        let mut b = pool.take_bytes();
        b.reserve(256);
        let cap = b.capacity();
        b.extend_from_slice(&[1, 2, 3]);
        pool.put_bytes(b);
        assert!(!pool.is_empty());
        let b2 = pool.take_bytes();
        assert!(b2.is_empty(), "pooled byte buffers come back cleared");
        assert!(b2.capacity() >= cap, "capacity survives the pool");
    }

    #[test]
    fn recycle_feeds_next_pack() {
        // after recycle, the next pack_layer reuses the returned buffers:
        // steady state allocates nothing new (capacity is stable)
        use crate::models::{LayerKind, Layout};
        use crate::util::rng::Pcg32;
        let layout = Layout::from_specs(&[("w", &[512], LayerKind::Conv)]);
        let mut c = build(&Config { lt_override: 16, ..Config::default() }, &layout);
        let mut rng = Pcg32::seeded(3);
        let dw = rng.normal_vec(512, 0.5);
        let mut prev = c.pack_layer(0, &dw);
        for _ in 0..10 {
            let sent_before = prev.sent();
            c.recycle(prev);
            prev = c.pack_layer(0, &dw);
            assert!(prev.sent() > 0 || sent_before > 0);
        }
    }

    #[test]
    fn packet_sparse_add() {
        let p = Packet {
            layer: 0,
            n: 5,
            idx: vec![1, 4],
            val: vec![2.0, -1.0],
            wire_bytes: 10,
            paper_bits: 16,
        };
        let mut acc = vec![0.0; 5];
        p.add_into(&mut acc);
        assert_eq!(acc, vec![0.0, 2.0, 0.0, 0.0, -1.0]);
        assert!((p.rate_paper() - 10.0).abs() < 1e-9);
        assert!((p.rate_wire() - 2.0).abs() < 1e-9);
    }
}
