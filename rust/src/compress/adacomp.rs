//! AdaComp — Adaptive Residual Gradient Compression (paper Algorithm 2).
//!
//! This is the L3 hot path: it runs per learner, per layer, per step. The
//! implementation is two passes over the layer and one over the bins, with
//! all scratch reused across calls (no per-step allocation in steady state):
//!
//!   pass 1 (fold+max): residue <- residue + dW (now holds G), track per-bin
//!            max |G| into `gmax`
//!   scale  = mean(|gmax|)                         (one pass over bins)
//!   pass 2 (select): h = G + (c-1)*dW; where |h| >= gmax(bin) and
//!            gmax > 0: emit (idx, sign(G)*scale), residue <- G - sent
//!
//! The soft-threshold scale factor c defaults to the paper's 2.0, making
//! `h = G + dW = residue_prev + 2*dW` — "the sum of its previous residue
//! plus the latest gradient multiplied by a scale-factor".
//!
//! Semantics are bit-identical to `python/compile/kernels/ref.py` (the
//! golden-vector test in rust/tests/golden.rs enforces this), including the
//! `gmax > 0` guard documented there.

use super::{residue::ResidueStore, wire, BufPool, Compressor, Config, Kind, Packet};
use crate::models::Layout;

pub struct AdaComp {
    residues: ResidueStore,
    /// Resolved L_T per layer.
    lts: Vec<usize>,
    /// h = G + (scale_factor - 1) * dW.
    sf_minus_1: f32,
    per_bin_scale: bool,
    /// Scratch: per-bin maxima (reused across layers/steps).
    gmax: Vec<f32>,
    /// Recycled packet buffers (zero-alloc steady state).
    pool: BufPool,
}

impl AdaComp {
    pub fn new(cfg: &Config, layout: &Layout) -> AdaComp {
        AdaComp {
            residues: ResidueStore::new(layout),
            lts: layout.layers.iter().map(|l| cfg.lt_for(l.kind).max(1)).collect(),
            sf_minus_1: cfg.scale_factor - 1.0,
            per_bin_scale: cfg.per_bin_scale,
            gmax: Vec::new(),
            pool: BufPool::default(),
        }
    }

    pub fn lt(&self, layer: usize) -> usize {
        self.lts[layer]
    }
}

impl Compressor for AdaComp {
    fn kind(&self) -> Kind {
        Kind::AdaComp
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        let lt = self.lts[layer];
        let r = self.residues.layer_mut(layer);
        let n = r.len();
        assert_eq!(dw.len(), n, "layer {layer} gradient length mismatch");
        let nbins = n.div_ceil(lt);

        // Pass 1a: fold dW into the residue (now holds G). Straight-line
        // slice zip — bounds-check free, autovectorizes.
        for (ri, &di) in r.iter_mut().zip(dw.iter()) {
            *ri += di;
        }

        // Pass 1b: per-bin max |G| (8-lane AVX2 or the scalar unroll —
        // bit-identical either way; see compress::select). chunks() handles
        // the ragged last bin.
        self.gmax.clear();
        self.gmax.reserve(nbins);
        for bin in r.chunks(lt) {
            self.gmax.push(super::select::bin_absmax(bin));
        }

        // Layer quantization scale: mean of per-bin maxima (all >= 0).
        let scale = self.gmax.iter().sum::<f32>() / nbins as f32;

        // Pass 2: soft-threshold select + ternarize + residue update
        // (compress::select — AVX2 compare+movemask prefilter with a scalar
        // hit drain, or the bit-identical scalar loop). Selection is sparse
        // (a few per bin), so the vector path turns the compare-heavy
        // no-send common case into one 8-wide test. Output goes straight
        // into recycled packet buffers (no staging copy, no steady-state
        // allocation).
        let (mut idx, mut val) = self.pool.take();
        let c1 = self.sf_minus_1;
        for (b, (rb, db)) in r.chunks_mut(lt).zip(dw.chunks(lt)).enumerate() {
            let gm = self.gmax[b];
            if gm <= 0.0 {
                continue; // all-zero bin: nothing informative to send
            }
            let q = if self.per_bin_scale { gm } else { scale };
            let base = (b * lt) as u32;
            super::select::select_bin_into(rb, db, gm, q, c1, base, &mut idx, &mut val);
        }

        // wire cost is analytic (== encode_adacomp length, pinned by
        // wire::tests::lens_match_encoders) — no encode on the hot path
        let wire_bytes = wire::adacomp_wire_len(n, lt, idx.len());
        let paper_bits = idx.len() * wire::slot_bits(lt) + 32;
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes,
            paper_bits,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.residues.layer(layer)
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        Some(self.residues.layer_mut(layer))
    }

    fn reset(&mut self) {
        self.residues.reset();
    }

    fn set_layer_lt(&mut self, layer: usize, lt: usize) {
        self.lts[layer] = lt.max(1);
    }

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};
    use crate::util::rng::Pcg32;

    fn layout_one(n: usize, kind: LayerKind) -> Layout {
        Layout::from_specs(&[("w", &[n], kind)])
    }

    fn pack_once(n: usize, lt_override: usize, dw: &[f32]) -> (Packet, Vec<f32>) {
        let layout = layout_one(n, LayerKind::Conv);
        let cfg = Config {
            lt_override,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = AdaComp::new(&cfg, &layout);
        let p = c.pack_layer(0, dw);
        let res = c.residue(0).to_vec();
        (p, res)
    }

    #[test]
    fn conservation_first_step() {
        // With zero initial residue: G = dW, and sent + residue == dW.
        let mut rng = Pcg32::seeded(1);
        let dw = rng.normal_vec(1000, 1.0);
        let (p, res) = pack_once(1000, 10, &dw);
        let mut recon = res.clone();
        p.add_into(&mut recon);
        for (a, b) in recon.iter().zip(dw.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn sent_values_are_ternary() {
        let mut rng = Pcg32::seeded(2);
        let dw = rng.normal_vec(500, 0.1);
        let (p, _) = pack_once(500, 50, &dw);
        assert!(!p.val.is_empty());
        let scale = p.val.iter().find(|v| **v != 0.0).map(|v| v.abs()).unwrap();
        for v in &p.val {
            assert!(*v == 0.0 || (v.abs() - scale).abs() < 1e-7);
        }
    }

    #[test]
    fn indices_strictly_increasing() {
        let mut rng = Pcg32::seeded(3);
        let dw = rng.normal_vec(2048, 1.0);
        let (p, _) = pack_once(2048, 64, &dw);
        for w in p.idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn zero_layer_sends_nothing() {
        let (p, res) = pack_once(100, 10, &vec![0.0; 100]);
        assert_eq!(p.sent(), 0);
        assert!(res.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn residue_accumulates_when_not_sent() {
        // Tiny uniform dW: each bin sends only its max-ish entries; the rest
        // accumulates. After two identical steps the unsent residues double.
        let layout = layout_one(100, LayerKind::Conv);
        let cfg = Config {
            lt_override: 10,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = AdaComp::new(&cfg, &layout);
        let mut rng = Pcg32::seeded(4);
        let dw = rng.normal_vec(100, 1.0);
        let p1 = c.pack_layer(0, &dw);
        let r1 = c.residue(0).to_vec();
        let _ = p1;
        let p2 = c.pack_layer(0, &dw);
        // conservation across both steps: sum(sent) + residue == 2*dW
        let mut total = c.residue(0).to_vec();
        p2.add_into(&mut total);
        let mut sent1 = vec![0.0; 100];
        // p1 values were already removed from r1; reconstruct: r1 + p1 = dw
        p1_check(&r1, &p1, &dw);
        p1.add_into(&mut sent1);
        for i in 0..100 {
            let want = 2.0 * dw[i];
            let got = total[i] + sent1[i];
            assert!((want - got).abs() < 1e-4, "{i}: {want} vs {got}");
        }
    }

    fn p1_check(r1: &[f32], p1: &Packet, dw: &[f32]) {
        let mut recon = r1.to_vec();
        p1.add_into(&mut recon);
        for (a, b) in recon.iter().zip(dw.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn wire_roundtrip_matches() {
        let mut rng = Pcg32::seeded(5);
        let dw = rng.normal_vec(777, 0.5);
        let layout = layout_one(777, LayerKind::Conv);
        let cfg = Config::with_kind(Kind::AdaComp); // lt 50 for conv
        let mut c = AdaComp::new(&cfg, &layout);
        let p = c.pack_layer(0, &dw);
        let bytes = wire::encode_adacomp(0, p.n, 50, scale_of(&p), &p.idx, &p.val);
        let q = wire::decode(&bytes.unwrap()).unwrap();
        assert_eq!(p.idx, q.idx);
        for (a, b) in p.val.iter().zip(q.val.iter()) {
            assert!((a - b).abs() < 1e-7);
        }
        // the engine's v2 wire form (what actually crosses the fabric) is
        // bitwise-exact; its measured length is the decoded wire_bytes
        let v2 = wire::encode_packet(&p).unwrap();
        let q2 = wire::decode(&v2).unwrap();
        assert_eq!(p.idx, q2.idx);
        assert_eq!(p.val, q2.val);
        assert_eq!(q2.wire_bytes, v2.len());
    }

    fn scale_of(p: &Packet) -> f32 {
        p.val.iter().find(|v| **v != 0.0).map(|v| v.abs()).unwrap_or(0.0)
    }

    #[test]
    fn soft_threshold_sends_more_than_ls_style_max() {
        // With dW comparable to residue, AdaComp sends > 1 element per bin on
        // average (the paper: "typically up to 5 per bin").
        let mut rng = Pcg32::seeded(6);
        let n = 10_000;
        let dw = rng.normal_vec(n, 1.0);
        let (p, _) = pack_once(n, 50, &dw);
        let nbins = n / 50;
        assert!(p.sent() > nbins, "sent {} <= bins {}", p.sent(), nbins);
        assert!(p.sent() < n / 2);
    }

    #[test]
    fn per_kind_lt_defaults() {
        let layout = Layout::from_specs(&[
            ("c", &[100], LayerKind::Conv),
            ("f", &[1000], LayerKind::Fc),
        ]);
        let c = AdaComp::new(&Config::default(), &layout);
        assert_eq!(c.lt(0), 50);
        assert_eq!(c.lt(1), 500);
    }

    #[test]
    fn set_layer_lt_retunes_in_place_and_keeps_residue() {
        // the controller's apply path: a live L_T change redefines the bin
        // structure for later steps without touching the residue store
        let layout = Layout::from_specs(&[("w", &[100], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: 10,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = AdaComp::new(&cfg, &layout);
        let mut rng = Pcg32::seeded(9);
        let dw = rng.normal_vec(100, 1.0);
        c.pack_layer(0, &dw);
        let residue_before = c.residue(0).to_vec();
        c.set_layer_lt(0, 50);
        assert_eq!(c.lt(0), 50);
        assert_eq!(c.residue(0), residue_before.as_slice());
        // a 0 clamps to 1 (per-element bins), never panics downstream
        c.set_layer_lt(0, 0);
        assert_eq!(c.lt(0), 1);
        let p = c.pack_layer(0, &dw);
        assert!(p.sent() > 0);
    }
}
