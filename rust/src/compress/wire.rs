//! Wire formats: the actual bytes a packet costs on the fabric.
//!
//! The paper's "Effective Compression Rate" assumes a sparse-indexed
//! representation of 8 bits per sent element for L_T < 64 and 16 bits for
//! L_T up to 16K, with 2 of those bits holding the ternary value. This
//! module implements that format *for real* — encode + decode round-trip —
//! so the simulated fabric charges honest byte counts:
//!
//! AdaComp/LS packet layout (little-endian):
//!   header (16B): scheme u8, pad u8, layer u16, n u32, lt u32, scale f32
//!   then per bin:
//!     L_T < 64   : count u8,  count x u8  slot (idx:6 | code:2)
//!     L_T <=16384: count u16, count x u16 slot (idx:14 | code:2)
//!     else       : count u32, count x u32 slot (idx:30 | code:2)
//!
//! Generic sparse packet (dryden / strom):
//!   header + count u32 + pos f32 + neg f32 + count x u32 (idx:31 | sign:1)
//!
//! Dense 1-bit packet (onebit): header + pos f32 + neg f32 + ceil(n/8) bytes.
//! Dense 2-bit packet (terngrad): header + ceil(n/4) bytes (codes as Tern).
//! Dense f32 packet (none): header + 4n bytes.
//!
//! Bucket frame (the reduce-plan's coalesced message — one wire message per
//! *bucket* of layers, amortizing per-message latency over tiny layers):
//!   bucket header (8B): tag u8 (0xB5), pad u8, bucket u16, count u32
//!   then per sub-message: len u32 + the sub-message bytes (any of the
//!   per-layer formats above). `bucket_wire_len` is the analytic length the
//!   exchange hot path charges; `encode_bucket_frame`/`decode_bucket_frame`
//!   pin it against the real encoder.

use anyhow::{bail, Result};

use super::quantize::Tern;
use super::Packet;

pub const HEADER_BYTES: usize = 16;

/// Bucket-frame header: tag u8, pad u8, bucket u16, sub-message count u32.
pub const BUCKET_HEADER_BYTES: usize = 8;

/// Frame tag identifying a bucket message.
pub const BUCKET_TAG: u8 = 0xB5;

/// Exact byte length of a bucket frame coalescing `parts` sub-messages whose
/// encoded bytes sum to `payload_bytes`: one bucket header plus a u32 length
/// prefix per sub-message. Charged once per *bucket* on the fabric — this is
/// the latency-amortization the reduce plan buys for sub-threshold layers.
pub fn bucket_wire_len(parts: usize, payload_bytes: usize) -> usize {
    BUCKET_HEADER_BYTES + 4 * parts + payload_bytes
}

pub const SCHEME_ADACOMP: u8 = 1;
pub const SCHEME_SPARSE_SIGN: u8 = 2;
pub const SCHEME_ONEBIT: u8 = 3;
pub const SCHEME_TERNARY_DENSE: u8 = 4;
pub const SCHEME_DENSE_F32: u8 = 5;

/// Slot width in bits for a given bin length (paper's 8/16-bit scheme,
/// widened to 32 past 16K so the format stays total).
pub fn slot_bits(lt: usize) -> usize {
    if lt < 64 {
        8
    } else if lt <= 16384 {
        16
    } else {
        32
    }
}

/// Exact byte length of `encode_adacomp` output, computed without
/// materializing the bytes — the pack hot path charges wire cost from this
/// (the equality with the real encoder is pinned by `lens_match_encoders`).
pub fn adacomp_wire_len(n: usize, lt: usize, sent: usize) -> usize {
    let per = slot_bits(lt) / 8; // per-bin count field and per-element slot
    HEADER_BYTES + (n.div_ceil(lt.max(1)) + sent) * per
}

/// Exact byte length of `encode_sparse_sign` output (dryden / strom).
pub fn sparse_sign_wire_len(sent: usize) -> usize {
    HEADER_BYTES + 4 + 8 + 4 * sent // count u32 + pos/neg f32 + slots
}

/// Exact byte length of `encode_onebit` output.
pub fn onebit_wire_len(n: usize) -> usize {
    HEADER_BYTES + 8 + n.div_ceil(8) // pos/neg f32 + sign bitmap
}

/// Exact byte length of `encode_ternary_dense` output (terngrad).
pub fn ternary_dense_wire_len(n: usize) -> usize {
    HEADER_BYTES + n.div_ceil(4) // 2-bit codes
}

/// Exact byte length of `encode_dense_f32` output (identity baseline).
pub fn dense_f32_wire_len(n: usize) -> usize {
    HEADER_BYTES + 4 * n
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8> {
        if self.i >= self.b.len() {
            bail!("wire underrun");
        }
        self.i += 1;
        Ok(self.b[self.i - 1])
    }
    fn u16(&mut self) -> Result<u16> {
        if self.i + 2 > self.b.len() {
            bail!("wire underrun");
        }
        let v = u16::from_le_bytes([self.b[self.i], self.b[self.i + 1]]);
        self.i += 2;
        Ok(v)
    }
    fn u32(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("wire underrun");
        }
        let v = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(v)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
}

fn header(w: &mut Writer, scheme: u8, layer: usize, n: usize, lt: usize, scale: f32) {
    w.u8(scheme);
    w.u8(0);
    w.u16(layer as u16);
    w.u32(n as u32);
    w.u32(lt as u32);
    w.f32(scale);
}

/// Encode an AdaComp/LS packet (ternary values, bin-relative indices).
/// `idx` must be strictly increasing; every `val` must be 0 or +/- scale.
pub fn encode_adacomp(layer: usize, n: usize, lt: usize, scale: f32, idx: &[u32], val: &[f32]) -> Vec<u8> {
    assert_eq!(idx.len(), val.len());
    let nbins = n.div_ceil(lt.max(1));
    let bits = slot_bits(lt);
    let mut w = Writer::new();
    header(&mut w, SCHEME_ADACOMP, layer, n, lt, scale);
    let mut k = 0usize; // cursor into idx/val
    for b in 0..nbins {
        let end = (((b + 1) * lt).min(n)) as u32;
        let start = k;
        while k < idx.len() && idx[k] < end {
            k += 1;
        }
        let count = k - start;
        match bits {
            8 => {
                debug_assert!(count <= u8::MAX as usize);
                w.u8(count as u8);
            }
            16 => w.u16(count as u16),
            _ => w.u32(count as u32),
        }
        for j in start..k {
            let rel = idx[j] - (b * lt) as u32;
            let code = if val[j] == 0.0 {
                0u32
            } else if val[j] > 0.0 {
                1
            } else {
                2
            };
            match bits {
                8 => {
                    debug_assert!(rel < 64);
                    w.u8(((rel << 2) | code) as u8);
                }
                16 => w.u16(((rel << 2) | code) as u16),
                _ => w.u32((rel << 2) | code),
            }
        }
    }
    debug_assert_eq!(k, idx.len());
    w.buf
}

/// Decode an AdaComp/LS packet back into a `Packet`.
pub fn decode(bytes: &[u8]) -> Result<Packet> {
    let mut r = Reader { b: bytes, i: 0 };
    let scheme = r.u8()?;
    let _pad = r.u8()?;
    let layer = r.u16()? as usize;
    let n = r.u32()? as usize;
    let lt = r.u32()? as usize;
    let scale = r.f32()?;
    match scheme {
        SCHEME_ADACOMP => {
            let nbins = n.div_ceil(lt.max(1));
            let bits = slot_bits(lt);
            let mut idx = Vec::new();
            let mut val = Vec::new();
            for b in 0..nbins {
                let count = match bits {
                    8 => r.u8()? as usize,
                    16 => r.u16()? as usize,
                    _ => r.u32()? as usize,
                };
                for _ in 0..count {
                    let slot = match bits {
                        8 => r.u8()? as u32,
                        16 => r.u16()? as u32,
                        _ => r.u32()?,
                    };
                    let rel = slot >> 2;
                    let code = (slot & 3) as u8;
                    idx.push((b * lt) as u32 + rel);
                    val.push(Tern::from_code(code).apply(scale));
                }
            }
            Ok(Packet {
                layer,
                n,
                idx,
                val,
                wire_bytes: bytes.len(),
                paper_bits: 0, // accounting is the encoder's job
            })
        }
        SCHEME_SPARSE_SIGN => {
            let count = r.u32()? as usize;
            let pos = r.f32()?;
            let neg = r.f32()?;
            let mut idx = Vec::with_capacity(count);
            let mut val = Vec::with_capacity(count);
            for _ in 0..count {
                let e = r.u32()?;
                idx.push(e & 0x7fff_ffff);
                val.push(if e >> 31 == 0 { pos } else { neg });
            }
            Ok(Packet { layer, n, idx, val, wire_bytes: bytes.len(), paper_bits: 0 })
        }
        SCHEME_ONEBIT => {
            let pos = r.f32()?;
            let neg = r.f32()?;
            let mut val = Vec::with_capacity(n);
            for i in 0..n {
                if i % 8 == 0 {
                    r.u8()?;
                }
                let byte = r.b[r.i - 1];
                let bit = (byte >> (i % 8)) & 1;
                val.push(if bit == 0 { pos } else { neg });
            }
            Ok(Packet { layer, n, idx: Vec::new(), val, wire_bytes: bytes.len(), paper_bits: 0 })
        }
        SCHEME_TERNARY_DENSE => {
            let mut val = Vec::with_capacity(n);
            for i in 0..n {
                if i % 4 == 0 {
                    r.u8()?;
                }
                let byte = r.b[r.i - 1];
                let code = (byte >> ((i % 4) * 2)) & 3;
                val.push(Tern::from_code(code).apply(scale));
            }
            Ok(Packet { layer, n, idx: Vec::new(), val, wire_bytes: bytes.len(), paper_bits: 0 })
        }
        SCHEME_DENSE_F32 => {
            let mut val = Vec::with_capacity(n);
            for _ in 0..n {
                val.push(r.f32()?);
            }
            Ok(Packet { layer, n, idx: Vec::new(), val, wire_bytes: bytes.len(), paper_bits: 0 })
        }
        other => bail!("unknown wire scheme {other}"),
    }
}

/// Encode a sparse sign packet (dryden / strom): indices + sign bit, with
/// +/- reconstruction values in the payload head.
pub fn encode_sparse_sign(
    layer: usize,
    n: usize,
    pos: f32,
    neg: f32,
    idx: &[u32],
    is_neg: impl Fn(usize) -> bool,
) -> Vec<u8> {
    let mut w = Writer::new();
    header(&mut w, SCHEME_SPARSE_SIGN, layer, n, 0, 0.0);
    w.u32(idx.len() as u32);
    w.f32(pos);
    w.f32(neg);
    for (j, &i) in idx.iter().enumerate() {
        let sign = if is_neg(j) { 1u32 << 31 } else { 0 };
        w.u32(i | sign);
    }
    w.buf
}

/// Encode a dense 1-bit packet (onebit): sign bitmap + two means.
pub fn encode_onebit(layer: usize, signs_neg: &[bool], pos: f32, neg: f32) -> Vec<u8> {
    let n = signs_neg.len();
    let mut w = Writer::new();
    header(&mut w, SCHEME_ONEBIT, layer, n, 0, 0.0);
    w.f32(pos);
    w.f32(neg);
    let mut byte = 0u8;
    for (i, &isneg) in signs_neg.iter().enumerate() {
        if isneg {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            w.u8(byte);
            byte = 0;
        }
    }
    if n % 8 != 0 {
        w.u8(byte);
    }
    w.buf
}

/// Encode a dense 2-bit ternary packet (terngrad).
pub fn encode_ternary_dense(layer: usize, n: usize, scale: f32, codes: impl Iterator<Item = Tern>) -> Vec<u8> {
    let mut w = Writer::new();
    header(&mut w, SCHEME_TERNARY_DENSE, layer, n, 0, scale);
    let mut byte = 0u8;
    let mut i = 0usize;
    for t in codes {
        byte |= t.code() << ((i % 4) * 2);
        if i % 4 == 3 {
            w.u8(byte);
            byte = 0;
        }
        i += 1;
    }
    assert_eq!(i, n);
    if n % 4 != 0 {
        w.u8(byte);
    }
    w.buf
}

/// Encode a dense f32 packet (identity baseline).
pub fn encode_dense_f32(layer: usize, vals: &[f32]) -> Vec<u8> {
    let mut w = Writer::new();
    header(&mut w, SCHEME_DENSE_F32, layer, vals.len(), 0, 0.0);
    for &v in vals {
        w.f32(v);
    }
    w.buf
}

/// Encode a bucket frame: the per-layer sub-messages of one reduce-plan
/// bucket coalesced into a single wire message.
pub fn encode_bucket_frame(bucket: usize, parts: &[Vec<u8>]) -> Vec<u8> {
    assert!(bucket <= u16::MAX as usize, "bucket id {bucket} overflows the frame header");
    let mut w = Writer::new();
    w.u8(BUCKET_TAG);
    w.u8(0);
    w.u16(bucket as u16);
    w.u32(parts.len() as u32);
    for p in parts {
        w.u32(p.len() as u32);
        w.buf.extend_from_slice(p);
    }
    w.buf
}

/// Decode a bucket frame back into (bucket id, per-layer packets).
pub fn decode_bucket_frame(bytes: &[u8]) -> Result<(usize, Vec<Packet>)> {
    let mut r = Reader { b: bytes, i: 0 };
    let tag = r.u8()?;
    if tag != BUCKET_TAG {
        bail!("not a bucket frame (tag {tag:#x})");
    }
    let _pad = r.u8()?;
    let bucket = r.u16()? as usize;
    let count = r.u32()? as usize;
    // every sub-message needs at least its u32 length prefix — reject a
    // lying count before trusting it with an allocation
    if count > (bytes.len() - r.i) / 4 {
        bail!("wire underrun in bucket frame (count {count})");
    }
    let mut packets = Vec::with_capacity(count);
    for _ in 0..count {
        let len = r.u32()? as usize;
        if r.i + len > r.b.len() {
            bail!("wire underrun in bucket frame");
        }
        packets.push(decode(&r.b[r.i..r.i + len])?);
        r.i += len;
    }
    Ok((bucket, packets))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adacomp_roundtrip_8bit() {
        // lt=10 < 64 -> 8-bit slots
        let idx = vec![0u32, 3, 9, 10, 25];
        let val = vec![0.5, -0.5, 0.5, 0.0, -0.5];
        let bytes = encode_adacomp(2, 30, 10, 0.5, &idx, &val);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.layer, 2);
        assert_eq!(p.n, 30);
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
        // 16 header + 3 bin counts + 5 slots
        assert_eq!(bytes.len(), 16 + 3 + 5);
    }

    #[test]
    fn adacomp_roundtrip_16bit() {
        let idx = vec![5u32, 499, 500, 1200];
        let val = vec![1.5, -1.5, 1.5, 1.5];
        let bytes = encode_adacomp(0, 1300, 500, 1.5, &idx, &val);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
        assert_eq!(bytes.len(), 16 + 3 * 2 + 4 * 2);
    }

    #[test]
    fn adacomp_roundtrip_wide() {
        let idx = vec![20000u32];
        let val = vec![-0.25];
        let bytes = encode_adacomp(1, 40000, 20000, 0.25, &idx, &val);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
    }

    #[test]
    fn adacomp_empty() {
        let bytes = encode_adacomp(0, 100, 10, 0.0, &[], &[]);
        let p = decode(&bytes).unwrap();
        assert!(p.idx.is_empty());
        assert_eq!(p.n, 100);
    }

    #[test]
    fn sparse_sign_roundtrip() {
        let idx = vec![1u32, 7, 1000];
        let bytes = encode_sparse_sign(3, 2000, 0.2, -0.3, &idx, |j| j == 1);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, vec![0.2, -0.3, 0.2]);
    }

    #[test]
    fn onebit_roundtrip() {
        let signs: Vec<bool> = (0..19).map(|i| i % 3 == 0).collect();
        let bytes = encode_onebit(0, &signs, 0.5, -0.25);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val.len(), 19);
        for (i, &v) in p.val.iter().enumerate() {
            assert_eq!(v, if i % 3 == 0 { -0.25 } else { 0.5 });
        }
        assert_eq!(bytes.len(), 16 + 8 + 3);
    }

    #[test]
    fn ternary_dense_roundtrip() {
        let codes = [Tern::Pos, Tern::Zero, Tern::Neg, Tern::Pos, Tern::Zero];
        let bytes = encode_ternary_dense(0, 5, 2.0, codes.iter().copied());
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val, vec![2.0, 0.0, -2.0, 2.0, 0.0]);
        assert_eq!(bytes.len(), 16 + 2);
    }

    #[test]
    fn dense_f32_roundtrip() {
        let vals = vec![1.0, -2.5, 3.25];
        let bytes = encode_dense_f32(4, &vals);
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val, vals);
        assert_eq!(p.layer, 4);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[1, 2, 3]).is_err());
        assert!(decode(&[99; 32]).is_err());
    }

    #[test]
    fn lens_match_encoders() {
        // adacomp, all three slot widths
        for (n, lt, idx, val) in [
            (30usize, 10usize, vec![0u32, 3, 9, 10, 25], vec![0.5f32, -0.5, 0.5, 0.0, -0.5]),
            (1300, 500, vec![5, 499, 500, 1200], vec![1.5, -1.5, 1.5, 1.5]),
            (40000, 20000, vec![20000], vec![-0.25]),
            (100, 10, vec![], vec![]),
        ] {
            let bytes = encode_adacomp(0, n, lt, 0.5, &idx, &val);
            assert_eq!(bytes.len(), adacomp_wire_len(n, lt, idx.len()), "n={n} lt={lt}");
        }
        let idx = vec![1u32, 7, 1000];
        assert_eq!(
            encode_sparse_sign(3, 2000, 0.2, -0.3, &idx, |j| j == 1).len(),
            sparse_sign_wire_len(idx.len())
        );
        for n in [1usize, 8, 19, 64] {
            let signs: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            assert_eq!(encode_onebit(0, &signs, 0.5, -0.25).len(), onebit_wire_len(n));
            let codes = (0..n).map(|i| if i % 2 == 0 { Tern::Pos } else { Tern::Zero });
            assert_eq!(encode_ternary_dense(0, n, 1.0, codes).len(), ternary_dense_wire_len(n));
            assert_eq!(encode_dense_f32(0, &vec![1.0; n]).len(), dense_f32_wire_len(n));
        }
    }

    #[test]
    fn bucket_frame_roundtrip_mixed_schemes() {
        // one bucket coalescing an adacomp layer, a tiny dense bias, and a
        // sparse-sign layer — the decoded packets must match each sub-format
        let parts = vec![
            encode_adacomp(3, 30, 10, 0.5, &[0, 9, 25], &[0.5, -0.5, 0.5]),
            encode_dense_f32(4, &[1.0, -2.0]),
            encode_sparse_sign(5, 100, 0.2, -0.3, &[7, 40], |j| j == 0),
        ];
        let bytes = encode_bucket_frame(2, &parts);
        let payload: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(bytes.len(), bucket_wire_len(parts.len(), payload));
        let (bucket, packets) = decode_bucket_frame(&bytes).unwrap();
        assert_eq!(bucket, 2);
        assert_eq!(packets.len(), 3);
        assert_eq!(packets[0].layer, 3);
        assert_eq!(packets[0].idx, vec![0, 9, 25]);
        assert_eq!(packets[1].layer, 4);
        assert_eq!(packets[1].val, vec![1.0, -2.0]);
        assert_eq!(packets[2].layer, 5);
        assert_eq!(packets[2].val, vec![-0.3, 0.2]);
    }

    #[test]
    fn bucket_frame_rejects_garbage() {
        assert!(decode_bucket_frame(&[1, 2, 3]).is_err());
        // right tag, truncated payload
        let good = encode_bucket_frame(0, &[encode_dense_f32(0, &[1.0])]);
        assert!(decode_bucket_frame(&good[..good.len() - 2]).is_err());
        // a per-layer packet is not a bucket frame
        assert!(decode_bucket_frame(&encode_dense_f32(0, &[1.0])).is_err());
        // a lying sub-message count must error, not allocate count capacity
        let bomb = [BUCKET_TAG, 0, 0, 0, 0xff, 0xff, 0xff, 0xff];
        assert!(decode_bucket_frame(&bomb).is_err());
    }

    #[test]
    fn empty_bucket_frame() {
        let bytes = encode_bucket_frame(1, &[]);
        assert_eq!(bytes.len(), BUCKET_HEADER_BYTES);
        let (bucket, packets) = decode_bucket_frame(&bytes).unwrap();
        assert_eq!(bucket, 1);
        assert!(packets.is_empty());
    }

    #[test]
    fn slot_bits_thresholds() {
        assert_eq!(slot_bits(50), 8);
        assert_eq!(slot_bits(63), 8);
        assert_eq!(slot_bits(64), 16);
        assert_eq!(slot_bits(500), 16);
        assert_eq!(slot_bits(16384), 16);
        assert_eq!(slot_bits(16385), 32);
    }
}
