//! Wire formats: the actual bytes a packet costs on the fabric.
//!
//! The paper's "Effective Compression Rate" assumes a sparse-indexed
//! representation of 8 bits per sent element for L_T < 64 and 16 bits for
//! L_T up to 16K, with 2 of those bits holding the ternary value. This
//! module implements that format *for real* — encode + decode round-trip —
//! and the exchange hot path now serializes every bucket through it, so the
//! simulated fabric charges **measured** byte counts (DESIGN.md §Wire
//! encoding).
//!
//! **v1 per-layer formats** (little-endian; scheme byte < [`V2_FLAG`]):
//!
//! AdaComp/LS packet layout:
//!   header (16B): scheme u8, pad u8, layer u16, n u32, lt u32, scale f32
//!   then per bin:
//!     L_T < 64   : count u8,  count x u8  slot (idx:6 | code:2)
//!     L_T <=16384: count u16, count x u16 slot (idx:14 | code:2)
//!     else       : count u32, count x u32 slot (idx:30 | code:2)
//!
//! Generic sparse packet (dryden / strom):
//!   header + count u32 + pos f32 + neg f32 + count x u32 (idx:31 | sign:1)
//!
//! Dense 1-bit packet (onebit): header + pos f32 + neg f32 + ceil(n/8) bytes.
//! Dense 2-bit packet (terngrad): header + ceil(n/4) bytes (codes as Tern).
//! Dense f32 packet (none): header + 4n bytes.
//!
//! **v2 sparse formats** (scheme byte ORed with [`V2_FLAG`]): the index
//! stream is delta + group-varint coded ([`super::vbyte`] — SIMD
//! stream-vbyte with a bit-identical scalar fallback), which beats the v1
//! per-bin slot scheme because typical inter-index gaps fit one or two
//! bytes and no per-bin count fields are paid:
//!
//!   ternary   : header(scale) + count u32 + vbyte idx + ceil(count/4) codes
//!   two-value : header + count u32 + a f32 + b f32 + vbyte idx
//!               + ceil(count/8) bitmap (bit 1 = second value)
//!   sparse f32: header + count u32 + vbyte idx + count x f32
//!
//! [`encode_packet_into`] picks the smallest applicable form by **bitwise**
//! value classification, so decode(encode(p)) reproduces `idx`/`val`
//! bit-exactly for every packet (including NaN and -0.0 payloads) — the
//! engine reduces *decoded* packets and stays bit-identical to the
//! pre-serialization engine. Dense packets keep their v1 forms, so dense
//! measured bytes equal the analytic `*_wire_len` (pinned by
//! `lens_match_encoders`); sparse packets go v2 and typically measure
//! *below* the analytic v1 length (asserted per model in bench_pack →
//! BENCH_wire.json).
//!
//! Bucket frame (the reduce-plan's coalesced message — one wire message per
//! *bucket* of layers, amortizing per-message latency over tiny layers):
//!   bucket header (8B): tag u8 (0xB5), pad u8, bucket u16, count u32
//!   then per sub-message: len u32 + the sub-message bytes (any of the
//!   per-layer formats above). Learners build the frame at publish time
//!   ([`encode_bucket_frame_packets_into`]); the engine decodes it through
//!   pooled buffers ([`decode_bucket_frame_into`]) and each decoded
//!   packet's `wire_bytes` is its measured sub-message length, so the
//!   topology's per-message charge equals the real frame length exactly.
//!   `bucket_wire_len` / `*_wire_len` remain as analytic cross-checks.

use anyhow::{anyhow, bail, Result};

use super::quantize::Tern;
use super::{vbyte, BufPool, Packet};

pub const HEADER_BYTES: usize = 16;

/// Bucket-frame header: tag u8, pad u8, bucket u16, sub-message count u32.
pub const BUCKET_HEADER_BYTES: usize = 8;

/// Frame tag identifying a bucket message.
pub const BUCKET_TAG: u8 = 0xB5;

/// Exact byte length of a bucket frame coalescing `parts` sub-messages whose
/// encoded bytes sum to `payload_bytes`: one bucket header plus a u32 length
/// prefix per sub-message. Charged once per *bucket* on the fabric — this is
/// the latency-amortization the reduce plan buys for sub-threshold layers.
pub fn bucket_wire_len(parts: usize, payload_bytes: usize) -> usize {
    BUCKET_HEADER_BYTES + 4 * parts + payload_bytes
}

pub const SCHEME_ADACOMP: u8 = 1;
pub const SCHEME_SPARSE_SIGN: u8 = 2;
pub const SCHEME_ONEBIT: u8 = 3;
pub const SCHEME_TERNARY_DENSE: u8 = 4;
pub const SCHEME_DENSE_F32: u8 = 5;
/// Generic sparse f32 payload — only exists in v2 (the bitwise fallback
/// when sparse values are neither ternary nor two-valued).
pub const SCHEME_SPARSE_F32: u8 = 6;

/// Scheme-byte flag selecting the v2 delta-vbyte sparse formats.
pub const V2_FLAG: u8 = 0x80;

pub const SCHEME_ADACOMP_V2: u8 = SCHEME_ADACOMP | V2_FLAG;
pub const SCHEME_SPARSE_SIGN_V2: u8 = SCHEME_SPARSE_SIGN | V2_FLAG;
pub const SCHEME_SPARSE_F32_V2: u8 = SCHEME_SPARSE_F32 | V2_FLAG;

/// Slot width in bits for a given bin length (paper's 8/16-bit scheme,
/// widened to 32 past 16K so the format stays total).
pub fn slot_bits(lt: usize) -> usize {
    if lt < 64 {
        8
    } else if lt <= 16384 {
        16
    } else {
        32
    }
}

/// Exact byte length of `encode_adacomp` output, computed without
/// materializing the bytes — the pack hot path charges wire cost from this
/// (the equality with the real encoder is pinned by `lens_match_encoders`).
pub fn adacomp_wire_len(n: usize, lt: usize, sent: usize) -> usize {
    let per = slot_bits(lt) / 8; // per-bin count field and per-element slot
    HEADER_BYTES + (n.div_ceil(lt.max(1)) + sent) * per
}

/// Exact byte length of `encode_sparse_sign` output (dryden / strom).
pub fn sparse_sign_wire_len(sent: usize) -> usize {
    HEADER_BYTES + 4 + 8 + 4 * sent // count u32 + pos/neg f32 + slots
}

/// Exact byte length of `encode_onebit` output.
pub fn onebit_wire_len(n: usize) -> usize {
    HEADER_BYTES + 8 + n.div_ceil(8) // pos/neg f32 + sign bitmap
}

/// Exact byte length of `encode_ternary_dense` output (terngrad).
pub fn ternary_dense_wire_len(n: usize) -> usize {
    HEADER_BYTES + n.div_ceil(4) // 2-bit codes
}

/// Exact byte length of `encode_dense_f32` output (identity baseline).
pub fn dense_f32_wire_len(n: usize) -> usize {
    HEADER_BYTES + 4 * n
}

/// Exact byte length of the v2 ternary sparse form for these indices.
pub fn v2_ternary_wire_len(idx: &[u32]) -> usize {
    HEADER_BYTES + 4 + vbyte::encoded_len(idx) + idx.len().div_ceil(4)
}

/// Exact byte length of the v2 two-value sparse form for these indices.
pub fn v2_two_value_wire_len(idx: &[u32]) -> usize {
    HEADER_BYTES + 4 + 8 + vbyte::encoded_len(idx) + idx.len().div_ceil(8)
}

/// Exact byte length of the v2 sparse f32 form for these indices.
pub fn v2_sparse_f32_wire_len(idx: &[u32]) -> usize {
    HEADER_BYTES + 4 + vbyte::encoded_len(idx) + 4 * idx.len()
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl Reader<'_> {
    fn u8(&mut self) -> Result<u8> {
        if self.i >= self.b.len() {
            bail!("wire underrun");
        }
        self.i += 1;
        Ok(self.b[self.i - 1])
    }
    fn u16(&mut self) -> Result<u16> {
        if self.i + 2 > self.b.len() {
            bail!("wire underrun");
        }
        let v = u16::from_le_bytes([self.b[self.i], self.b[self.i + 1]]);
        self.i += 2;
        Ok(v)
    }
    fn u32(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("wire underrun");
        }
        let v = u32::from_le_bytes(self.b[self.i..self.i + 4].try_into().unwrap());
        self.i += 4;
        Ok(v)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

/// Write the 16-byte per-layer header, failing fast on any field that
/// would silently truncate (layer > u16, n or lt > u32).
fn header_checked(
    out: &mut Vec<u8>,
    scheme: u8,
    layer: usize,
    n: usize,
    lt: usize,
    scale: f32,
) -> Result<()> {
    if layer > u16::MAX as usize {
        bail!("layer id {layer} overflows the u16 wire header");
    }
    if n > u32::MAX as usize {
        bail!("layer length {n} overflows the u32 wire header");
    }
    if lt > u32::MAX as usize {
        bail!("bin length {lt} overflows the u32 wire header");
    }
    out.push(scheme);
    out.push(0);
    put_u16(out, layer as u16);
    put_u32(out, n as u32);
    put_u32(out, lt as u32);
    put_f32(out, scale);
    Ok(())
}

/// Fail unless `idx` is strictly increasing with every index below `n` —
/// the invariant both the v1 bin walk and the v2 delta coder rely on.
fn check_sparse_idx(idx: &[u32], n: usize) -> Result<()> {
    let mut prev: Option<u32> = None;
    for &i in idx {
        if let Some(p) = prev {
            if i <= p {
                bail!("sparse indices must be strictly increasing ({i} after {p})");
            }
        }
        if i as usize >= n {
            bail!("sparse index {i} out of range for layer length {n}");
        }
        prev = Some(i);
    }
    Ok(())
}

/// Encode an AdaComp/LS packet (ternary values, bin-relative indices).
/// `idx` must be strictly increasing and below `n`; every `val` must be 0
/// or +/- scale. Fails fast on header overflow or malformed indices.
pub fn encode_adacomp(
    layer: usize,
    n: usize,
    lt: usize,
    scale: f32,
    idx: &[u32],
    val: &[f32],
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_adacomp_into(layer, n, lt, scale, idx, val, &mut out)?;
    Ok(out)
}

fn encode_adacomp_into(
    layer: usize,
    n: usize,
    lt: usize,
    scale: f32,
    idx: &[u32],
    val: &[f32],
    out: &mut Vec<u8>,
) -> Result<()> {
    if idx.len() != val.len() {
        bail!("idx/val length mismatch ({} vs {})", idx.len(), val.len());
    }
    if lt == 0 {
        bail!("adacomp bin length must be >= 1");
    }
    let bits = slot_bits(lt);
    if bits == 32 && lt > 1 << 30 {
        bail!("bin length {lt} overflows the 30-bit slot index field");
    }
    check_sparse_idx(idx, n)?;
    header_checked(out, SCHEME_ADACOMP, layer, n, lt, scale)?;
    let nbins = n.div_ceil(lt);
    let mut k = 0usize; // cursor into idx/val
    for b in 0..nbins {
        let end = (((b + 1) * lt).min(n)) as u32;
        let start = k;
        while k < idx.len() && idx[k] < end {
            k += 1;
        }
        // strictly-increasing indices below n imply count <= lt and
        // rel < lt, so the casts below cannot truncate
        let count = k - start;
        match bits {
            8 => out.push(count as u8),
            16 => put_u16(out, count as u16),
            _ => put_u32(out, count as u32),
        }
        for j in start..k {
            let rel = idx[j] - (b * lt) as u32;
            let code = if val[j] == 0.0 {
                0u32
            } else if val[j] > 0.0 {
                1
            } else {
                2
            };
            match bits {
                8 => out.push(((rel << 2) | code) as u8),
                16 => put_u16(out, ((rel << 2) | code) as u16),
                _ => put_u32(out, (rel << 2) | code),
            }
        }
    }
    debug_assert_eq!(k, idx.len());
    Ok(())
}

/// Encode a sparse sign packet (dryden / strom): indices + sign bit, with
/// +/- reconstruction values in the payload head. Fails fast on indices
/// that would collide with the sign bit (idx >= 2^31).
pub fn encode_sparse_sign(
    layer: usize,
    n: usize,
    pos: f32,
    neg: f32,
    idx: &[u32],
    is_neg: impl Fn(usize) -> bool,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header_checked(&mut out, SCHEME_SPARSE_SIGN, layer, n, 0, 0.0)?;
    put_u32(&mut out, idx.len() as u32);
    put_f32(&mut out, pos);
    put_f32(&mut out, neg);
    for (j, &i) in idx.iter().enumerate() {
        if i >= 1 << 31 {
            bail!("sparse index {i} collides with the sign bit (>= 2^31)");
        }
        let sign = if is_neg(j) { 1u32 << 31 } else { 0 };
        put_u32(&mut out, i | sign);
    }
    Ok(out)
}

/// Encode a dense 1-bit packet (onebit): sign bitmap + two means.
pub fn encode_onebit(layer: usize, signs_neg: &[bool], pos: f32, neg: f32) -> Result<Vec<u8>> {
    let n = signs_neg.len();
    let mut out = Vec::new();
    header_checked(&mut out, SCHEME_ONEBIT, layer, n, 0, 0.0)?;
    put_f32(&mut out, pos);
    put_f32(&mut out, neg);
    let mut byte = 0u8;
    for (i, &isneg) in signs_neg.iter().enumerate() {
        if isneg {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if n % 8 != 0 {
        out.push(byte);
    }
    Ok(out)
}

/// Encode a dense 2-bit ternary packet (terngrad).
pub fn encode_ternary_dense(
    layer: usize,
    n: usize,
    scale: f32,
    codes: impl Iterator<Item = Tern>,
) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    header_checked(&mut out, SCHEME_TERNARY_DENSE, layer, n, 0, scale)?;
    let mut byte = 0u8;
    let mut i = 0usize;
    for t in codes {
        byte |= t.code() << ((i % 4) * 2);
        if i % 4 == 3 {
            out.push(byte);
            byte = 0;
        }
        i += 1;
    }
    if i != n {
        bail!("ternary code count {i} != layer length {n}");
    }
    if n % 4 != 0 {
        out.push(byte);
    }
    Ok(out)
}

/// Encode a dense f32 packet (identity baseline).
pub fn encode_dense_f32(layer: usize, vals: &[f32]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_dense_f32_into(layer, vals, &mut out)?;
    Ok(out)
}

fn encode_dense_f32_into(layer: usize, vals: &[f32], out: &mut Vec<u8>) -> Result<()> {
    header_checked(out, SCHEME_DENSE_F32, layer, vals.len(), 0, 0.0)?;
    for &v in vals {
        put_f32(out, v);
    }
    Ok(())
}

/// Bitwise ternary classification: `Some(scale)` when every value is +0.0,
/// `+scale`, or `-scale` for one shared magnitude bit pattern. -0.0 has no
/// ternary code (decode would resurrect it as +0.0), so it rejects — the
/// caller falls through to a bit-exact form.
fn uniform_ternary_scale(val: &[f32]) -> Option<f32> {
    let mut mag: u32 = 0; // shared |scale| bits; 0 until a nonzero is seen
    for &v in val {
        let bits = v.to_bits();
        if bits == 0 {
            continue; // +0.0 -> Tern::Zero
        }
        let m = bits & 0x7fff_ffff;
        if m == 0 {
            return None; // -0.0
        }
        if mag == 0 {
            mag = m;
        } else if mag != m {
            return None;
        }
    }
    Some(f32::from_bits(mag))
}

/// Bitwise two-value classification: `Some((a, b))` when at most two
/// distinct f32 bit patterns occur (`a` = first seen, `b` = second; both
/// default forward so empty/uniform inputs still encode).
fn two_distinct_bits(val: &[f32]) -> Option<(f32, f32)> {
    let mut a: Option<u32> = None;
    let mut b: Option<u32> = None;
    for &v in val {
        let bits = v.to_bits();
        if Some(bits) == a || Some(bits) == b {
            continue;
        }
        if a.is_none() {
            a = Some(bits);
        } else if b.is_none() {
            b = Some(bits);
        } else {
            return None;
        }
    }
    let a = a.unwrap_or(0);
    let b = b.unwrap_or(a);
    Some((f32::from_bits(a), f32::from_bits(b)))
}

fn tern_of_bits(bits: u32) -> Tern {
    if bits == 0 {
        Tern::Zero
    } else if bits & 0x8000_0000 == 0 {
        Tern::Pos
    } else {
        Tern::Neg
    }
}

/// Append the 2-bit ternary code stream for `val` (bitwise sign/zero codes).
fn put_tern_codes(val: &[f32], out: &mut Vec<u8>) {
    let mut byte = 0u8;
    for (i, &v) in val.iter().enumerate() {
        byte |= tern_of_bits(v.to_bits()).code() << ((i % 4) * 2);
        if i % 4 == 3 {
            out.push(byte);
            byte = 0;
        }
    }
    if val.len() % 4 != 0 {
        out.push(byte);
    }
}

/// ONEBIT with the bitmap derived bitwise from `vals` (bit 1 = value `b`).
fn encode_onebit_bits_into(
    layer: usize,
    vals: &[f32],
    a: f32,
    b: f32,
    out: &mut Vec<u8>,
) -> Result<()> {
    header_checked(out, SCHEME_ONEBIT, layer, vals.len(), 0, 0.0)?;
    put_f32(out, a);
    put_f32(out, b);
    let a_bits = a.to_bits();
    let mut byte = 0u8;
    for (i, &v) in vals.iter().enumerate() {
        if v.to_bits() != a_bits {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if vals.len() % 8 != 0 {
        out.push(byte);
    }
    Ok(())
}

/// Append the smallest self-describing wire form of `p` to `out`.
///
/// Selection is by **bitwise** value classification, never by scheme name,
/// so decode(encode(p)) reproduces `idx`/`val` bit-exactly for any packet:
///
/// - dense: two-value → v1 ONEBIT, ternary → v1 TERNARY_DENSE, else v1
///   DENSE_F32 (dense measured bytes == the analytic `*_wire_len`s);
/// - sparse: ternary → v2 ternary, two-value → v2 two-value, else v2
///   sparse f32 (when both apply the smaller wins — ternary pays 2
///   bits/element, two-value 1 bit/element plus an 8-byte value head).
///
/// This is the learner's publish-time hot path: `out` is the bucket
/// cell's pooled frame buffer, so steady state allocates nothing.
pub fn encode_packet_into(p: &Packet, out: &mut Vec<u8>) -> Result<()> {
    if p.is_dense() {
        let two = two_distinct_bits(&p.val);
        let tern = uniform_ternary_scale(&p.val);
        let one_extra = 8 + p.n.div_ceil(8);
        let tern_extra = p.n.div_ceil(4);
        if let Some(scale) = tern {
            if two.is_none() || tern_extra <= one_extra {
                header_checked(out, SCHEME_TERNARY_DENSE, p.layer, p.n, 0, scale)?;
                put_tern_codes(&p.val, out);
                return Ok(());
            }
        }
        if let Some((a, b)) = two {
            return encode_onebit_bits_into(p.layer, &p.val, a, b, out);
        }
        return encode_dense_f32_into(p.layer, &p.val, out);
    }
    if p.idx.len() != p.val.len() {
        bail!("sparse packet idx/val length mismatch");
    }
    check_sparse_idx(&p.idx, p.n)?;
    let c = p.idx.len();
    let two = two_distinct_bits(&p.val);
    let tern = uniform_ternary_scale(&p.val);
    let tern_extra = c.div_ceil(4);
    let two_extra = 8 + c.div_ceil(8);
    if let Some(scale) = tern {
        if two.is_none() || tern_extra <= two_extra {
            header_checked(out, SCHEME_ADACOMP_V2, p.layer, p.n, 0, scale)?;
            put_u32(out, c as u32);
            vbyte::encode_into(&p.idx, out);
            put_tern_codes(&p.val, out);
            return Ok(());
        }
    }
    if let Some((a, b)) = two {
        header_checked(out, SCHEME_SPARSE_SIGN_V2, p.layer, p.n, 0, 0.0)?;
        put_u32(out, c as u32);
        put_f32(out, a);
        put_f32(out, b);
        vbyte::encode_into(&p.idx, out);
        let a_bits = a.to_bits();
        let mut byte = 0u8;
        for (i, &v) in p.val.iter().enumerate() {
            if v.to_bits() != a_bits {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                out.push(byte);
                byte = 0;
            }
        }
        if c % 8 != 0 {
            out.push(byte);
        }
        return Ok(());
    }
    header_checked(out, SCHEME_SPARSE_F32_V2, p.layer, p.n, 0, 0.0)?;
    put_u32(out, c as u32);
    vbyte::encode_into(&p.idx, out);
    for &v in &p.val {
        put_f32(out, v);
    }
    Ok(())
}

/// [`encode_packet_into`] into a fresh buffer (tests / benches).
pub fn encode_packet(p: &Packet) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_packet_into(p, &mut out)?;
    Ok(out)
}

/// Decode any per-layer wire format back into a `Packet`.
pub fn decode(bytes: &[u8]) -> Result<Packet> {
    let mut idx = Vec::new();
    let mut val = Vec::new();
    let (layer, n) = decode_into(bytes, &mut idx, &mut val)?;
    Ok(Packet {
        layer,
        n,
        idx,
        val,
        wire_bytes: bytes.len(),
        paper_bits: 0, // accounting is the encoder's job
    })
}

/// Decode any per-layer wire format into caller-owned buffers (cleared
/// first — capacity is reused, the exchange hot path allocates nothing in
/// steady state). Returns `(layer, n)`. Every branch rejects counts whose
/// implied payload exceeds the buffer *before* reserving memory, so a
/// corrupt length field errors instead of allocating.
pub fn decode_into(bytes: &[u8], idx: &mut Vec<u32>, val: &mut Vec<f32>) -> Result<(usize, usize)> {
    idx.clear();
    val.clear();
    let mut r = Reader { b: bytes, i: 0 };
    let scheme = r.u8()?;
    let _pad = r.u8()?;
    let layer = r.u16()? as usize;
    let n = r.u32()? as usize;
    let lt = r.u32()? as usize;
    let scale = r.f32()?;
    match scheme {
        SCHEME_ADACOMP => {
            let nbins = n.div_ceil(lt.max(1));
            let bits = slot_bits(lt);
            // every bin carries at least its count field
            if r.remaining() / (bits / 8) < nbins {
                bail!("wire underrun (adacomp bin counts)");
            }
            for b in 0..nbins {
                let count = match bits {
                    8 => r.u8()? as usize,
                    16 => r.u16()? as usize,
                    _ => r.u32()? as usize,
                };
                for _ in 0..count {
                    let slot = match bits {
                        8 => r.u8()? as u32,
                        16 => r.u16()? as u32,
                        _ => r.u32()?,
                    };
                    let rel = slot >> 2;
                    let code = (slot & 3) as u8;
                    idx.push((b * lt) as u32 + rel);
                    val.push(Tern::from_code(code).apply(scale));
                }
            }
        }
        SCHEME_SPARSE_SIGN => {
            let count = r.u32()? as usize;
            let pos = r.f32()?;
            let neg = r.f32()?;
            if r.remaining() / 4 < count {
                bail!("wire underrun (sparse-sign count {count})");
            }
            idx.reserve(count);
            val.reserve(count);
            for _ in 0..count {
                let e = r.u32()?;
                idx.push(e & 0x7fff_ffff);
                val.push(if e >> 31 == 0 { pos } else { neg });
            }
        }
        SCHEME_ONEBIT => {
            let pos = r.f32()?;
            let neg = r.f32()?;
            if r.remaining() < n.div_ceil(8) {
                bail!("wire underrun (onebit bitmap for n {n})");
            }
            val.reserve(n);
            let mut byte = 0u8;
            for i in 0..n {
                if i % 8 == 0 {
                    byte = r.u8()?;
                }
                let bit = (byte >> (i % 8)) & 1;
                val.push(if bit == 0 { pos } else { neg });
            }
        }
        SCHEME_TERNARY_DENSE => {
            if r.remaining() < n.div_ceil(4) {
                bail!("wire underrun (ternary codes for n {n})");
            }
            val.reserve(n);
            let mut byte = 0u8;
            for i in 0..n {
                if i % 4 == 0 {
                    byte = r.u8()?;
                }
                let code = (byte >> ((i % 4) * 2)) & 3;
                val.push(Tern::from_code(code).apply(scale));
            }
        }
        SCHEME_DENSE_F32 => {
            if r.remaining() / 4 < n {
                bail!("wire underrun (dense f32 for n {n})");
            }
            val.reserve(n);
            for _ in 0..n {
                val.push(r.f32()?);
            }
        }
        SCHEME_ADACOMP_V2 => {
            let count = decode_v2_idx(&mut r, n, idx)?;
            if r.remaining() < count.div_ceil(4) {
                bail!("wire underrun (v2 ternary codes)");
            }
            val.reserve(count);
            let mut byte = 0u8;
            for i in 0..count {
                if i % 4 == 0 {
                    byte = r.u8()?;
                }
                let code = (byte >> ((i % 4) * 2)) & 3;
                val.push(Tern::from_code(code).apply(scale));
            }
        }
        SCHEME_SPARSE_SIGN_V2 => {
            let count = r.u32()? as usize;
            if count > n {
                bail!("sparse count {count} exceeds layer length {n}");
            }
            let a = r.f32()?;
            let b = r.f32()?;
            let used = vbyte::decode_into(count, &r.b[r.i..], idx)?;
            r.i += used;
            if idx.iter().any(|&i| i as usize >= n) {
                bail!("decoded sparse index out of range for layer length {n}");
            }
            if r.remaining() < count.div_ceil(8) {
                bail!("wire underrun (v2 two-value bitmap)");
            }
            val.reserve(count);
            let mut byte = 0u8;
            for i in 0..count {
                if i % 8 == 0 {
                    byte = r.u8()?;
                }
                val.push(if (byte >> (i % 8)) & 1 == 0 { a } else { b });
            }
        }
        SCHEME_SPARSE_F32_V2 => {
            let count = decode_v2_idx(&mut r, n, idx)?;
            if r.remaining() / 4 < count {
                bail!("wire underrun (v2 sparse f32)");
            }
            val.reserve(count);
            for _ in 0..count {
                val.push(r.f32()?);
            }
        }
        other => bail!("unknown wire scheme {other}"),
    }
    Ok((layer, n))
}

/// Shared v2 prologue: count u32 + delta-vbyte index stream, bounds-checked
/// against the layer length.
fn decode_v2_idx(r: &mut Reader<'_>, n: usize, idx: &mut Vec<u32>) -> Result<usize> {
    let count = r.u32()? as usize;
    if count > n {
        bail!("sparse count {count} exceeds layer length {n}");
    }
    let used = vbyte::decode_into(count, &r.b[r.i..], idx)?;
    r.i += used;
    if idx.iter().any(|&i| i as usize >= n) {
        bail!("decoded sparse index out of range for layer length {n}");
    }
    Ok(count)
}

/// Encode a bucket frame: the per-layer sub-messages of one reduce-plan
/// bucket coalesced into a single wire message.
pub fn encode_bucket_frame(bucket: usize, parts: &[Vec<u8>]) -> Vec<u8> {
    assert!(bucket <= u16::MAX as usize, "bucket id {bucket} overflows the frame header");
    let mut out = Vec::new();
    out.push(BUCKET_TAG);
    out.push(0);
    put_u16(&mut out, bucket as u16);
    put_u32(&mut out, parts.len() as u32);
    for p in parts {
        put_u32(&mut out, p.len() as u32);
        out.extend_from_slice(p);
    }
    out
}

/// Encode a completed bucket's cell slots into `out` (cleared first — this
/// is the learner's publish-time frame buffer, reused every step). Each
/// packet goes through [`encode_packet_into`], so the frame length is the
/// *measured* wire cost the fabric will charge for this bucket message.
pub fn encode_bucket_frame_packets_into(
    bucket: usize,
    slots: &[Option<Packet>],
    out: &mut Vec<u8>,
) -> Result<()> {
    out.clear();
    if bucket > u16::MAX as usize {
        bail!("bucket id {bucket} overflows the frame header");
    }
    out.push(BUCKET_TAG);
    out.push(0);
    put_u16(out, bucket as u16);
    put_u32(out, slots.len() as u32);
    for s in slots {
        let p = s
            .as_ref()
            .ok_or_else(|| anyhow!("bucket frame encode: missing packet"))?;
        let at = out.len();
        put_u32(out, 0); // length backfilled after the sub-message encodes
        encode_packet_into(p, out)?;
        let len = out.len() - at - 4;
        out[at..at + 4].copy_from_slice(&(len as u32).to_le_bytes());
    }
    Ok(())
}

/// Decode a bucket frame back into (bucket id, per-layer packets).
pub fn decode_bucket_frame(bytes: &[u8]) -> Result<(usize, Vec<Packet>)> {
    let mut pool = BufPool::default();
    let mut out = Vec::new();
    let bucket = decode_bucket_frame_into(bytes, &mut pool, &mut out)?;
    Ok((bucket, out))
}

/// Decode a bucket frame, appending one packet per sub-message to `out`
/// with `idx`/`val` drawn from `pool` (the exchange hot path — steady
/// state allocates nothing). Each decoded packet's `wire_bytes` is its
/// measured sub-message length, so a topology summing them plus
/// [`bucket_wire_len`] framing charges exactly `bytes.len()`. Returns the
/// frame's bucket id.
pub fn decode_bucket_frame_into(
    bytes: &[u8],
    pool: &mut BufPool,
    out: &mut Vec<Packet>,
) -> Result<usize> {
    let mut r = Reader { b: bytes, i: 0 };
    let tag = r.u8()?;
    if tag != BUCKET_TAG {
        bail!("not a bucket frame (tag {tag:#x})");
    }
    let _pad = r.u8()?;
    let bucket = r.u16()? as usize;
    let count = r.u32()? as usize;
    // every sub-message needs at least its u32 length prefix — reject a
    // lying count before trusting it with an allocation
    if count > r.remaining() / 4 {
        bail!("wire underrun in bucket frame (count {count})");
    }
    for _ in 0..count {
        let len = r.u32()? as usize;
        if r.i + len > r.b.len() {
            bail!("wire underrun in bucket frame");
        }
        let (mut idx, mut val) = pool.take();
        let (layer, n) = decode_into(&r.b[r.i..r.i + len], &mut idx, &mut val)?;
        out.push(Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes: len,
            paper_bits: 0,
        });
        r.i += len;
    }
    Ok(bucket)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits_of(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn sparse_packet(n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
        Packet {
            layer: 1,
            n,
            idx,
            val,
            wire_bytes: 0,
            paper_bits: 0,
        }
    }

    #[test]
    fn adacomp_roundtrip_8bit() {
        // lt=10 < 64 -> 8-bit slots
        let idx = vec![0u32, 3, 9, 10, 25];
        let val = vec![0.5, -0.5, 0.5, 0.0, -0.5];
        let bytes = encode_adacomp(2, 30, 10, 0.5, &idx, &val).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.layer, 2);
        assert_eq!(p.n, 30);
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
        // 16 header + 3 bin counts + 5 slots
        assert_eq!(bytes.len(), 16 + 3 + 5);
    }

    #[test]
    fn adacomp_roundtrip_16bit() {
        let idx = vec![5u32, 499, 500, 1200];
        let val = vec![1.5, -1.5, 1.5, 1.5];
        let bytes = encode_adacomp(0, 1300, 500, 1.5, &idx, &val).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
        assert_eq!(bytes.len(), 16 + 3 * 2 + 4 * 2);
    }

    #[test]
    fn adacomp_roundtrip_wide() {
        let idx = vec![20000u32];
        let val = vec![-0.25];
        let bytes = encode_adacomp(1, 40000, 20000, 0.25, &idx, &val).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, val);
    }

    #[test]
    fn adacomp_empty() {
        let bytes = encode_adacomp(0, 100, 10, 0.0, &[], &[]).unwrap();
        let p = decode(&bytes).unwrap();
        assert!(p.idx.is_empty());
        assert_eq!(p.n, 100);
    }

    #[test]
    fn sparse_sign_roundtrip() {
        let idx = vec![1u32, 7, 1000];
        let bytes = encode_sparse_sign(3, 2000, 0.2, -0.3, &idx, |j| j == 1).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.idx, idx);
        assert_eq!(p.val, vec![0.2, -0.3, 0.2]);
    }

    #[test]
    fn onebit_roundtrip() {
        let signs: Vec<bool> = (0..19).map(|i| i % 3 == 0).collect();
        let bytes = encode_onebit(0, &signs, 0.5, -0.25).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val.len(), 19);
        for (i, &v) in p.val.iter().enumerate() {
            assert_eq!(v, if i % 3 == 0 { -0.25 } else { 0.5 });
        }
        assert_eq!(bytes.len(), 16 + 8 + 3);
    }

    #[test]
    fn ternary_dense_roundtrip() {
        let codes = [Tern::Pos, Tern::Zero, Tern::Neg, Tern::Pos, Tern::Zero];
        let bytes = encode_ternary_dense(0, 5, 2.0, codes.iter().copied()).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val, vec![2.0, 0.0, -2.0, 2.0, 0.0]);
        assert_eq!(bytes.len(), 16 + 2);
    }

    #[test]
    fn dense_f32_roundtrip() {
        let vals = vec![1.0, -2.5, 3.25];
        let bytes = encode_dense_f32(4, &vals).unwrap();
        let p = decode(&bytes).unwrap();
        assert_eq!(p.val, vals);
        assert_eq!(p.layer, 4);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[1, 2, 3]).is_err());
        assert!(decode(&[99; 32]).is_err());
    }

    /// Build a raw header by hand (the only way to exercise lying counts —
    /// the checked encoders refuse to produce them).
    fn raw_header(scheme: u8, n: u32, lt: u32) -> Vec<u8> {
        let mut b = vec![scheme, 0, 0, 0];
        b.extend_from_slice(&n.to_le_bytes());
        b.extend_from_slice(&lt.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_le_bytes());
        b
    }

    #[test]
    fn decode_rejects_lying_counts_before_allocating() {
        // each header claims a huge element count with a near-empty payload;
        // decode must error out without reserving that much memory
        let mut onebit = raw_header(SCHEME_ONEBIT, u32::MAX, 0);
        onebit.extend_from_slice(&[0; 9]); // pos/neg + one bitmap byte
        assert!(decode(&onebit).is_err());

        let mut tern = raw_header(SCHEME_TERNARY_DENSE, u32::MAX, 0);
        tern.push(0);
        assert!(decode(&tern).is_err());

        let mut dense = raw_header(SCHEME_DENSE_F32, u32::MAX, 0);
        dense.extend_from_slice(&[0; 8]);
        assert!(decode(&dense).is_err());

        let mut sign = raw_header(SCHEME_SPARSE_SIGN, 100, 0);
        sign.extend_from_slice(&u32::MAX.to_le_bytes()); // lying count
        sign.extend_from_slice(&[0; 12]);
        assert!(decode(&sign).is_err());

        let mut ada = raw_header(SCHEME_ADACOMP, u32::MAX, 1); // ~4e9 bins
        ada.extend_from_slice(&[0; 4]);
        assert!(decode(&ada).is_err());

        let mut v2 = raw_header(SCHEME_ADACOMP_V2, 100, 0);
        v2.extend_from_slice(&u32::MAX.to_le_bytes()); // count > n
        assert!(decode(&v2).is_err());
    }

    #[test]
    fn encoders_reject_header_overflow() {
        // layer id silently truncated to u16 before this guard existed
        assert!(encode_dense_f32(70_000, &[1.0]).is_err());
        assert!(encode_onebit(70_000, &[true], 0.5, -0.5).is_err());
        assert!(encode_adacomp(70_000, 10, 10, 0.5, &[0], &[0.5]).is_err());
        assert!(encode_sparse_sign(70_000, 10, 0.5, -0.5, &[0], |_| false).is_err());
        assert!(encode_ternary_dense(70_000, 1, 1.0, [Tern::Pos].into_iter()).is_err());
    }

    #[test]
    fn adacomp_encode_validates_indices() {
        // non-increasing
        assert!(encode_adacomp(0, 30, 10, 0.5, &[5, 5], &[0.5, 0.5]).is_err());
        assert!(encode_adacomp(0, 30, 10, 0.5, &[9, 3], &[0.5, 0.5]).is_err());
        // out of range
        assert!(encode_adacomp(0, 30, 10, 0.5, &[30], &[0.5]).is_err());
        // idx/val mismatch
        assert!(encode_adacomp(0, 30, 10, 0.5, &[1, 2], &[0.5]).is_err());
        // degenerate bin length
        assert!(encode_adacomp(0, 30, 0, 0.5, &[], &[]).is_err());
    }

    #[test]
    fn sparse_sign_rejects_sign_bit_collision() {
        // idx >= 2^31 would silently alias the sign bit
        assert!(encode_sparse_sign(0, usize::MAX, 0.5, -0.5, &[1 << 31], |_| false).is_err());
        let ok = encode_sparse_sign(0, usize::MAX, 0.5, -0.5, &[(1 << 31) - 1], |_| true).unwrap();
        let p = decode(&ok).unwrap();
        assert_eq!(p.idx, vec![(1 << 31) - 1]);
        assert_eq!(p.val, vec![-0.5]);
    }

    #[test]
    fn lens_match_encoders() {
        // adacomp, all three slot widths
        for (n, lt, idx, val) in [
            (30usize, 10usize, vec![0u32, 3, 9, 10, 25], vec![0.5f32, -0.5, 0.5, 0.0, -0.5]),
            (1300, 500, vec![5, 499, 500, 1200], vec![1.5, -1.5, 1.5, 1.5]),
            (40000, 20000, vec![20000], vec![-0.25]),
            (100, 10, vec![], vec![]),
        ] {
            let bytes = encode_adacomp(0, n, lt, 0.5, &idx, &val).unwrap();
            assert_eq!(bytes.len(), adacomp_wire_len(n, lt, idx.len()), "n={n} lt={lt}");
        }
        let idx = vec![1u32, 7, 1000];
        assert_eq!(
            encode_sparse_sign(3, 2000, 0.2, -0.3, &idx, |j| j == 1).unwrap().len(),
            sparse_sign_wire_len(idx.len())
        );
        for n in [1usize, 8, 19, 64] {
            let signs: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            assert_eq!(encode_onebit(0, &signs, 0.5, -0.25).unwrap().len(), onebit_wire_len(n));
            let codes = (0..n).map(|i| if i % 2 == 0 { Tern::Pos } else { Tern::Zero });
            assert_eq!(
                encode_ternary_dense(0, n, 1.0, codes).unwrap().len(),
                ternary_dense_wire_len(n)
            );
            assert_eq!(encode_dense_f32(0, &vec![1.0; n]).unwrap().len(), dense_f32_wire_len(n));
        }
    }

    #[test]
    fn v2_lens_match_encoders() {
        // ternary sparse (has a zero value, so two-value can't apply)
        let p = sparse_packet(4000, vec![2, 700, 701, 1500, 3999], vec![0.5, -0.5, 0.0, 0.5, -0.5]);
        let bytes = encode_packet(&p).unwrap();
        assert_eq!(bytes[0], SCHEME_ADACOMP_V2);
        assert_eq!(bytes.len(), v2_ternary_wire_len(&p.idx));

        // two distinct non-ternary values
        let p = sparse_packet(4000, vec![5, 9, 2000], vec![0.25, -0.75, 0.25]);
        let bytes = encode_packet(&p).unwrap();
        assert_eq!(bytes[0], SCHEME_SPARSE_SIGN_V2);
        assert_eq!(bytes.len(), v2_two_value_wire_len(&p.idx));

        // arbitrary values fall through to sparse f32
        let p = sparse_packet(4000, vec![5, 9, 2000], vec![0.25, -0.75, 1.5]);
        let bytes = encode_packet(&p).unwrap();
        assert_eq!(bytes[0], SCHEME_SPARSE_F32_V2);
        assert_eq!(bytes.len(), v2_sparse_f32_wire_len(&p.idx));
    }

    #[test]
    fn packet_roundtrips_bitwise_per_classification() {
        let cases = vec![
            // ternary sparse (with a literal zero)
            sparse_packet(4000, vec![2, 700, 701, 1500], vec![0.5, -0.5, 0.0, 0.5]),
            // two-value sparse, values that aren't +/- pairs
            sparse_packet(10_000, vec![1, 5000, 9999], vec![0.1, 0.7, 0.1]),
            // arbitrary sparse f32 (3+ distinct values)
            sparse_packet(100, vec![0, 50, 99], vec![1.0, -2.0, 3.5]),
            // -0.0 cannot be ternary: falls to two-value, still bit-exact
            sparse_packet(100, vec![3, 4], vec![-0.0, 0.5]),
            // NaN payloads survive bitwise
            sparse_packet(100, vec![3, 4, 7], vec![f32::NAN, 0.5, -1.5]),
            // empty sparse packet
            sparse_packet(100, vec![], vec![]),
            // dense arbitrary
            Packet::dense(3, vec![1.0, -2.5, 3.25, 0.0]),
            // dense two-value
            Packet::dense(3, vec![0.5, -0.25, 0.5, 0.5, -0.25]),
            // dense ternary
            Packet::dense(3, vec![0.75, 0.0, -0.75, 0.0]),
        ];
        for p in cases {
            let bytes = encode_packet(&p).unwrap();
            let q = decode(&bytes).unwrap();
            assert_eq!(q.layer, p.layer);
            assert_eq!(q.n, p.n);
            assert_eq!(q.idx, p.idx, "idx mismatch (scheme {})", bytes[0]);
            assert_eq!(bits_of(&q.val), bits_of(&p.val), "val bits mismatch (scheme {})", bytes[0]);
            assert_eq!(q.wire_bytes, bytes.len());
        }
    }

    #[test]
    fn dense_packet_measured_equals_analytic() {
        // the dense schemes keep their v1 forms, so the engine's measured
        // bytes match the compressors' analytic wire_bytes exactly
        let tern = Packet::dense(0, vec![0.5, 0.0, -0.5, 0.5, 0.0, 0.5, -0.5]);
        assert_eq!(encode_packet(&tern).unwrap().len(), ternary_dense_wire_len(7));
        let one: Vec<f32> = (0..100).map(|i| if i % 3 == 0 { 0.2 } else { -0.4 }).collect();
        assert_eq!(encode_packet(&Packet::dense(0, one)).unwrap().len(), onebit_wire_len(100));
        let raw: Vec<f32> = (0..33).map(|i| i as f32 * 0.37 - 5.0).collect();
        assert_eq!(encode_packet(&Packet::dense(0, raw)).unwrap().len(), dense_f32_wire_len(33));
    }

    #[test]
    fn v2_shrinks_adacomp_indices_in_16bit_regime() {
        // fc-style layer: lt=500 -> 16-bit slots; ~0.4% density
        let n = 100_000usize;
        let lt = 500usize;
        let idx: Vec<u32> = (0..n as u32).step_by(250).collect();
        let val: Vec<f32> = idx.iter().map(|&i| if i % 500 == 0 { 0.5 } else { -0.5 }).collect();
        let v1 = encode_adacomp(0, n, lt, 0.5, &idx, &val).unwrap();
        let p = sparse_packet(n, idx, val);
        let v2 = encode_packet(&p).unwrap();
        assert!(
            v2.len() < v1.len(),
            "v2 ({}) must beat v1 ({}) in the 16-bit slot regime",
            v2.len(),
            v1.len()
        );
        let q = decode(&v2).unwrap();
        assert_eq!(q.idx, p.idx);
        assert_eq!(q.val, p.val);
    }

    #[test]
    fn v2_truncation_errors_not_panics() {
        let p = sparse_packet(4000, vec![2, 700, 701, 1500], vec![0.5, -0.5, 0.0, 0.5]);
        let bytes = encode_packet(&p).unwrap();
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn bucket_frame_roundtrip_mixed_schemes() {
        // one bucket coalescing an adacomp layer, a tiny dense bias, and a
        // sparse-sign layer — the decoded packets must match each sub-format
        let parts = vec![
            encode_adacomp(3, 30, 10, 0.5, &[0, 9, 25], &[0.5, -0.5, 0.5]).unwrap(),
            encode_dense_f32(4, &[1.0, -2.0]).unwrap(),
            encode_sparse_sign(5, 100, 0.2, -0.3, &[7, 40], |j| j == 0).unwrap(),
        ];
        let bytes = encode_bucket_frame(2, &parts);
        let payload: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(bytes.len(), bucket_wire_len(parts.len(), payload));
        let (bucket, packets) = decode_bucket_frame(&bytes).unwrap();
        assert_eq!(bucket, 2);
        assert_eq!(packets.len(), 3);
        assert_eq!(packets[0].layer, 3);
        assert_eq!(packets[0].idx, vec![0, 9, 25]);
        assert_eq!(packets[1].layer, 4);
        assert_eq!(packets[1].val, vec![1.0, -2.0]);
        assert_eq!(packets[2].layer, 5);
        assert_eq!(packets[2].val, vec![-0.3, 0.2]);
    }

    #[test]
    fn bucket_frame_from_slots_measures_exactly() {
        // the publish-time encoder must agree byte-for-byte with framing
        // per-packet encodes, and decoded wire_bytes must sum (with the
        // frame overhead) to the real frame length — the measured-bytes
        // contract the fabric charge relies on
        let slots = vec![
            Some(sparse_packet(4000, vec![2, 700, 1500], vec![0.5, -0.5, 0.5])),
            Some(Packet::dense(2, vec![1.0, -2.0, 0.25])),
        ];
        let mut frame = Vec::new();
        encode_bucket_frame_packets_into(7, &slots, &mut frame).unwrap();
        let parts: Vec<Vec<u8>> = slots
            .iter()
            .map(|s| encode_packet(s.as_ref().unwrap()).unwrap())
            .collect();
        assert_eq!(frame, encode_bucket_frame(7, &parts));

        let mut pool = BufPool::default();
        let mut out = Vec::new();
        let bucket = decode_bucket_frame_into(&frame, &mut pool, &mut out).unwrap();
        assert_eq!(bucket, 7);
        assert_eq!(out.len(), 2);
        let payload: usize = out.iter().map(|p| p.wire_bytes).sum();
        assert_eq!(bucket_wire_len(out.len(), payload), frame.len());
        for (p, s) in out.iter().zip(slots.iter()) {
            let s = s.as_ref().unwrap();
            assert_eq!(p.idx, s.idx);
            assert_eq!(bits_of(&p.val), bits_of(&s.val));
        }

        // a missing slot is a caller bug surfaced as an error, not a panic
        let holey = vec![Some(Packet::dense(0, vec![1.0])), None];
        assert!(encode_bucket_frame_packets_into(0, &holey, &mut frame).is_err());
    }

    #[test]
    fn bucket_frame_rejects_garbage() {
        assert!(decode_bucket_frame(&[1, 2, 3]).is_err());
        // right tag, truncated payload
        let good = encode_bucket_frame(0, &[encode_dense_f32(0, &[1.0]).unwrap()]);
        assert!(decode_bucket_frame(&good[..good.len() - 2]).is_err());
        // a per-layer packet is not a bucket frame
        assert!(decode_bucket_frame(&encode_dense_f32(0, &[1.0]).unwrap()).is_err());
        // a lying sub-message count must error, not allocate count capacity
        let bomb = [BUCKET_TAG, 0, 0, 0, 0xff, 0xff, 0xff, 0xff];
        assert!(decode_bucket_frame(&bomb).is_err());
    }

    #[test]
    fn empty_bucket_frame() {
        let bytes = encode_bucket_frame(1, &[]);
        assert_eq!(bytes.len(), BUCKET_HEADER_BYTES);
        let (bucket, packets) = decode_bucket_frame(&bytes).unwrap();
        assert_eq!(bucket, 1);
        assert!(packets.is_empty());
    }

    #[test]
    fn slot_bits_thresholds() {
        assert_eq!(slot_bits(50), 8);
        assert_eq!(slot_bits(63), 8);
        assert_eq!(slot_bits(64), 16);
        assert_eq!(slot_bits(500), 16);
        assert_eq!(slot_bits(16384), 16);
        assert_eq!(slot_bits(16385), 32);
    }
}
