//! Per-layer-kind scheme mixing.
//!
//! The paper's Fig 1 experiment compresses the FC layer with Dryden top-0.3%
//! while the conv layers are (a) left uncompressed or (b) compressed with
//! 1-bit quantization — i.e. *different schemes per layer kind*. `Mixed`
//! routes each layer to the compressor for its kind; each sub-compressor
//! owns a full residue store but only ever touches its own layers.

use super::{Compressor, Config, Kind, Packet};
use crate::models::{LayerKind, Layout};

pub struct Mixed {
    conv: Box<dyn Compressor>,
    other: Box<dyn Compressor>,
    is_conv: Vec<bool>,
}

impl Mixed {
    pub fn new(conv_cfg: &Config, other_cfg: &Config, layout: &Layout) -> Mixed {
        Mixed {
            conv: super::build_single(conv_cfg, layout),
            other: super::build_single(other_cfg, layout),
            is_conv: layout
                .layers
                .iter()
                .map(|l| l.kind == LayerKind::Conv)
                .collect(),
        }
    }
}

impl Compressor for Mixed {
    fn kind(&self) -> Kind {
        // reported scheme: the non-conv side (the paper names runs after the
        // FC treatment, e.g. "Dryden 0.3% + conv 1-bit")
        self.other.kind()
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        if self.is_conv[layer] {
            self.conv.pack_layer(layer, dw)
        } else {
            self.other.pack_layer(layer, dw)
        }
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        if self.is_conv[layer] {
            self.conv.residue_mut(layer)
        } else {
            self.other.residue_mut(layer)
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        if self.is_conv[layer] {
            self.conv.residue(layer)
        } else {
            self.other.residue(layer)
        }
    }

    fn reset(&mut self) {
        self.conv.reset();
        self.other.reset();
    }

    fn set_layer_lt(&mut self, layer: usize, lt: usize) {
        if self.is_conv[layer] {
            self.conv.set_layer_lt(layer, lt);
        } else {
            self.other.set_layer_lt(layer, lt);
        }
    }

    fn recycle(&mut self, spent: Packet) {
        if self.is_conv[spent.layer] {
            self.conv.recycle(spent);
        } else {
            self.other.recycle(spent);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::test_layout;
    use crate::util::rng::Pcg32;

    #[test]
    fn routes_by_kind() {
        let layout = test_layout(); // layer 0 conv (600), layer 1 fc (1200)
        let conv_cfg = Config::with_kind(Kind::None);
        let fc_cfg = Config::with_kind(Kind::Dryden);
        let mut m = Mixed::new(&conv_cfg, &fc_cfg, &layout);
        let mut rng = Pcg32::seeded(1);
        let dw0 = rng.normal_vec(600, 1.0);
        let dw1 = rng.normal_vec(1200, 1.0);
        let p0 = m.pack_layer(0, &dw0);
        let p1 = m.pack_layer(1, &dw1);
        assert!(p0.is_dense(), "conv side should be uncompressed");
        assert!(!p1.is_dense(), "fc side should be sparse top-k");
        assert_eq!(p1.sent(), (1200.0f64 * 0.003).round() as usize);
    }

    #[test]
    fn lt_defaults_cover_all_kinds() {
        // Paper defaults per layer kind: conv 50, fc/lstm 500, and embed
        // documented to ride with fc/lstm at 500. Checked at both places
        // the default lives (Layout construction and Config::lt_for) plus
        // per-kind routing through Mixed.
        let layout = Layout::from_specs(&[
            ("conv_w", &[3, 3, 2, 4], LayerKind::Conv),
            ("fc_w", &[10, 10], LayerKind::Fc),
            ("lstm_wx", &[10, 40], LayerKind::Lstm),
            ("embed", &[25, 4], LayerKind::Embed),
        ]);
        let want = [50usize, 500, 500, 500];
        let cfg = Config::default();
        for (l, &w) in layout.layers.iter().zip(want.iter()) {
            assert_eq!(l.lt_default, w, "layout default for {}", l.name);
            assert_eq!(cfg.lt_for(l.kind), w, "config default for {}", l.name);
        }
        // Mixed routes conv to the conv-side scheme, every other kind
        // (fc, lstm, embed) to the other side.
        let mut m = Mixed::new(
            &Config::with_kind(Kind::None),
            &Config::with_kind(Kind::Dryden),
            &layout,
        );
        let mut rng = Pcg32::seeded(3);
        for (li, l) in layout.layers.iter().enumerate() {
            let dw = rng.normal_vec(l.len(), 1.0);
            let p = m.pack_layer(li, &dw);
            if l.kind == LayerKind::Conv {
                assert!(p.is_dense(), "conv layer {} should be dense", l.name);
            } else {
                assert!(!p.is_dense(), "{} should route to top-k side", l.name);
            }
        }
    }

    #[test]
    fn residues_tracked_per_side() {
        let layout = test_layout();
        let mut m = Mixed::new(
            &Config::with_kind(Kind::OneBit),
            &Config::with_kind(Kind::Dryden),
            &layout,
        );
        let mut rng = Pcg32::seeded(2);
        let dw1 = rng.normal_vec(1200, 1.0);
        m.pack_layer(1, &dw1);
        // fc residue nonzero (top-k leaves most mass), conv residue untouched
        assert!(m.residue(1).iter().any(|&x| x != 0.0));
        assert!(m.residue(0).iter().all(|&x| x == 0.0));
    }
}
