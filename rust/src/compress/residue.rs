//! Per-layer residual-gradient storage shared by the error-feedback schemes.

use crate::models::Layout;

/// Dense per-layer residue buffers.
#[derive(Debug, Clone)]
pub struct ResidueStore {
    bufs: Vec<Vec<f32>>,
}

impl ResidueStore {
    pub fn new(layout: &Layout) -> ResidueStore {
        ResidueStore {
            bufs: layout.layers.iter().map(|l| vec![0.0; l.len()]).collect(),
        }
    }

    pub fn layer(&self, i: usize) -> &[f32] {
        &self.bufs[i]
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.bufs[i]
    }

    /// G = residue + dW, in place; the buffer then holds G.
    pub fn fold(&mut self, i: usize, dw: &[f32]) {
        let r = &mut self.bufs[i];
        assert_eq!(r.len(), dw.len(), "layer {i} gradient length mismatch");
        for (ri, &di) in r.iter_mut().zip(dw.iter()) {
            *ri += di;
        }
    }

    pub fn reset(&mut self) {
        for b in self.bufs.iter_mut() {
            b.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn num_layers(&self) -> usize {
        self.bufs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::test_layout;

    #[test]
    fn fold_accumulates() {
        let layout = test_layout();
        let mut rs = ResidueStore::new(&layout);
        let dw = vec![1.0f32; 600];
        rs.fold(0, &dw);
        rs.fold(0, &dw);
        assert_eq!(rs.layer(0)[0], 2.0);
        assert_eq!(rs.layer(1)[0], 0.0);
        rs.reset();
        assert_eq!(rs.layer(0)[0], 0.0);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let layout = test_layout();
        let mut rs = ResidueStore::new(&layout);
        rs.fold(0, &[1.0, 2.0]);
    }
}
