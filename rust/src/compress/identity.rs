//! No-compression baseline: transmits raw dense f32 gradients.

use super::{residue::ResidueStore, wire, BufPool, Compressor, Kind, Packet};
use crate::models::Layout;

pub struct Identity {
    /// Zeros — identity never holds back gradient mass.
    zeros: ResidueStore,
    pool: BufPool,
}

impl Identity {
    pub fn new(layout: &Layout) -> Identity {
        Identity {
            zeros: ResidueStore::new(layout),
            pool: BufPool::default(),
        }
    }
}

impl Compressor for Identity {
    fn kind(&self) -> Kind {
        Kind::None
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        let n = dw.len();
        assert_eq!(self.zeros.layer(layer).len(), n);
        // wire size is analytic (header + 4 bytes/element, exactly what
        // wire::encode_dense_f32 produces) — no need to materialize bytes
        // on the hot path; the equality is pinned by the test below.
        let (idx, mut val) = self.pool.take();
        val.extend_from_slice(dw);
        Packet {
            layer,
            n,
            idx, // dense packet: idx stays empty (pooled for its capacity)
            val,
            wire_bytes: wire::dense_f32_wire_len(n),
            paper_bits: 32 * n,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.zeros.layer(layer)
    }

    fn reset(&mut self) {}

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::test_layout;

    #[test]
    fn analytic_wire_size_matches_encoder() {
        let layout = test_layout();
        let mut c = Identity::new(&layout);
        let dw = vec![0.25f32; 600];
        let p = c.pack_layer(0, &dw);
        assert_eq!(p.wire_bytes, wire::encode_dense_f32(0, &dw).unwrap().len());
        // generic (3+ distinct values) dense packets keep the raw-f32 wire
        // form on the real exchange path too: measured == analytic
        let dw2: Vec<f32> = (0..1200).map(|i| i as f32 * 0.01).collect();
        let p2 = c.pack_layer(1, &dw2);
        assert_eq!(wire::encode_packet(&p2).unwrap().len(), p2.wire_bytes);
    }

    #[test]
    fn passthrough() {
        let layout = test_layout();
        let mut c = Identity::new(&layout);
        let dw = vec![1.5f32; 600];
        let p = c.pack_layer(0, &dw);
        assert!(p.is_dense());
        assert_eq!(p.val, dw);
        assert!((p.rate_wire() - 1.0).abs() < 0.01);
        assert!(c.residue(0).iter().all(|&x| x == 0.0));
    }
}
