//! Wen et al. 2017 — TernGrad: stochastic ternary gradient quantization.
//!
//! No residue / error feedback: quantization is *unbiased* by construction.
//! Each element of dW becomes sign(dW)*s_t with probability |dW|/s_t
//! (s_t = max |dW| of the layer), else 0. Dense 2-bit wire format -> the
//! 16x ceiling the paper cites ("without the use of sparsity, the
//! compression rate in their approach is limited to 16x").

use super::{quantize::Tern, residue::ResidueStore, wire, BufPool, Compressor, Config, Kind, Packet};
use crate::models::Layout;
use crate::util::rng::Pcg32;

pub struct TernGrad {
    /// Kept only so `residue()` has something to return (always zeros):
    /// TernGrad is residue-free.
    zeros: ResidueStore,
    rng: Pcg32,
    pool: BufPool,
}

impl TernGrad {
    pub fn new(cfg: &Config, layout: &Layout) -> TernGrad {
        TernGrad {
            zeros: ResidueStore::new(layout),
            rng: Pcg32::new(cfg.seed, 1313),
            pool: BufPool::default(),
        }
    }
}

impl Compressor for TernGrad {
    fn kind(&self) -> Kind {
        Kind::TernGrad
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        let n = dw.len();
        assert_eq!(self.zeros.layer(layer).len(), n);
        let st = dw.iter().fold(0.0f32, |m, &x| m.max(x.abs()));

        let (idx, mut val) = self.pool.take();
        if st > 0.0 {
            let inv = 1.0 / st;
            for &g in dw {
                let p = g.abs() * inv;
                let t = if self.rng.uniform() < p {
                    if g > 0.0 {
                        Tern::Pos
                    } else {
                        Tern::Neg
                    }
                } else {
                    Tern::Zero
                };
                val.push(t.apply(st));
            }
        } else {
            val.resize(n, 0.0);
        }

        Packet {
            layer,
            n,
            idx, // dense packet: idx stays empty (pooled for its capacity)
            val,
            wire_bytes: wire::ternary_dense_wire_len(n),
            paper_bits: 2 * n + 32,
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.zeros.layer(layer)
    }

    fn reset(&mut self) {}

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};

    fn make(n: usize, seed: u64) -> TernGrad {
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
        let cfg = Config {
            seed,
            ..Config::with_kind(Kind::TernGrad)
        };
        TernGrad::new(&cfg, &layout)
    }

    #[test]
    fn wire_roundtrip_bitwise() {
        // terngrad's dense ternary packets keep the v1 TERNARY_DENSE wire
        // form: measured == analytic, values bit-identical after decode
        let n = 64;
        let dw: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) - 0.5).collect();
        let mut c = make(n, 7);
        let p = c.pack_layer(0, &dw);
        let bytes = crate::compress::wire::encode_packet(&p).unwrap();
        assert_eq!(bytes.len(), p.wire_bytes);
        let q = crate::compress::wire::decode(&bytes).unwrap();
        assert!(q.is_dense());
        assert_eq!(q.val, p.val);
    }

    #[test]
    fn unbiased_in_expectation() {
        // average many independent quantizations of the same dW
        let n = 64;
        let dw: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) - 0.5).collect();
        let mut acc = vec![0.0f64; n];
        let trials = 3000;
        for t in 0..trials {
            let mut c = make(n, t as u64);
            let p = c.pack_layer(0, &dw);
            for (a, &v) in acc.iter_mut().zip(p.val.iter()) {
                *a += v as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = *a / trials as f64;
            assert!(
                (mean - dw[i] as f64).abs() < 0.05,
                "i={i} mean={mean} want={}",
                dw[i]
            );
        }
    }

    #[test]
    fn values_are_ternary_at_max_scale() {
        let mut c = make(100, 7);
        let dw: Vec<f32> = (0..100).map(|i| (i as f32) * 0.01 - 0.3).collect();
        let p = c.pack_layer(0, &dw);
        let st = dw.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for v in &p.val {
            assert!(*v == 0.0 || (v.abs() - st).abs() < 1e-6);
        }
    }

    #[test]
    fn compression_is_about_16x() {
        let mut c = make(8192, 1);
        let dw = vec![0.5; 8192];
        let p = c.pack_layer(0, &dw);
        let rate = p.rate_wire();
        assert!(rate > 15.0 && rate <= 16.0, "rate {rate}");
    }

    #[test]
    fn zero_gradient_sends_zeros() {
        let mut c = make(10, 2);
        let p = c.pack_layer(0, &[0.0; 10]);
        assert!(p.val.iter().all(|&v| v == 0.0));
    }
}
