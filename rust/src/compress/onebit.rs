//! Seide et al. 2014 — 1-bit SGD with error feedback.
//!
//! Every element of G = residue + dW is transmitted as one sign bit; the
//! receiver reconstructs positives as the mean of the positive part and
//! negatives as the mean of the negative part. Fixed 32x compression,
//! originally for FC layers only — Fig 1 of the paper shows that applying it
//! to conv layers (while FC is also compressed) diverges.

use super::{quantize, residue::ResidueStore, wire, BufPool, Compressor, Kind, Packet};
use crate::models::Layout;

pub struct OneBit {
    residues: ResidueStore,
    pool: BufPool,
}

impl OneBit {
    pub fn new(layout: &Layout) -> OneBit {
        OneBit {
            residues: ResidueStore::new(layout),
            pool: BufPool::default(),
        }
    }
}

impl Compressor for OneBit {
    fn kind(&self) -> Kind {
        Kind::OneBit
    }

    fn pack_layer(&mut self, layer: usize, dw: &[f32]) -> Packet {
        self.residues.fold(layer, dw);
        let r = self.residues.layer_mut(layer);
        let n = r.len();
        let (pos, neg) = quantize::signed_means(r.iter().copied());

        let (idx, mut val) = self.pool.take();
        for g in r.iter_mut() {
            let sent = if *g < 0.0 { neg } else { pos };
            val.push(sent);
            *g -= sent;
        }

        Packet {
            layer,
            n,
            idx, // dense packet: idx stays empty (pooled for its capacity)
            val,
            wire_bytes: wire::onebit_wire_len(n),
            paper_bits: n + 64, // 1 bit per element + two reconstruction means
        }
    }

    fn residue(&self, layer: usize) -> &[f32] {
        self.residues.layer(layer)
    }

    fn residue_mut(&mut self, layer: usize) -> Option<&mut [f32]> {
        Some(self.residues.layer_mut(layer))
    }

    fn reset(&mut self) {
        self.residues.reset();
    }

    fn recycle(&mut self, spent: Packet) {
        self.pool.put(spent.idx, spent.val);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{LayerKind, Layout};
    use crate::util::rng::Pcg32;

    fn make(n: usize) -> OneBit {
        OneBit::new(&Layout::from_specs(&[("w", &[n], LayerKind::Fc)]))
    }

    #[test]
    fn wire_roundtrip_bitwise() {
        // onebit's dense two-level packets keep the v1 ONEBIT wire form:
        // measured bytes == the compressor's analytic wire_bytes, and the
        // decoded values are bit-identical
        let mut c = make(100);
        let mut rng = Pcg32::seeded(24);
        let dw = rng.normal_vec(100, 1.0);
        let p = c.pack_layer(0, &dw);
        let bytes = crate::compress::wire::encode_packet(&p).unwrap();
        assert_eq!(bytes.len(), p.wire_bytes);
        let q = crate::compress::wire::decode(&bytes).unwrap();
        assert!(q.is_dense());
        assert_eq!(q.val, p.val);
    }

    #[test]
    fn dense_packet_two_levels() {
        let mut c = make(100);
        let mut rng = Pcg32::seeded(1);
        let dw = rng.normal_vec(100, 1.0);
        let p = c.pack_layer(0, &dw);
        assert!(p.is_dense());
        let mut levels: Vec<f32> = p.val.clone();
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        assert_eq!(levels.len(), 2);
        assert!(levels[0] < 0.0 && levels[1] > 0.0);
    }

    #[test]
    fn conservation() {
        let mut c = make(64);
        let mut rng = Pcg32::seeded(2);
        let dw = rng.normal_vec(64, 0.5);
        let p = c.pack_layer(0, &dw);
        let mut recon = c.residue(0).to_vec();
        p.add_into(&mut recon);
        for (a, b) in recon.iter().zip(dw.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn compression_is_about_32x() {
        let mut c = make(8000);
        let mut rng = Pcg32::seeded(3);
        let dw = rng.normal_vec(8000, 1.0);
        let p = c.pack_layer(0, &dw);
        let rate = p.rate_wire();
        assert!(rate > 28.0 && rate <= 32.0, "rate {rate}");
    }

    #[test]
    fn mean_preserving_on_each_side() {
        // sum of sent == sum of G on first step (pos/neg means preserve sums)
        let mut c = make(256);
        let mut rng = Pcg32::seeded(4);
        let dw = rng.normal_vec(256, 1.0);
        let p = c.pack_layer(0, &dw);
        let sum_sent: f32 = p.val.iter().sum();
        let sum_g: f32 = dw.iter().sum();
        assert!((sum_sent - sum_g).abs() < 1e-3, "{sum_sent} vs {sum_g}");
    }
}
