//! Experiment configuration files.
//!
//! A JSON spec fully describing a run (model, compression, topology,
//! schedule, learners), loadable via `adacomp train --config exp.json` and
//! saved next to results for provenance. Mirrors `train::TrainConfig` +
//! `compress::Config`; unknown keys are rejected so typos fail loudly.

use anyhow::{bail, Context, Result};

use crate::comm::LinkModel;
use crate::compress;
use crate::optim::LrSchedule;
use crate::train::TrainConfig;
use crate::util::json::{self, Json};

/// Parse a TrainConfig from a JSON experiment spec.
pub fn from_json(v: &Json) -> Result<TrainConfig> {
    let obj = v.as_obj().context("experiment spec must be an object")?;
    const KNOWN: &[&str] = &[
        "name", "model", "backend", "learners", "batch_per_learner", "epochs",
        "steps_per_epoch", "lr", "lr_schedule", "optimizer", "momentum",
        "topology", "seed", "clip_norm", "divergence_loss", "compression",
        "link", "threads", "exchange", "bucket_bytes", "staleness", "jitter",
        "churn", "mtbf", "kernel_threads", "controller",
    ];
    for k in obj.keys() {
        if !KNOWN.contains(&k.as_str()) {
            bail!("unknown experiment key '{k}' (known: {KNOWN:?})");
        }
    }
    let mut cfg = TrainConfig {
        model_name: v
            .get("model")
            .as_str()
            .context("'model' is required")?
            .to_string(),
        ..TrainConfig::default()
    };
    cfg.run_name = v
        .get("name")
        .as_str()
        .map(|s| s.to_string())
        .unwrap_or_else(|| cfg.model_name.clone());
    if let Some(b) = v.get("backend").as_str() {
        match b {
            "native" | "pjrt" | "auto" => cfg.backend = b.to_string(),
            other => bail!("unknown backend '{other}' (native | pjrt | auto)"),
        }
    }
    if let Some(n) = v.get("learners").as_usize() {
        cfg.n_learners = n.max(1);
    }
    if let Some(b) = v.get("batch_per_learner").as_usize() {
        cfg.batch_per_learner = b.max(1);
    }
    if let Some(e) = v.get("epochs").as_usize() {
        cfg.epochs = e;
    }
    if let Some(s) = v.get("steps_per_epoch").as_usize() {
        cfg.steps_per_epoch = s;
    }
    if let Some(o) = v.get("optimizer").as_str() {
        cfg.optimizer = o.to_string();
    }
    if let Some(m) = v.get("momentum").as_f64() {
        cfg.momentum = m as f32;
    }
    if let Some(t) = v.get("topology").as_str() {
        // fail at load time with the valid-form list, not mid-run; ps:<S>
        // and hier:<G> parameters are bounded by the spec's learner count
        crate::comm::topology::build(t, cfg.n_learners)?;
        cfg.topology = t.to_string();
    }
    if let Some(e) = v.get("exchange").as_str() {
        crate::train::ExchangeMode::parse(e)?;
        cfg.exchange = e.to_string();
    }
    if let Some(b) = v.get("bucket_bytes").as_usize() {
        cfg.bucket_bytes = b;
    }
    // bounded-staleness window knobs: fail at load time with the valid
    // range (the topology::build pattern), not a mid-run panic
    if v.get("staleness") != &Json::Null {
        let k = v
            .get("staleness")
            .as_f64()
            .context("'staleness' must be a number")?;
        // reject fractional / negative values instead of silently
        // truncating to a different schedule than the spec asked for
        if k < 0.0 || k.fract() != 0.0 {
            bail!(
                "staleness {k} out of range (valid: integer 0 <= K <= {}; 0 = synchronous)",
                crate::train::engine::MAX_STALENESS
            );
        }
        cfg.staleness = k as usize;
    }
    if v.get("jitter") != &Json::Null {
        cfg.link.jitter = v.get("jitter").as_f64().context("'jitter' must be a number")?;
    }
    // elastic-fleet knobs: the churn schedule is parsed (and rejected with
    // the valid event forms) at load time, not at step N mid-run
    if let Some(c) = v.get("churn").as_str() {
        crate::train::churn::parse(c)?;
        cfg.churn = c.to_string();
    }
    if v.get("mtbf") != &Json::Null {
        let m = v.get("mtbf").as_f64().context("'mtbf' must be a number")?;
        if m < 0.0 || m.fract() != 0.0 {
            bail!("mtbf {m} out of range (valid: integer steps >= 0; 0 disables random failures)");
        }
        cfg.mtbf = m as u64;
    }
    if let Some(s) = v.get("seed").as_i64() {
        cfg.seed = s as u64;
    }
    if let Some(c) = v.get("clip_norm").as_f64() {
        cfg.clip_norm = c as f32;
    }
    if let Some(d) = v.get("divergence_loss").as_f64() {
        cfg.divergence_loss = d;
    }
    if let Some(t) = v.get("threads").as_usize() {
        cfg.threads = t;
    }
    // intra-GEMM core budget: fail at load time with the valid range (the
    // staleness pattern) — 0 = auto (threads / active learners)
    if v.get("kernel_threads") != &Json::Null {
        let n = v
            .get("kernel_threads")
            .as_f64()
            .context("'kernel_threads' must be a number")?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!(
                "kernel_threads {n} out of range (valid: integer 0 <= N <= {}; 0 = auto budget)",
                crate::tensor::parallel::MAX_KERNEL_THREADS
            );
        }
        crate::train::validate_kernel_threads(n as usize)?;
        cfg.kernel_threads = n as usize;
    }
    // adaptive control plane: validated by name at load time (off | on)
    if let Some(c) = v.get("controller").as_str() {
        crate::train::control::parse_mode(c)?;
        cfg.controller = c.to_string();
    }
    if let Some(lr) = v.get("lr").as_f64() {
        cfg.lr = LrSchedule::Constant(lr as f32);
    }
    if v.get("lr_schedule") != &Json::Null {
        cfg.lr = lr_schedule_from(v.get("lr_schedule"))?;
    }
    if v.get("compression") != &Json::Null {
        cfg.compression = compression_from(v.get("compression"))?;
    }
    if v.get("link") != &Json::Null {
        cfg.link = LinkModel {
            latency_s: v.get("link").get("latency_s").as_f64().unwrap_or(25e-6),
            bandwidth_bps: v
                .get("link")
                .get("bandwidth_bps")
                .as_f64()
                .unwrap_or(1.25e9),
            // jitter stays a top-level key (it models learners, not the link
            // alpha-beta parameters)
            jitter: cfg.link.jitter,
        };
    }
    crate::train::engine::validate_window(cfg.staleness, cfg.link.jitter)?;
    Ok(cfg)
}

fn lr_schedule_from(v: &Json) -> Result<LrSchedule> {
    let kind = v.get("kind").as_str().context("lr_schedule.kind")?;
    Ok(match kind {
        "constant" => LrSchedule::Constant(
            v.get("lr").as_f64().context("lr_schedule.lr")? as f32
        ),
        "step" => LrSchedule::StepDecay {
            base: v.get("base").as_f64().context("base")? as f32,
            gamma: v.get("gamma").as_f64().unwrap_or(0.1) as f32,
            every_epochs: v.get("every_epochs").as_usize().unwrap_or(10),
        },
        "milestones" => LrSchedule::Milestones {
            base: v.get("base").as_f64().context("base")? as f32,
            points: v
                .get("points")
                .as_arr()
                .context("points")?
                .iter()
                .map(|p| {
                    Ok((
                        p.get("epoch").as_usize().context("epoch")?,
                        p.get("lr").as_f64().context("lr")? as f32,
                    ))
                })
                .collect::<Result<Vec<_>>>()?,
        },
        other => bail!("unknown lr schedule kind '{other}'"),
    })
}

fn compression_from(v: &Json) -> Result<compress::Config> {
    let mut c = compress::Config::default();
    if let Some(s) = v.get("scheme").as_str() {
        c.kind = compress::Kind::parse_or_err(s)?;
    }
    if let Some(s) = v.get("scheme_conv").as_str() {
        c.kind_conv = Some(compress::Kind::parse_or_err(s)?);
    }
    if let Some(x) = v.get("lt_conv").as_usize() {
        c.lt_conv = x;
    }
    if let Some(x) = v.get("lt_fc").as_usize() {
        c.lt_fc = x;
    }
    if let Some(x) = v.get("lt_lstm").as_usize() {
        c.lt_lstm = x;
    }
    if let Some(x) = v.get("lt_embed").as_usize() {
        c.lt_embed = x;
    }
    // "lt": a plain integer (all-layer override, the Fig 4 sweep form) or a
    // per-kind spec string "conv=64,fc=500[,lstm=N][,embed=N]" — both
    // validated through the same parser the CLI uses, so errors match
    let lt = v.get("lt");
    if lt != &Json::Null {
        if let Some(x) = lt.as_usize() {
            c.lt_override = x;
        } else if let Some(s) = lt.as_str() {
            c.parse_lt_spec(s)?;
        } else {
            bail!(
                "'lt' must be an integer or a per-kind spec string \
                 (conv=64,fc=500[,lstm=N][,embed=N])"
            );
        }
    }
    if let Some(x) = v.get("scale_factor").as_f64() {
        c.scale_factor = x as f32;
    }
    if let Some(x) = v.get("topk_fraction").as_f64() {
        c.topk_fraction = x;
    }
    if let Some(x) = v.get("strom_tau").as_f64() {
        c.strom_tau = x as f32;
    }
    if let Some(b) = v.get("per_bin_scale").as_bool() {
        c.per_bin_scale = b;
    }
    Ok(c)
}

/// Serialize a TrainConfig back to a JSON spec (provenance next to results).
pub fn to_json(cfg: &TrainConfig) -> Json {
    let lr = match &cfg.lr {
        LrSchedule::Constant(v) => json::obj(vec![
            ("kind", json::s("constant")),
            ("lr", json::num(*v as f64)),
        ]),
        LrSchedule::StepDecay {
            base,
            gamma,
            every_epochs,
        } => json::obj(vec![
            ("kind", json::s("step")),
            ("base", json::num(*base as f64)),
            ("gamma", json::num(*gamma as f64)),
            ("every_epochs", json::num(*every_epochs as f64)),
        ]),
        LrSchedule::Milestones { base, points } => json::obj(vec![
            ("kind", json::s("milestones")),
            ("base", json::num(*base as f64)),
            (
                "points",
                json::arr(
                    points
                        .iter()
                        .map(|(e, l)| {
                            json::obj(vec![
                                ("epoch", json::num(*e as f64)),
                                ("lr", json::num(*l as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    let comp = json::obj(vec![
        ("scheme", json::s(cfg.compression.kind.name())),
        ("lt_conv", json::num(cfg.compression.lt_conv as f64)),
        ("lt_fc", json::num(cfg.compression.lt_fc as f64)),
        ("lt_lstm", json::num(cfg.compression.lt_lstm as f64)),
        ("lt_embed", json::num(cfg.compression.lt_embed as f64)),
        ("lt", json::num(cfg.compression.lt_override as f64)),
        ("scale_factor", json::num(cfg.compression.scale_factor as f64)),
        ("topk_fraction", json::num(cfg.compression.topk_fraction)),
        ("strom_tau", json::num(cfg.compression.strom_tau as f64)),
        ("per_bin_scale", Json::Bool(cfg.compression.per_bin_scale)),
    ]);
    json::obj(vec![
        ("name", json::s(&cfg.run_name)),
        ("model", json::s(&cfg.model_name)),
        ("backend", json::s(&cfg.backend)),
        ("learners", json::num(cfg.n_learners as f64)),
        ("batch_per_learner", json::num(cfg.batch_per_learner as f64)),
        ("epochs", json::num(cfg.epochs as f64)),
        ("steps_per_epoch", json::num(cfg.steps_per_epoch as f64)),
        ("optimizer", json::s(&cfg.optimizer)),
        ("momentum", json::num(cfg.momentum as f64)),
        ("topology", json::s(&cfg.topology)),
        ("exchange", json::s(&cfg.exchange)),
        ("bucket_bytes", json::num(cfg.bucket_bytes as f64)),
        ("staleness", json::num(cfg.staleness as f64)),
        ("jitter", json::num(cfg.link.jitter)),
        ("churn", json::s(&cfg.churn)),
        ("mtbf", json::num(cfg.mtbf as f64)),
        ("seed", json::num(cfg.seed as f64)),
        ("clip_norm", json::num(cfg.clip_norm as f64)),
        ("threads", json::num(cfg.threads as f64)),
        ("kernel_threads", json::num(cfg.kernel_threads as f64)),
        ("controller", json::s(&cfg.controller)),
        ("lr_schedule", lr),
        ("compression", comp),
    ])
}

/// Load from a file path.
pub fn load(path: &str) -> Result<TrainConfig> {
    let txt = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = Json::from_str_slice(&txt).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_roundtrip() {
        let txt = r#"{
            "name": "exp1", "model": "cifar_cnn", "learners": 8,
            "batch_per_learner": 16, "epochs": 20, "optimizer": "adam",
            "topology": "ps", "seed": 5, "clip_norm": 1.5,
            "lr_schedule": {"kind": "milestones", "base": 0.02,
                            "points": [{"epoch": 10, "lr": 0.004}]},
            "compression": {"scheme": "adacomp", "lt_conv": 50, "lt_fc": 500,
                            "scale_factor": 2.5, "per_bin_scale": true}
        }"#;
        let v = Json::from_str_slice(txt).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.model_name, "cifar_cnn");
        assert_eq!(cfg.n_learners, 8);
        assert_eq!(cfg.optimizer, "adam");
        assert_eq!(cfg.compression.scale_factor, 2.5);
        assert!(cfg.compression.per_bin_scale);
        assert!((cfg.lr.at(10) - 0.004).abs() < 1e-7);
        // serialize and parse again
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.n_learners, cfg.n_learners);
        assert_eq!(back.compression.kind, cfg.compression.kind);
        assert_eq!(back.clip_norm, cfg.clip_norm);
    }

    #[test]
    fn backend_key_roundtrips_and_validates() {
        let v = Json::from_str_slice(r#"{"model": "char_lstm", "backend": "native"}"#).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.backend, "native");
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.backend, "native");
        let bad = Json::from_str_slice(r#"{"model": "m", "backend": "tpu"}"#).unwrap();
        assert!(from_json(&bad).is_err());
    }

    #[test]
    fn exchange_key_roundtrips_and_validates() {
        let v = Json::from_str_slice(r#"{"model": "m", "exchange": "barrier"}"#).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.exchange, "barrier");
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.exchange, "barrier");
        let bad = Json::from_str_slice(r#"{"model": "m", "exchange": "warp"}"#).unwrap();
        let err = from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("streamed") && err.contains("barrier"), "{err}");
    }

    #[test]
    fn sharded_and_hier_topologies_roundtrip() {
        let v = Json::from_str_slice(
            r#"{"model": "m", "learners": 8, "topology": "ps:4", "bucket_bytes": 2048}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.topology, "ps:4");
        assert_eq!(cfg.bucket_bytes, 2048);
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.topology, "ps:4");
        assert_eq!(back.bucket_bytes, 2048);
        let v = Json::from_str_slice(r#"{"model": "m", "learners": 8, "topology": "hier:2"}"#)
            .unwrap();
        assert_eq!(from_json(&v).unwrap().topology, "hier:2");
    }

    #[test]
    fn sharded_topology_params_fail_fast() {
        // satellite: S/G bounds are checked against the spec's learner
        // count at load time, with the valid-form list in the error
        for spec in [
            r#"{"model": "m", "learners": 4, "topology": "ps:8"}"#,
            r#"{"model": "m", "learners": 4, "topology": "ps:0"}"#,
            r#"{"model": "m", "learners": 4, "topology": "hier:1"}"#,
            r#"{"model": "m", "learners": 4, "topology": "hier:8"}"#,
            r#"{"model": "m", "topology": "ps:2"}"#, // default learners = 1
        ] {
            let v = Json::from_str_slice(spec).unwrap();
            let err = format!("{:#}", from_json(&v).unwrap_err());
            assert!(err.contains("ps:<S>") && err.contains("hier:<G>"), "{spec}: {err}");
        }
        // boundary: S == learners is fine
        let v = Json::from_str_slice(r#"{"model": "m", "learners": 4, "topology": "ps:4"}"#)
            .unwrap();
        assert!(from_json(&v).is_ok());
    }

    #[test]
    fn staleness_and_jitter_roundtrip_and_validate() {
        // satellite: window knobs load, roundtrip, and fail fast with the
        // valid range in the error (the topology::build pattern)
        let v = Json::from_str_slice(
            r#"{"model": "m", "learners": 8, "staleness": 2, "jitter": 0.3}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.staleness, 2);
        assert!((cfg.link.jitter - 0.3).abs() < 1e-12);
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.staleness, 2);
        assert!((back.link.jitter - 0.3).abs() < 1e-12);
        // jitter composes with an explicit link object (stays a
        // learner-model knob, not an alpha-beta parameter)
        let v = Json::from_str_slice(
            r#"{"model": "m", "jitter": 0.5,
                "link": {"latency_s": 1e-3, "bandwidth_bps": 1e9}}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert!((cfg.link.jitter - 0.5).abs() < 1e-12);
        assert!((cfg.link.latency_s - 1e-3).abs() < 1e-12);
        // out-of-range (or wrongly typed) values fail at load time
        for (spec, needle) in [
            (r#"{"model": "m", "staleness": -1}"#, "0 <= K <= 16"),
            (r#"{"model": "m", "staleness": 99}"#, "0 <= K <= 16"),
            (r#"{"model": "m", "staleness": 2.7}"#, "0 <= K <= 16"),
            (r#"{"model": "m", "staleness": "two"}"#, "must be a number"),
            (r#"{"model": "m", "jitter": 1.0}"#, "0.0 <= jitter < 1.0"),
            (r#"{"model": "m", "jitter": -0.2}"#, "0.0 <= jitter < 1.0"),
            (r#"{"model": "m", "jitter": "0.3"}"#, "must be a number"),
        ] {
            let v = Json::from_str_slice(spec).unwrap();
            let err = format!("{:#}", from_json(&v).unwrap_err());
            assert!(err.contains(needle), "{spec}: {err}");
        }
        // defaults: synchronous, no jitter
        let v = Json::from_str_slice(r#"{"model": "m"}"#).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.staleness, 0);
        assert_eq!(cfg.link.jitter, 0.0);
    }

    #[test]
    fn controller_key_roundtrips_and_validates() {
        let v = Json::from_str_slice(r#"{"model": "m", "controller": "on"}"#).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.controller, "on");
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.controller, "on");
        // default stays off (bit-identical legacy engine path)
        let v = Json::from_str_slice(r#"{"model": "m"}"#).unwrap();
        assert_eq!(from_json(&v).unwrap().controller, "off");
        let bad = Json::from_str_slice(r#"{"model": "m", "controller": "auto"}"#).unwrap();
        let err = format!("{:#}", from_json(&bad).unwrap_err());
        assert!(err.contains("valid: off, on"), "{err}");
    }

    #[test]
    fn lt_key_accepts_int_or_per_kind_spec() {
        // plain integer: the classic all-layer override
        let v = Json::from_str_slice(
            r#"{"model": "m", "compression": {"scheme": "adacomp", "lt": 200}}"#,
        )
        .unwrap();
        assert_eq!(from_json(&v).unwrap().compression.lt_override, 200);
        // per-kind spec string routes through the CLI parser
        let v = Json::from_str_slice(
            r#"{"model": "m",
                "compression": {"scheme": "adacomp", "lt": "conv=64,fc=500,lstm=250"}}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.compression.lt_conv, 64);
        assert_eq!(cfg.compression.lt_fc, 500);
        assert_eq!(cfg.compression.lt_lstm, 250);
        assert_eq!(cfg.compression.lt_override, 0);
        // per-kind values survive serialization via the lt_* keys
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.compression.lt_conv, 64);
        assert_eq!(back.compression.lt_fc, 500);
        assert_eq!(back.compression.lt_lstm, 250);
        // explicit lt_lstm / lt_embed keys load too
        let v = Json::from_str_slice(
            r#"{"model": "m",
                "compression": {"scheme": "adacomp", "lt_lstm": 80, "lt_embed": 90}}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.compression.lt_lstm, 80);
        assert_eq!(cfg.compression.lt_embed, 90);
        // malformed specs fail fast with the valid-form list
        for (spec, needle) in [
            (r#"{"model": "m", "compression": {"lt": "conv=64,disk=9"}}"#, "valid kinds"),
            (r#"{"model": "m", "compression": {"lt": "conv=0"}}"#, "out of range"),
            (r#"{"model": "m", "compression": {"lt": "conv"}}"#, "bad L_T"),
            (r#"{"model": "m", "compression": {"lt": true}}"#, "per-kind spec string"),
        ] {
            let v = Json::from_str_slice(spec).unwrap();
            let err = format!("{:#}", from_json(&v).unwrap_err());
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn kernel_threads_roundtrip_and_validate() {
        // satellite: the intra-GEMM core budget loads, roundtrips, and
        // fails fast with the valid range in the error (staleness pattern)
        let v = Json::from_str_slice(
            r#"{"model": "m", "learners": 4, "threads": 2, "kernel_threads": 4}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.kernel_threads, 4);
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.kernel_threads, 4);
        for (spec, needle) in [
            (r#"{"model": "m", "kernel_threads": -1}"#, "0 <= N <= 64"),
            (r#"{"model": "m", "kernel_threads": 65}"#, "0 <= N <= 64"),
            (r#"{"model": "m", "kernel_threads": 2.5}"#, "0 <= N <= 64"),
            (r#"{"model": "m", "kernel_threads": "four"}"#, "must be a number"),
        ] {
            let v = Json::from_str_slice(spec).unwrap();
            let err = format!("{:#}", from_json(&v).unwrap_err());
            assert!(err.contains(needle), "{spec}: {err}");
        }
        // default: auto budget
        let v = Json::from_str_slice(r#"{"model": "m"}"#).unwrap();
        assert_eq!(from_json(&v).unwrap().kernel_threads, 0);
    }

    #[test]
    fn churn_and_mtbf_roundtrip_and_validate() {
        // elastic-fleet knobs load, roundtrip, and fail fast with the valid
        // event forms in the error (the topology::build pattern)
        let v = Json::from_str_slice(
            r#"{"model": "m", "learners": 8, "churn": "fail@120:2,join@300:1", "mtbf": 500}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.churn, "fail@120:2,join@300:1");
        assert_eq!(cfg.mtbf, 500);
        let back = from_json(&to_json(&cfg)).unwrap();
        assert_eq!(back.churn, cfg.churn);
        assert_eq!(back.mtbf, 500);
        for (spec, needle) in [
            (r#"{"model": "m", "churn": "fail120:2"}"#, "missing '@'"),
            (r#"{"model": "m", "churn": "explode@9:1"}"#, "unknown kind"),
            (r#"{"model": "m", "churn": "fail@9:0"}"#, "count must be >= 1"),
            (r#"{"model": "m", "mtbf": -3}"#, "integer steps >= 0"),
            (r#"{"model": "m", "mtbf": 2.5}"#, "integer steps >= 0"),
            (r#"{"model": "m", "mtbf": "often"}"#, "must be a number"),
        ] {
            let v = Json::from_str_slice(spec).unwrap();
            let err = format!("{:#}", from_json(&v).unwrap_err());
            assert!(err.contains(needle), "{spec}: {err}");
        }
        // defaults: static fleet
        let v = Json::from_str_slice(r#"{"model": "m"}"#).unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.churn, "");
        assert_eq!(cfg.mtbf, 0);
    }

    #[test]
    fn unknown_names_error_with_valid_lists() {
        let bad = Json::from_str_slice(r#"{"model": "m", "topology": "mesh"}"#).unwrap();
        let err = format!("{:#}", from_json(&bad).unwrap_err());
        assert!(err.contains("ring") && err.contains("ps"), "{err}");
        let bad = Json::from_str_slice(
            r#"{"model": "m", "compression": {"scheme": "gzip"}}"#,
        )
        .unwrap();
        let err = format!("{:#}", from_json(&bad).unwrap_err());
        assert!(err.contains("adacomp") && err.contains("terngrad"), "{err}");
    }

    #[test]
    fn rejects_unknown_keys() {
        let v = Json::from_str_slice(r#"{"model": "m", "learnerz": 3}"#).unwrap();
        let err = from_json(&v).unwrap_err().to_string();
        assert!(err.contains("learnerz"), "{err}");
    }

    #[test]
    fn requires_model() {
        let v = Json::from_str_slice(r#"{"learners": 3}"#).unwrap();
        assert!(from_json(&v).is_err());
    }

    #[test]
    fn mixed_scheme_spec() {
        let v = Json::from_str_slice(
            r#"{"model": "cifar_cnn",
                "compression": {"scheme": "dryden", "scheme_conv": "onebit"}}"#,
        )
        .unwrap();
        let cfg = from_json(&v).unwrap();
        assert_eq!(cfg.compression.kind, compress::Kind::Dryden);
        assert_eq!(cfg.compression.kind_conv, Some(compress::Kind::OneBit));
    }
}
