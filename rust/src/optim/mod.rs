//! Optimizers applied at the central weight-update step (paper Algorithm 1:
//! `Update(unpack(...))`). The paper evaluates SGD with momentum and Adam
//! and argues AdaComp is optimizer-agnostic; RMSProp is included because the
//! discussion section names it.

pub mod schedule;

pub use schedule::LrSchedule;

/// Flat-parameter optimizer.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// params -= update(grad); `grad` is the mean gradient across learners.
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32);
    fn reset(&mut self);
    /// Flat serialization of the optimizer's slow state (momentum /
    /// moment estimates), for checkpoint handover across membership
    /// epochs. Stateless optimizers return an empty vec.
    fn state(&self) -> Vec<f32> {
        Vec::new()
    }
    /// Restore state captured by [`state`](Optimizer::state). Returns
    /// false (and leaves the optimizer untouched) on a shape mismatch.
    fn load_state(&mut self, _s: &[f32]) -> bool {
        false
    }
}

/// SGD with classical momentum: v = mu*v + g; p -= lr*v.
pub struct Sgd {
    pub momentum: f32,
    v: Vec<f32>,
}

impl Sgd {
    pub fn new(n: usize, momentum: f32) -> Sgd {
        Sgd {
            momentum,
            v: vec![0.0; n],
        }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.v.len());
        let mu = self.momentum;
        for ((p, &g), v) in params.iter_mut().zip(grad.iter()).zip(self.v.iter_mut()) {
            *v = mu * *v + g;
            *p -= lr * *v;
        }
    }

    fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state(&self) -> Vec<f32> {
        self.v.clone()
    }

    fn load_state(&mut self, s: &[f32]) -> bool {
        if s.len() != self.v.len() {
            return false;
        }
        self.v.copy_from_slice(s);
        true
    }
}

/// Adam (Kingma & Ba 2014), bias-corrected.
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize) -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let eps = self.eps;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }

    fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    fn state(&self) -> Vec<f32> {
        let mut s = Vec::with_capacity(self.m.len() * 2 + 1);
        s.extend_from_slice(&self.m);
        s.extend_from_slice(&self.v);
        s.push(self.t as f32);
        s
    }

    fn load_state(&mut self, s: &[f32]) -> bool {
        let n = self.m.len();
        if s.len() != n * 2 + 1 {
            return false;
        }
        self.m.copy_from_slice(&s[..n]);
        self.v.copy_from_slice(&s[n..n * 2]);
        self.t = s[n * 2] as u32;
        true
    }
}

/// RMSProp (Hinton): s = rho*s + (1-rho)*g^2; p -= lr * g / sqrt(s + eps).
pub struct RmsProp {
    pub rho: f32,
    pub eps: f32,
    s: Vec<f32>,
}

impl RmsProp {
    pub fn new(n: usize) -> RmsProp {
        RmsProp {
            rho: 0.9,
            eps: 1e-8,
            s: vec![0.0; n],
        }
    }
}

impl Optimizer for RmsProp {
    fn name(&self) -> &'static str {
        "rmsprop"
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        let rho = self.rho;
        for i in 0..params.len() {
            let g = grad[i];
            self.s[i] = rho * self.s[i] + (1.0 - rho) * g * g;
            params[i] -= lr * g / (self.s[i] + self.eps).sqrt();
        }
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state(&self) -> Vec<f32> {
        self.s.clone()
    }

    fn load_state(&mut self, s: &[f32]) -> bool {
        if s.len() != self.s.len() {
            return false;
        }
        self.s.copy_from_slice(s);
        true
    }
}

/// Build by name. `momentum` only applies to sgd.
pub fn build(name: &str, n: usize, momentum: f32) -> Option<Box<dyn Optimizer>> {
    match name {
        "sgd" => Some(Box::new(Sgd::new(n, momentum))),
        "adam" => Some(Box::new(Adam::new(n))),
        "rmsprop" => Some(Box::new(RmsProp::new(n))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All optimizers must descend a simple quadratic f(p) = 0.5*|p|^2.
    fn descend(opt: &mut dyn Optimizer, lr: f32) -> f32 {
        let mut p = vec![1.0f32, -2.0, 3.0];
        for _ in 0..400 {
            let g: Vec<f32> = p.clone(); // grad of 0.5|p|^2
            opt.step(&mut p, &g, lr);
        }
        p.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    #[test]
    fn sgd_descends() {
        assert!(descend(&mut Sgd::new(3, 0.0), 0.1) < 1e-3);
    }

    #[test]
    fn sgd_momentum_descends() {
        assert!(descend(&mut Sgd::new(3, 0.9), 0.02) < 1e-2);
    }

    #[test]
    fn adam_descends() {
        assert!(descend(&mut Adam::new(3), 0.05) < 1e-2);
    }

    #[test]
    fn rmsprop_descends() {
        assert!(descend(&mut RmsProp::new(3), 0.05) < 0.1);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut o = Sgd::new(1, 0.9);
        let mut p = vec![0.0f32];
        o.step(&mut p, &[1.0], 1.0);
        assert!((p[0] + 1.0).abs() < 1e-6); // v=1, p=-1
        o.step(&mut p, &[1.0], 1.0);
        assert!((p[0] + 2.9).abs() < 1e-6); // v=1.9
        o.reset();
        o.step(&mut p, &[0.0], 1.0);
        assert!((p[0] + 2.9).abs() < 1e-6); // velocity cleared
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // first step of adam moves by ~lr regardless of gradient scale
        let mut o = Adam::new(1);
        let mut p = vec![0.0f32];
        o.step(&mut p, &[1e-4], 0.1);
        assert!((p[0] + 0.1).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn state_roundtrips_and_rejects_shape_mismatch() {
        // run each optimizer a few steps, snapshot, run a fresh one from
        // the snapshot — next step must match bit-for-bit.
        for name in ["sgd", "adam", "rmsprop"] {
            let mut a = build(name, 3, 0.9).unwrap();
            let mut p = vec![1.0f32, -2.0, 3.0];
            for _ in 0..5 {
                let g = p.clone();
                a.step(&mut p, &g, 0.05);
            }
            let snap = a.state();
            assert!(!snap.is_empty(), "{name} state should be non-empty");
            let mut b = build(name, 3, 0.9).unwrap();
            assert!(b.load_state(&snap), "{name} load_state");
            assert!(!b.load_state(&snap[..snap.len() - 1]), "{name} mismatch");
            let g = p.clone();
            let mut pa = p.clone();
            let mut pb = p.clone();
            a.step(&mut pa, &g, 0.05);
            b.step(&mut pb, &g, 0.05);
            for (x, y) in pa.iter().zip(pb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} diverged after load");
            }
        }
    }

    #[test]
    fn build_by_name() {
        assert!(build("sgd", 2, 0.9).is_some());
        assert!(build("adam", 2, 0.0).is_some());
        assert!(build("rmsprop", 2, 0.0).is_some());
        assert!(build("lamb", 2, 0.0).is_none());
    }
}
