//! Learning-rate schedules. The paper uses the baselines' unchanged
//! hyper-parameters: step decay for the Caffe-style CNNs, constant for the
//! small models.

/// Piecewise-constant learning rate.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant(f32),
    /// lr * gamma^(epoch / step) — classic Caffe "step" policy.
    StepDecay {
        base: f32,
        gamma: f32,
        every_epochs: usize,
    },
    /// Explicit milestones: (epoch, lr); uses the last milestone <= epoch.
    Milestones {
        base: f32,
        points: Vec<(usize, f32)>,
    },
}

impl LrSchedule {
    pub fn at(&self, epoch: usize) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::StepDecay {
                base,
                gamma,
                every_epochs,
            } => base * gamma.powi((epoch / every_epochs.max(&1).to_owned()) as i32),
            LrSchedule::Milestones { base, points } => {
                let mut lr = *base;
                for (e, v) in points {
                    if epoch >= *e {
                        lr = *v;
                    }
                }
                lr
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        assert_eq!(LrSchedule::Constant(0.1).at(0), 0.1);
        assert_eq!(LrSchedule::Constant(0.1).at(99), 0.1);
    }

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay {
            base: 1.0,
            gamma: 0.1,
            every_epochs: 10,
        };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(10) - 0.1).abs() < 1e-7);
        assert!((s.at(25) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn milestones() {
        let s = LrSchedule::Milestones {
            base: 0.1,
            points: vec![(5, 0.01), (8, 0.001)],
        };
        assert_eq!(s.at(4), 0.1);
        assert_eq!(s.at(5), 0.01);
        assert_eq!(s.at(9), 0.001);
    }
}
