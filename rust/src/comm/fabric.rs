//! Simulated interconnect substrate.
//!
//! The paper's testbed is N learner nodes exchanging gradients peer-to-peer
//! over MPI. Here learners live in one process (the paper's *claims* are
//! about convergence and bytes-on-the-wire, both fully determined by the
//! synchronous-SGD semantics — see DESIGN.md §Substitutions), and this
//! module provides the honest accounting: every packet is charged its real
//! wire-format bytes, and an analytic alpha-beta (latency + bandwidth) model
//! turns byte counts into simulated exchange time so benches can compare
//! topologies and compression rates in seconds, not just bytes.

/// Link parameters for the alpha-beta cost model.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-message latency (alpha), seconds.
    pub latency_s: f64,
    /// Link bandwidth (1/beta), bytes per second.
    pub bandwidth_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 10 GbE-class: 25us latency, 1.25 GB/s
        LinkModel {
            latency_s: 25e-6,
            bandwidth_bps: 1.25e9,
        }
    }
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// Byte + time accounting for one training run.
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    /// Total bytes every learner pushed into the fabric.
    pub bytes_up: u64,
    /// Total bytes delivered to learners.
    pub bytes_down: u64,
    /// Number of exchange rounds.
    pub rounds: u64,
    /// Simulated communication seconds (sum over rounds of the critical path).
    pub sim_time_s: f64,
    /// What the same rounds would have cost uncompressed (dense f32).
    pub dense_bytes_equiv: u64,
}

impl FabricStats {
    /// End-to-end compression rate actually achieved on the wire.
    pub fn effective_rate(&self) -> f64 {
        if self.bytes_up == 0 {
            1.0
        } else {
            self.dense_bytes_equiv as f64 / self.bytes_up as f64
        }
    }
}

/// The fabric: link model + running stats.
#[derive(Debug, Clone, Default)]
pub struct Fabric {
    pub link: LinkModel,
    pub stats: FabricStats,
}

impl Fabric {
    pub fn new(link: LinkModel) -> Fabric {
        Fabric {
            link,
            stats: FabricStats::default(),
        }
    }

    /// Record one exchange round.
    ///
    /// * `per_learner_up`: bytes each learner sent,
    /// * `per_learner_down`: bytes each learner received,
    /// * `critical_path_s`: the topology's computed round time,
    /// * `dense_equiv`: what dense f32 would have sent in total.
    pub fn record_round(
        &mut self,
        per_learner_up: &[usize],
        per_learner_down: &[usize],
        critical_path_s: f64,
        dense_equiv: usize,
    ) {
        self.stats.bytes_up += per_learner_up.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.bytes_down += per_learner_down.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.rounds += 1;
        self.stats.sim_time_s += critical_path_s;
        self.stats.dense_bytes_equiv += dense_equiv as u64;
    }

    pub fn reset(&mut self) {
        self.stats = FabricStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_model() {
        let l = LinkModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
        };
        // 1ms latency + 1000 bytes at 1MB/s = 1ms -> 2ms
        assert!((l.transfer_time(1000) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut f = Fabric::new(LinkModel::default());
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        assert_eq!(f.stats.bytes_up, 400);
        assert_eq!(f.stats.bytes_down, 800);
        assert_eq!(f.stats.rounds, 2);
        assert!((f.stats.sim_time_s - 1.0).abs() < 1e-12);
        assert!((f.stats.effective_rate() - 8.0).abs() < 1e-12);
        f.reset();
        assert_eq!(f.stats.rounds, 0);
    }
}
