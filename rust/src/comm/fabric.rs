//! Simulated interconnect substrate.
//!
//! The paper's testbed is N learner nodes exchanging gradients peer-to-peer
//! over MPI. Here learners live in one process (the paper's *claims* are
//! about convergence and bytes-on-the-wire, both fully determined by the
//! synchronous-SGD semantics — see DESIGN.md §Substitutions), and this
//! module provides the honest accounting: every packet is charged its real
//! wire-format bytes, and an analytic alpha-beta (latency + bandwidth) model
//! turns byte counts into simulated exchange time so benches can compare
//! topologies and compression rates in seconds, not just bytes.
//!
//! **Overlap timeline.** Beyond per-round comm time, the fabric folds each
//! training step onto a simulated step timeline ([`Fabric::record_step`]):
//! the engine supplies the step's measured compute span (backward + pack
//! wall time) and three comm placements — overlapped behind backward (the
//! streamed pipeline, with per-bucket rounds placed **per port**: rounds on
//! one topology port serialize, rounds on disjoint ports — `ps:<S>` shards
//! — run concurrently, and `overlap_end_s` is the max over port
//! completion times), serialized after a barrier, and the serialized dense
//! no-compression baseline ([`ReducePlan::dense_round_s`]
//! (super::plan::ReducePlan::dense_round_s) — identical across topologies
//! and exchange modes). `sim_step_s()` and `projected_speedup()` turn the
//! paper's compression *rates* into projected wall-clock step-time wins
//! (DESIGN.md §Overlap pipeline, §Topologies).

/// Link parameters for the alpha-beta cost model.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-message latency (alpha), seconds.
    pub latency_s: f64,
    /// Link bandwidth (1/beta), bytes per second.
    pub bandwidth_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 10 GbE-class: 25us latency, 1.25 GB/s
        LinkModel {
            latency_s: 25e-6,
            bandwidth_bps: 1.25e9,
        }
    }
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// Byte + time accounting for one training run.
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    /// Total bytes every learner pushed into the fabric.
    pub bytes_up: u64,
    /// Total bytes delivered to learners.
    pub bytes_down: u64,
    /// Number of exchange rounds: one per reduce-plan bucket per step, in
    /// both exchange modes (the modes differ in placement, not message
    /// structure).
    pub rounds: u64,
    /// Simulated communication seconds (sum over rounds of the critical path).
    pub sim_time_s: f64,
    /// What the same rounds would have cost uncompressed (dense f32).
    pub dense_bytes_equiv: u64,
    /// Steps folded into the step timeline (`record_step` calls).
    pub steps: u64,
    /// Σ per-step critical path with comm overlapped behind backward — the
    /// streamed pipeline's step time. On the barrier path this equals
    /// `sim_barrier_s` (nothing overlaps).
    pub sim_overlap_s: f64,
    /// Σ per-step compute + serialized comm: the same packets behind a full
    /// barrier.
    pub sim_barrier_s: f64,
    /// Σ per-step compute + serialized *dense f32* comm: the
    /// no-compression, no-overlap baseline.
    pub sim_dense_s: f64,
}

impl FabricStats {
    /// End-to-end compression rate actually achieved on the wire.
    pub fn effective_rate(&self) -> f64 {
        if self.bytes_up == 0 {
            1.0
        } else {
            self.dense_bytes_equiv as f64 / self.bytes_up as f64
        }
    }

    /// Mean simulated step time of the run's actual exchange placement
    /// (overlapped on the streamed path, serialized on the barrier path).
    pub fn sim_step_s(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sim_overlap_s / self.steps as f64
        }
    }

    /// Projected end-to-end speedup of this run's placement (overlapped +
    /// compressed) over the dense/barrier baseline — the paper's ~40X/~200X
    /// compression rates expressed as step-time wins.
    pub fn projected_speedup(&self) -> f64 {
        if self.sim_overlap_s <= 0.0 {
            1.0
        } else {
            self.sim_dense_s / self.sim_overlap_s
        }
    }

    /// Σ per-step `max(comm_end, compute) − compute`: the comm tail of the
    /// overlap placement with the measured compute canceled out — the
    /// deterministic part of the streamed timeline (round costs are
    /// simulated), comparable across runs. Derived from the identity
    /// `sim_barrier_s = Σ(compute + comm_serial)` and
    /// `sim_time_s = Σ comm_serial`.
    pub fn comm_tail_s(&self) -> f64 {
        self.sim_overlap_s - self.sim_barrier_s + self.sim_time_s
    }

    /// Σ per-step dense-baseline comm with the measured compute canceled
    /// (steps × the plan's canonical dense round) — deterministic, used to
    /// pin the baseline's mode/topology independence.
    pub fn dense_comm_total_s(&self) -> f64 {
        self.sim_dense_s - self.sim_barrier_s + self.sim_time_s
    }
}

/// The fabric: link model + running stats.
#[derive(Debug, Clone, Default)]
pub struct Fabric {
    pub link: LinkModel,
    pub stats: FabricStats,
}

impl Fabric {
    pub fn new(link: LinkModel) -> Fabric {
        Fabric {
            link,
            stats: FabricStats::default(),
        }
    }

    /// Record one exchange round.
    ///
    /// * `per_learner_up`: bytes each learner sent,
    /// * `per_learner_down`: bytes each learner received,
    /// * `critical_path_s`: the topology's computed round time,
    /// * `dense_equiv`: what dense f32 would have sent in total.
    pub fn record_round(
        &mut self,
        per_learner_up: &[usize],
        per_learner_down: &[usize],
        critical_path_s: f64,
        dense_equiv: usize,
    ) {
        self.stats.bytes_up += per_learner_up.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.bytes_down += per_learner_down.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.rounds += 1;
        self.stats.sim_time_s += critical_path_s;
        self.stats.dense_bytes_equiv += dense_equiv as u64;
    }

    /// Fold one finished training step onto the simulated step timeline.
    ///
    /// * `compute_s`: measured wall span of the learner phase (fwd/bwd+pack),
    /// * `comm_serial_s`: Σ per-round comm time of the step's exchanges,
    /// * `overlap_end_s`: when the last exchange finished on the overlap
    ///   timeline (streamed: per-bucket rounds pipelined behind backward,
    ///   max over the topology's port completion times; barrier:
    ///   `compute_s + comm_serial_s`),
    /// * `dense_comm_s`: Σ per-round dense-baseline comm time.
    pub fn record_step(
        &mut self,
        compute_s: f64,
        comm_serial_s: f64,
        overlap_end_s: f64,
        dense_comm_s: f64,
    ) {
        self.stats.steps += 1;
        self.stats.sim_overlap_s += overlap_end_s.max(compute_s);
        self.stats.sim_barrier_s += compute_s + comm_serial_s;
        self.stats.sim_dense_s += compute_s + dense_comm_s;
    }

    pub fn reset(&mut self) {
        self.stats = FabricStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_model() {
        let l = LinkModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
        };
        // 1ms latency + 1000 bytes at 1MB/s = 1ms -> 2ms
        assert!((l.transfer_time(1000) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut f = Fabric::new(LinkModel::default());
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        assert_eq!(f.stats.bytes_up, 400);
        assert_eq!(f.stats.bytes_down, 800);
        assert_eq!(f.stats.rounds, 2);
        assert!((f.stats.sim_time_s - 1.0).abs() < 1e-12);
        assert!((f.stats.effective_rate() - 8.0).abs() < 1e-12);
        f.reset();
        assert_eq!(f.stats.rounds, 0);
    }

    #[test]
    fn step_timeline_overlap_vs_barrier_vs_dense() {
        let mut f = Fabric::new(LinkModel::default());
        // compute 10ms; compressed comm 2ms total, finishing at 10.5ms when
        // overlapped; dense comm would take 40ms serialized.
        f.record_step(10e-3, 2e-3, 10.5e-3, 40e-3);
        assert_eq!(f.stats.steps, 1);
        assert!((f.stats.sim_overlap_s - 10.5e-3).abs() < 1e-12);
        assert!((f.stats.sim_barrier_s - 12e-3).abs() < 1e-12);
        assert!((f.stats.sim_dense_s - 50e-3).abs() < 1e-12);
        assert!(f.stats.sim_overlap_s < f.stats.sim_barrier_s);
        assert!((f.stats.sim_step_s() - 10.5e-3).abs() < 1e-12);
        assert!((f.stats.projected_speedup() - 50.0 / 10.5).abs() < 1e-9);
        // overlap end can never beat pure compute: record_step clamps
        f.record_step(5e-3, 1e-3, 1e-3, 2e-3);
        assert!((f.stats.sim_overlap_s - 15.5e-3).abs() < 1e-12);
    }
}
