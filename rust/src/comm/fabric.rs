//! Simulated interconnect substrate.
//!
//! The paper's testbed is N learner nodes exchanging gradients peer-to-peer
//! over MPI. Here learners live in one process (the paper's *claims* are
//! about convergence and bytes-on-the-wire, both fully determined by the
//! synchronous-SGD semantics — see DESIGN.md §Substitutions), and this
//! module provides the honest accounting: every packet is charged its real
//! wire-format bytes — on the engine path these come from the learner's
//! actually-serialized bucket frame (encode at publish, decode before
//! reduce; see `crate::compress::wire`), so the charge is the measured
//! frame length, not an analytic estimate — and an alpha-beta (latency +
//! bandwidth) model turns byte counts into simulated exchange time so
//! benches can compare topologies and compression rates in seconds, not
//! just bytes.
//!
//! **Overlap timeline.** Beyond per-round comm time, the fabric folds each
//! training step onto a simulated step timeline ([`Fabric::record_step`]):
//! the engine supplies the step's (jittered) compute span and three comm
//! placements — the frontier advance of the overlapped schedule (the
//! streamed pipeline, with per-bucket rounds placed **per port** from
//! their [`RoundSched`](super::topology::RoundSched) ready-time inputs:
//! rounds on one topology port serialize, rounds on disjoint ports —
//! `ps:<S>` shards — run concurrently, and the timeline is continuous
//! across steps under bounded staleness), serialized after a barrier, and
//! the serialized dense no-compression baseline
//! ([`ReducePlan::dense_round_s`]
//! (super::plan::ReducePlan::dense_round_s) — identical across topologies,
//! exchange modes and staleness windows). `sim_step_s()` and
//! `projected_speedup()` turn the paper's compression *rates* into
//! projected wall-clock step-time wins (DESIGN.md §Overlap pipeline,
//! §Topologies, §Bounded staleness).
//!
//! **Straggler model.** [`LinkModel::jitter`] makes the simulated fleet
//! uneven: [`LinkModel::compute_mult`] draws a deterministic per-(learner,
//! step) compute multiplier (base jitter plus occasional straggler
//! episodes) from a seeded xorshift64* hash, and [`Fabric::record_stall`]
//! accounts the resulting window-wait time (`stall_s`) and per-learner
//! critical-path shares.

/// Link parameters for the alpha-beta cost model, plus the per-learner
/// compute-jitter model used by the straggler simulation.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-message latency (alpha), seconds.
    pub latency_s: f64,
    /// Link bandwidth (1/beta), bytes per second.
    pub bandwidth_bps: f64,
    /// Per-learner compute-jitter fraction (`--jitter`), `0.0 <= j < 1.0`.
    /// 0 = every learner computes at its measured speed (no skew). With
    /// `j > 0` each (learner, step) draws a deterministic multiplier from
    /// [`compute_mult`](Self::compute_mult) — base jitter up to `+j`, plus
    /// an occasional straggler episode — so the simulated fleet is uneven
    /// in a reproducible way at any thread count. Timeline-only: jitter
    /// never touches gradients, losses, or bytes.
    pub jitter: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 10 GbE-class: 25us latency, 1.25 GB/s
        LinkModel {
            latency_s: 25e-6,
            bandwidth_bps: 1.25e9,
            jitter: 0.0,
        }
    }
}

/// Probability (as a power-of-two reciprocal) that a (learner, step) cell is
/// a straggler episode: 1/8 of steps run `1 + STRAGGLE_BOOST * jitter`
/// slower — the long-tail slowdown (GC pause, co-tenant burst, flaky NIC)
/// that bounded staleness exists to absorb.
const STRAGGLE_SHIFT: u32 = 3;
/// Multiple of `jitter` a straggler episode adds on top of the base draw.
pub const STRAGGLE_BOOST: f64 = 4.0;

/// One round of xorshift64* mixing (Vigna'16) — the deterministic hash
/// behind the jitter draws.
#[inline]
fn xorshift64star(mut x: u64) -> u64 {
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Fail fast on an out-of-range jitter fraction (the `topology::build`
    /// pattern: config JSON, CLI/harness, and the engine all validate
    /// through here).
    pub fn validate_jitter(jitter: f64) -> anyhow::Result<()> {
        if !jitter.is_finite() || !(0.0..1.0).contains(&jitter) {
            anyhow::bail!(
                "jitter {jitter} out of range (valid: 0.0 <= jitter < 1.0; 0 = no jitter)"
            );
        }
        Ok(())
    }

    /// Deterministic compute-time multiplier for one (learner, step) cell:
    /// `1 + jitter·u` with `u ~ U[0,1)` drawn from a seeded xorshift64*
    /// hash of `(seed, learner, step)`, plus an occasional straggler
    /// episode (1 step in 8) that adds `STRAGGLE_BOOST · jitter`. Pure
    /// function of its inputs — identical at every thread count, across
    /// repeat runs, and independent of wall-clock time.
    pub fn compute_mult(&self, seed: u64, learner: usize, step: u64) -> f64 {
        if self.jitter <= 0.0 {
            return 1.0;
        }
        let x = xorshift64star(
            seed ^ 0xada0_0417 // decorrelate from batch/compressor streams
                ^ (learner as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let u = (x >> 11) as f64 / (1u64 << 53) as f64;
        let spike = if x & ((1u64 << STRAGGLE_SHIFT) - 1) == 0 {
            STRAGGLE_BOOST * self.jitter
        } else {
            0.0
        };
        1.0 + self.jitter * u + spike
    }
}

/// Byte + time accounting for one training run.
#[derive(Debug, Clone, Default)]
pub struct FabricStats {
    /// Total bytes every learner pushed into the fabric.
    pub bytes_up: u64,
    /// Total bytes delivered to learners.
    pub bytes_down: u64,
    /// Number of exchange rounds: one per reduce-plan bucket per step, in
    /// both exchange modes (the modes differ in placement, not message
    /// structure).
    pub rounds: u64,
    /// Simulated communication seconds (sum over rounds of the critical path).
    pub sim_time_s: f64,
    /// What the same rounds would have cost uncompressed (dense f32).
    pub dense_bytes_equiv: u64,
    /// Steps folded into the step timeline (`record_step` calls).
    pub steps: u64,
    /// The simulated makespan of the run's actual schedule: Σ per-step
    /// frontier advances (comm overlapped behind backward on the streamed
    /// path; with bounded staleness, steps also amortize behind each
    /// other). On the synchronous (K = 0) barrier path this equals
    /// `sim_barrier_s` (nothing overlaps).
    pub sim_overlap_s: f64,
    /// Σ per-step compute + serialized comm: the same packets behind a full
    /// barrier.
    pub sim_barrier_s: f64,
    /// Σ per-step compute + serialized *dense f32* comm: the
    /// no-compression, no-overlap baseline.
    pub sim_dense_s: f64,
    /// Σ over (learner, step) of simulated idle time: how long learners sat
    /// waiting for the staleness window (the K-back update frontier) before
    /// starting their next step. The synchronous engine (K = 0) charges the
    /// full barrier wait here; bounded staleness exists to shrink it.
    pub stall_s: f64,
    /// Per-learner count of steps where this learner finished compute last
    /// (the step's critical path ran through it). With jitter off every
    /// learner ties near-evenly; a straggler shows up as a dominant share
    /// ([`crit_share`](Self::crit_share)).
    pub crit_steps: Vec<u64>,
    /// Simulated seconds spent rebuilding the fleet (reduce plan, topology,
    /// cell rings) across all membership epochs.
    pub rebuild_s: f64,
    /// Simulated idle seconds learners spent while the engine drained the
    /// staleness window to the frontier before a membership event.
    pub drain_stall_s: f64,
    /// Total L1 mass of residual gradient lost to `fail` events (learners
    /// that vanished without handover).
    pub lost_residual_l1: f64,
    /// Total L1 mass of residual gradient handed over by `leave` events
    /// (folded into the survivors' residue stores).
    pub handover_l1: f64,
    /// Membership timeline: one entry per applied churn event.
    pub membership: Vec<MembershipChange>,
    /// Control-plane timeline: one entry per knob re-tune applied by the
    /// adaptive controller (`--controller on`) at an epoch boundary.
    pub control: Vec<ControlDecision>,
    /// Total controller re-tunes (== `control.len()`, kept as a scalar so
    /// summaries don't have to walk the timeline).
    pub control_retunes: u64,
}

/// One applied membership event (fail / join / leave) and its recovery
/// accounting, recorded by [`Fabric::record_membership`].
#[derive(Debug, Clone)]
pub struct MembershipChange {
    /// Global step boundary the event fired at.
    pub step: u64,
    /// Event kind name ("fail" | "join" | "leave").
    pub kind: String,
    /// Learners added or removed.
    pub count: usize,
    /// Fleet size after the event.
    pub n_after: usize,
    /// Effective topology after the rebuild (post-fallback).
    pub topology: String,
    /// True when the requested topology's bounds no longer held and the
    /// rebuild degraded to a fallback instead of aborting.
    pub degraded: bool,
    /// Simulated seconds this event's rebuild took.
    pub rebuild_s: f64,
    /// Simulated idle seconds spent draining the window for this event.
    pub drain_stall_s: f64,
    /// Residual L1 mass lost by this event (fail only; 0 otherwise).
    pub lost_l1: f64,
    /// Residual L1 mass handed over by this event (leave only; 0 otherwise).
    pub handover_l1: f64,
    /// Bucket-coalescing threshold (dense wire bytes) the post-event plan
    /// was rebuilt with: the *live* value — re-derived from the link model
    /// and the post-event topology's ports when `--bucket-bytes 0` (auto),
    /// or the controller-tuned value when the controller owns the knob.
    pub threshold_bytes: usize,
    /// Bucket count of the rebuilt plan (observable proof the rebuild used
    /// the recomputed threshold, not the run-start one).
    pub n_buckets: usize,
}

/// One knob re-tune applied by the adaptive controller at an epoch
/// boundary, recorded by [`Fabric::record_decision`]. Decisions are a pure
/// function of the epoch's deterministic measurements (see
/// `train::control`), so this timeline is bit-identical across thread
/// counts and exchange modes.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlDecision {
    /// Epoch whose measurements produced this decision (the new value takes
    /// effect from epoch + 1).
    pub epoch: usize,
    /// Knob name: `"staleness"`, `"bucket_bytes"`, or `"lt:<layer>"`.
    pub knob: String,
    /// Value before the re-tune.
    pub old: f64,
    /// Value after the re-tune.
    pub new: f64,
    /// Human-readable signal that tripped the rule (threshold crossings
    /// included, for the decision timeline in RunRecord).
    pub signal: String,
}

impl FabricStats {
    /// End-to-end compression rate actually achieved on the wire.
    pub fn effective_rate(&self) -> f64 {
        if self.bytes_up == 0 {
            1.0
        } else {
            self.dense_bytes_equiv as f64 / self.bytes_up as f64
        }
    }

    /// Mean simulated step time of the run's actual exchange placement
    /// (overlapped on the streamed path, serialized on the barrier path).
    pub fn sim_step_s(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sim_overlap_s / self.steps as f64
        }
    }

    /// Projected end-to-end speedup of this run's placement (overlapped +
    /// compressed) over the dense/barrier baseline — the paper's ~40X/~200X
    /// compression rates expressed as step-time wins.
    pub fn projected_speedup(&self) -> f64 {
        if self.sim_overlap_s <= 0.0 {
            1.0
        } else {
            self.sim_dense_s / self.sim_overlap_s
        }
    }

    /// Σ per-step `max(comm_end, compute) − compute`: the comm tail of the
    /// overlap placement with the measured compute canceled out — the
    /// deterministic part of the streamed timeline (round costs are
    /// simulated), comparable across runs. Derived from the identity
    /// `sim_barrier_s = Σ(compute + comm_serial)` and
    /// `sim_time_s = Σ comm_serial`.
    pub fn comm_tail_s(&self) -> f64 {
        self.sim_overlap_s - self.sim_barrier_s + self.sim_time_s
    }

    /// Σ per-step dense-baseline comm with the measured compute canceled
    /// (steps × the plan's canonical dense round) — deterministic, used to
    /// pin the baseline's mode/topology independence.
    pub fn dense_comm_total_s(&self) -> f64 {
        self.sim_dense_s - self.sim_barrier_s + self.sim_time_s
    }

    /// Mean simulated stall seconds per (learner, step).
    pub fn stall_per_step_s(&self) -> f64 {
        let cells = self.steps.max(1) * self.crit_steps.len().max(1) as u64;
        self.stall_s / cells as f64
    }

    /// Fraction of steps whose compute critical path ran through each
    /// learner (sums to ~1 over learners).
    pub fn crit_share(&self) -> Vec<f64> {
        let steps = self.steps.max(1) as f64;
        self.crit_steps.iter().map(|&c| c as f64 / steps).collect()
    }
}

/// The fabric: link model + running stats.
#[derive(Debug, Clone, Default)]
pub struct Fabric {
    pub link: LinkModel,
    pub stats: FabricStats,
}

impl Fabric {
    pub fn new(link: LinkModel) -> Fabric {
        Fabric {
            link,
            stats: FabricStats::default(),
        }
    }

    /// Record one exchange round.
    ///
    /// * `per_learner_up`: bytes each learner sent,
    /// * `per_learner_down`: bytes each learner received,
    /// * `critical_path_s`: the topology's computed round time,
    /// * `dense_equiv`: what dense f32 would have sent in total.
    pub fn record_round(
        &mut self,
        per_learner_up: &[usize],
        per_learner_down: &[usize],
        critical_path_s: f64,
        dense_equiv: usize,
    ) {
        self.stats.bytes_up += per_learner_up.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.bytes_down += per_learner_down.iter().map(|&b| b as u64).sum::<u64>();
        self.stats.rounds += 1;
        self.stats.sim_time_s += critical_path_s;
        self.stats.dense_bytes_equiv += dense_equiv as u64;
    }

    /// Fold one finished training step onto the simulated step timeline.
    ///
    /// * `compute_s`: the step's (jittered) compute span — max over the
    ///   learners' simulated step durations,
    /// * `comm_serial_s`: Σ per-round comm time of the step's exchanges,
    /// * `overlap_s`: the step's increment on the continuous overlap
    ///   timeline — how far the applied-update frontier advanced (streamed:
    ///   per-bucket rounds pipelined behind backward across the topology's
    ///   ports and, with staleness, behind *later steps'* compute; barrier:
    ///   the serialized placement). The window scheduler may advance the
    ///   frontier by **less than** `compute_s` on an amortized step — the
    ///   engine owns the placement, the fabric only accumulates it,
    /// * `dense_comm_s`: Σ per-round dense-baseline comm time (the
    ///   synchronous coalesced round — the "before" system is always the
    ///   K = 0 barrier placement).
    pub fn record_step(
        &mut self,
        compute_s: f64,
        comm_serial_s: f64,
        overlap_s: f64,
        dense_comm_s: f64,
    ) {
        self.stats.steps += 1;
        self.stats.sim_overlap_s += overlap_s;
        self.stats.sim_barrier_s += compute_s + comm_serial_s;
        self.stats.sim_dense_s += compute_s + dense_comm_s;
    }

    /// Fold one step's straggler accounting: `stalls[l]` = simulated idle
    /// seconds learner `l` spent waiting for the staleness window before
    /// this step, `crit` = the learner whose compute finished last.
    pub fn record_stall(&mut self, stalls: &[f64], crit: usize) {
        if self.stats.crit_steps.len() < stalls.len() {
            self.stats.crit_steps.resize(stalls.len(), 0);
        }
        self.stats.stall_s += stalls.iter().sum::<f64>();
        self.stats.crit_steps[crit] += 1;
    }

    /// Record one applied membership event: appends it to the timeline and
    /// folds its recovery costs into the run totals.
    pub fn record_membership(&mut self, change: MembershipChange) {
        self.stats.rebuild_s += change.rebuild_s;
        self.stats.drain_stall_s += change.drain_stall_s;
        self.stats.lost_residual_l1 += change.lost_l1;
        self.stats.handover_l1 += change.handover_l1;
        self.stats.membership.push(change);
    }

    /// Record one applied controller re-tune: appends it to the decision
    /// timeline and bumps the run total.
    pub fn record_decision(&mut self, decision: ControlDecision) {
        self.stats.control_retunes += 1;
        self.stats.control.push(decision);
    }

    pub fn reset(&mut self) {
        self.stats = FabricStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_model() {
        let l = LinkModel {
            latency_s: 1e-3,
            bandwidth_bps: 1e6,
            ..LinkModel::default()
        };
        // 1ms latency + 1000 bytes at 1MB/s = 1ms -> 2ms
        assert!((l.transfer_time(1000) - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut f = Fabric::new(LinkModel::default());
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        f.record_round(&[100, 100], &[200, 200], 0.5, 1600);
        assert_eq!(f.stats.bytes_up, 400);
        assert_eq!(f.stats.bytes_down, 800);
        assert_eq!(f.stats.rounds, 2);
        assert!((f.stats.sim_time_s - 1.0).abs() < 1e-12);
        assert!((f.stats.effective_rate() - 8.0).abs() < 1e-12);
        f.reset();
        assert_eq!(f.stats.rounds, 0);
    }

    #[test]
    fn step_timeline_overlap_vs_barrier_vs_dense() {
        let mut f = Fabric::new(LinkModel::default());
        // compute 10ms; compressed comm 2ms total, finishing at 10.5ms when
        // overlapped; dense comm would take 40ms serialized.
        f.record_step(10e-3, 2e-3, 10.5e-3, 40e-3);
        assert_eq!(f.stats.steps, 1);
        assert!((f.stats.sim_overlap_s - 10.5e-3).abs() < 1e-12);
        assert!((f.stats.sim_barrier_s - 12e-3).abs() < 1e-12);
        assert!((f.stats.sim_dense_s - 50e-3).abs() < 1e-12);
        assert!(f.stats.sim_overlap_s < f.stats.sim_barrier_s);
        assert!((f.stats.sim_step_s() - 10.5e-3).abs() < 1e-12);
        assert!((f.stats.projected_speedup() - 50.0 / 10.5).abs() < 1e-9);
        // with bounded staleness a step may advance the frontier by less
        // than its own compute (amortized behind earlier steps) — the
        // fabric accumulates the engine's placement verbatim
        f.record_step(5e-3, 1e-3, 1e-3, 2e-3);
        assert!((f.stats.sim_overlap_s - 11.5e-3).abs() < 1e-12);
    }

    #[test]
    fn stall_accounting_accumulates_and_shares() {
        let mut f = Fabric::new(LinkModel::default());
        f.record_step(1e-3, 0.0, 1e-3, 2e-3);
        f.record_stall(&[0.0, 2e-3, 1e-3], 1);
        f.record_step(1e-3, 0.0, 1e-3, 2e-3);
        f.record_stall(&[5e-4, 0.0, 5e-4], 1);
        assert!((f.stats.stall_s - 4e-3).abs() < 1e-15);
        assert!((f.stats.stall_per_step_s() - 4e-3 / 6.0).abs() < 1e-15);
        assert_eq!(f.stats.crit_steps, vec![0, 2, 0]);
        let share = f.stats.crit_share();
        assert_eq!(share, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn membership_events_accumulate_recovery_totals() {
        let mut f = Fabric::new(LinkModel::default());
        f.record_membership(MembershipChange {
            step: 20,
            kind: "fail".into(),
            count: 1,
            n_after: 3,
            topology: "ps:3".into(),
            degraded: true,
            rebuild_s: 1e-3,
            drain_stall_s: 2e-3,
            lost_l1: 5.0,
            handover_l1: 0.0,
            threshold_bytes: 31250,
            n_buckets: 2,
        });
        f.record_membership(MembershipChange {
            step: 40,
            kind: "leave".into(),
            count: 1,
            n_after: 2,
            topology: "ps:2".into(),
            degraded: true,
            rebuild_s: 1e-3,
            drain_stall_s: 0.0,
            lost_l1: 0.0,
            handover_l1: 3.5,
            threshold_bytes: 62500,
            n_buckets: 1,
        });
        assert_eq!(f.stats.membership.len(), 2);
        assert!((f.stats.rebuild_s - 2e-3).abs() < 1e-12);
        assert!((f.stats.drain_stall_s - 2e-3).abs() < 1e-12);
        assert!((f.stats.lost_residual_l1 - 5.0).abs() < 1e-12);
        assert!((f.stats.handover_l1 - 3.5).abs() < 1e-12);
        assert_eq!(f.stats.membership[0].kind, "fail");
        assert_eq!(f.stats.membership[1].n_after, 2);
        // the rebuilt plan's live threshold + bucket count ride along
        assert_eq!(f.stats.membership[0].threshold_bytes, 31250);
        assert_eq!(f.stats.membership[1].threshold_bytes, 62500);
        assert_eq!(f.stats.membership[1].n_buckets, 1);
        f.reset();
        assert!(f.stats.membership.is_empty());
    }

    #[test]
    fn control_decisions_accumulate_timeline_and_totals() {
        let mut f = Fabric::new(LinkModel::default());
        assert_eq!(f.stats.control_retunes, 0);
        f.record_decision(ControlDecision {
            epoch: 0,
            knob: "staleness".into(),
            old: 0.0,
            new: 1.0,
            signal: "straggler_excess=0.21>0.10".into(),
        });
        f.record_decision(ControlDecision {
            epoch: 1,
            knob: "lt:3".into(),
            old: 50.0,
            new: 100.0,
            signal: "comm_share=0.40 vs elems_share=0.10".into(),
        });
        assert_eq!(f.stats.control.len(), 2);
        assert_eq!(f.stats.control_retunes, 2);
        assert_eq!(f.stats.control[0].knob, "staleness");
        assert_eq!(f.stats.control[1].knob, "lt:3");
        assert_eq!(f.stats.control[1].new, 100.0);
        f.reset();
        assert!(f.stats.control.is_empty());
        assert_eq!(f.stats.control_retunes, 0);
    }

    #[test]
    fn jitter_model_is_deterministic_bounded_and_validated() {
        let link = LinkModel {
            jitter: 0.3,
            ..LinkModel::default()
        };
        // pure function of (seed, learner, step): repeat draws identical
        let mut spikes = 0usize;
        for l in 0..16usize {
            for t in 0..200u64 {
                let m = link.compute_mult(42, l, t);
                assert_eq!(m.to_bits(), link.compute_mult(42, l, t).to_bits());
                // base draw in [1, 1.3); straggler episodes add 4*0.3
                assert!((1.0..1.0 + 0.3 + STRAGGLE_BOOST * 0.3).contains(&m), "{m}");
                if m >= 1.0 + STRAGGLE_BOOST * 0.3 {
                    spikes += 1;
                }
            }
        }
        // ~1/8 of cells are straggler episodes (3200 draws: loose bounds)
        assert!((200..600).contains(&spikes), "spikes {spikes}");
        // different seeds decorrelate
        assert_ne!(
            link.compute_mult(1, 0, 0).to_bits(),
            link.compute_mult(2, 0, 0).to_bits()
        );
        // jitter off: multiplier is exactly 1
        let off = LinkModel::default();
        assert_eq!(off.compute_mult(42, 3, 7), 1.0);
        // range validation (the fail-fast satellite)
        assert!(LinkModel::validate_jitter(0.0).is_ok());
        assert!(LinkModel::validate_jitter(0.999).is_ok());
        for bad in [-0.1, 1.0, 2.5, f64::NAN, f64::INFINITY] {
            let err = LinkModel::validate_jitter(bad).unwrap_err().to_string();
            assert!(err.contains("0.0 <= jitter < 1.0"), "{bad}: {err}");
        }
    }
}
