//! The reduce plan: how a model's layers become wire messages.
//!
//! Built from the model [`Layout`] at run start — and **rebuilt** under the
//! fleet write lock whenever the fleet or the knobs change (membership
//! epochs re-derive the auto threshold for the post-event topology; the
//! adaptive controller re-tunes `threshold_bytes` at epoch boundaries) —
//! the plan answers two questions the exchange path used to hard-code:
//!
//! 1. **Bucketing** — which layers share a wire message. PR 3's per-layer
//!    timeline showed tiny layers (biases) paying one full per-message
//!    latency each on the streamed path. The plan walks the layout in
//!    **reverse layer order** (the order gradients complete during
//!    backward) and coalesces consecutive sub-threshold layers into a
//!    bucket: one [`bucket frame`](crate::compress::wire::bucket_wire_len)
//!    per bucket on the wire (a real serialized byte frame on the engine
//!    path — its measured length is what the fabric is charged), one
//!    latency charge per bucket. A layer whose
//!    dense wire size alone reaches the threshold stands as its own bucket
//!    (big layers must not wait for neighbours). Because the walk is the
//!    streamed completion order, every bucket covers a **contiguous** layer
//!    range and becomes exchangeable the moment its earliest layer's
//!    gradient is packed.
//! 2. **Port mapping** — which fabric port carries each bucket. Sharded
//!    topologies ([`ParamServer`](super::topology::ParamServer) with
//!    `ps:<S>`) expose S independent ports; the plan partitions buckets
//!    over them
//!    (`bucket.id % ports`), and the engine overlaps rounds on disjoint
//!    ports on the simulated timeline while rounds on one port serialize.
//!
//! The plan also owns the run's **canonical dense baseline**
//! ([`ReducePlan::dense_round_s`]): the cost of shipping the entire model
//! dense (f32) as **one coalesced message** per learner each way through a
//! single serialized port — no sharding, no overlap, no bucketing. The
//! same "before" system for every topology, exchange mode, *and* bucket
//! threshold, so `projected_speedup` compares apples to apples across
//! `--topology`, `--exchange`, and `--bucket-bytes` choices.
//!
//! The plan never touches floats: reduction order (learner-id within each
//! bucket) is the topologies' contract, which is why results stay
//! bit-identical across every plan shape.

use std::ops::Range;

use super::fabric::LinkModel;
use crate::compress::wire::{bucket_wire_len, dense_f32_wire_len};
use crate::models::Layout;

/// One coalesced wire message: a contiguous run of layout layers.
#[derive(Debug, Clone)]
pub struct Bucket {
    /// Position in [`ReducePlan::buckets`] (reverse-layer streamed order).
    pub id: usize,
    /// Fabric port this bucket's rounds run on (`< ReducePlan::ports`).
    pub port: usize,
    /// The layout layers coalesced into this bucket, as an ascending range;
    /// packets inside the bucket's message travel in this (ascending layer)
    /// order.
    pub layers: Range<usize>,
}

impl Bucket {
    /// A synthetic whole-model bucket (benches/tests drive the coalesced
    /// barrier exchange through this; the engine uses a real plan).
    pub fn whole_model(num_layers: usize) -> Bucket {
        Bucket {
            id: 0,
            port: 0,
            layers: 0..num_layers,
        }
    }

    /// Number of layers (sub-messages) in this bucket.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Dense f32 wire bytes of this bucket's frame: every layer dense,
    /// wrapped in the bucket frame. The canonical no-compression message.
    pub fn dense_wire_bytes(&self, layer_lens: &[usize]) -> usize {
        let payload: usize = self
            .layers
            .clone()
            .map(|li| dense_f32_wire_len(layer_lens[li]))
            .sum();
        bucket_wire_len(self.num_layers(), payload)
    }
}

/// Canonical dense baseline for one bucket: each learner ships the bucket
/// dense through a single serialized port, up and down — no compression, no
/// sharding, no overlap. Identical for every topology by construction
/// (pinned by `dense_baseline_is_topology_independent`).
pub fn dense_bucket_s(
    bucket: &Bucket,
    layer_lens: &[usize],
    n_learners: usize,
    link: &LinkModel,
) -> f64 {
    2.0 * n_learners as f64 * link.transfer_time(bucket.dense_wire_bytes(layer_lens))
}

/// The run's reduce plan: buckets in streamed completion order plus the
/// layer → bucket map.
#[derive(Debug, Clone)]
pub struct ReducePlan {
    /// Buckets in reverse-layer streamed order: `buckets[0]` holds the
    /// *last* layout layers (first gradients to complete during backward).
    pub buckets: Vec<Bucket>,
    /// `bucket_of[layer]` = index into `buckets`.
    pub bucket_of: Vec<usize>,
    /// Coalescing threshold actually used, in dense wire bytes.
    pub threshold_bytes: usize,
    /// Fabric ports the buckets are partitioned over.
    pub ports: usize,
}

impl ReducePlan {
    /// Default coalescing threshold for a link: the latency·bandwidth
    /// product (α·β). Below it a message's per-message latency costs more
    /// than its payload transfer — exactly the regime where coalescing
    /// wins; above it streaming granularity matters more than latency.
    pub fn auto_threshold(link: &LinkModel) -> usize {
        ((link.latency_s * link.bandwidth_bps) as usize).max(1)
    }

    /// Ports-aware auto threshold: α·β scaled down by the topology's port
    /// count. A sharded fabric (`ps:<S>`) only reaches its concurrency when
    /// the plan yields at least S buckets, so the more ports the fleet
    /// exposes, the finer the auto plan should slice. Single-port
    /// topologies (`ring`, `ps`, `hier:<G>`) get exactly
    /// [`auto_threshold`](Self::auto_threshold). This is what the engine
    /// derives `--bucket-bytes 0` from — including at membership epochs,
    /// where a topology fallback can change the port count mid-run.
    pub fn auto_threshold_for(link: &LinkModel, ports: usize) -> usize {
        (Self::auto_threshold(link) / ports.max(1)).max(1)
    }

    /// Build the plan: walk layers in reverse order, coalescing consecutive
    /// layers whose dense wire size is below `threshold_bytes` until the
    /// open bucket reaches the threshold; at-or-above-threshold layers get
    /// singleton buckets. `threshold_bytes = 1` reproduces the pre-plan
    /// per-layer messages. Buckets are assigned ports round-robin.
    pub fn build(layout: &Layout, threshold_bytes: usize, ports: usize) -> ReducePlan {
        let threshold_bytes = threshold_bytes.max(1);
        let ports = ports.max(1);
        let num_layers = layout.num_layers();
        let mut buckets: Vec<Bucket> = Vec::new();
        // open bucket: ascending range [open_lo, open_hi) accumulated while
        // walking layers downwards (open_hi fixed, open_lo decreasing)
        let mut open: Option<(Range<usize>, usize)> = None;
        fn close(open: &mut Option<(Range<usize>, usize)>, buckets: &mut Vec<Bucket>) {
            if let Some((layers, _)) = open.take() {
                buckets.push(Bucket {
                    id: buckets.len(),
                    port: 0,
                    layers,
                });
            }
        }
        for li in (0..num_layers).rev() {
            let bytes = dense_f32_wire_len(layout.layers[li].len());
            if bytes >= threshold_bytes {
                // big layer: its own bucket, never merged
                close(&mut open, &mut buckets);
                buckets.push(Bucket {
                    id: buckets.len(),
                    port: 0,
                    layers: li..li + 1,
                });
                continue;
            }
            let (layers, acc) = match open.take() {
                Some((r, acc)) => (li..r.end, acc + bytes),
                None => (li..li + 1, bytes),
            };
            open = Some((layers, acc));
            if acc >= threshold_bytes {
                close(&mut open, &mut buckets);
            }
        }
        close(&mut open, &mut buckets);

        let mut bucket_of = vec![usize::MAX; num_layers];
        for b in buckets.iter_mut() {
            b.port = b.id % ports;
            for li in b.layers.clone() {
                bucket_of[li] = b.id;
            }
        }
        debug_assert!(bucket_of.iter().all(|&b| b != usize::MAX));
        ReducePlan {
            buckets,
            bucket_of,
            threshold_bytes,
            ports,
        }
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Largest per-bucket layer count — sizes the engine's reusable
    /// per-learner gather buffers (one packet per bucket layer).
    pub fn max_bucket_layers(&self) -> usize {
        self.buckets.iter().map(|b| b.num_layers()).max().unwrap_or(0)
    }

    /// (bucket index, slot within the bucket's message) for a layout layer.
    pub fn slot_of(&self, layer: usize) -> (usize, usize) {
        let bi = self.bucket_of[layer];
        (bi, layer - self.buckets[bi].layers.start)
    }

    /// The run's canonical dense baseline: every learner ships the
    /// **entire model** as one dense f32 message each way through a single
    /// serialized port — no compression, no bucketing, no sharding, no
    /// overlap. Deliberately independent of the plan's bucket structure
    /// (a smaller `--bucket-bytes` must not inflate the "before" system
    /// with extra per-message latency) and identical across topologies and
    /// exchange modes, so `projected_speedup` is comparable across every
    /// knob. Constant for a fixed (layout, learner count, link); the
    /// engine computes it once per run.
    pub fn dense_round_s(&self, layer_lens: &[usize], n_learners: usize, link: &LinkModel) -> f64 {
        dense_bucket_s(
            &Bucket::whole_model(layer_lens.len()),
            layer_lens,
            n_learners,
            link,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::wire::HEADER_BYTES;
    use crate::models::LayerKind;

    /// mlp-ish layout: big weight / tiny bias pairs.
    fn layout() -> Layout {
        Layout::from_specs(&[
            ("w1", &[2000], LayerKind::Fc), // 8016 dense-wire bytes
            ("b1", &[20], LayerKind::Fc),   // 96
            ("w2", &[1500], LayerKind::Fc), // 6016
            ("b2", &[10], LayerKind::Fc),   // 56
        ])
    }

    #[test]
    fn every_layer_in_exactly_one_bucket() {
        let layout = layout();
        for threshold in [1usize, 200, 4096, 1 << 20] {
            let plan = ReducePlan::build(&layout, threshold, 2);
            let mut seen = vec![0usize; layout.num_layers()];
            for b in &plan.buckets {
                for li in b.layers.clone() {
                    seen[li] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "threshold {threshold}: {seen:?}");
            // bucket_of agrees with the bucket ranges
            for li in 0..layout.num_layers() {
                let (bi, slot) = plan.slot_of(li);
                assert!(plan.buckets[bi].layers.contains(&li));
                assert_eq!(plan.buckets[bi].layers.start + slot, li);
            }
        }
    }

    #[test]
    fn bucket_order_is_reverse_layer_streamed_order() {
        let layout = layout();
        for threshold in [1usize, 200, 4096] {
            let plan = ReducePlan::build(&layout, threshold, 1);
            // bucket k's layers all come after bucket k+1's layers in the
            // layout — i.e. bucket order = reverse completion order
            for w in plan.buckets.windows(2) {
                assert!(
                    w[0].layers.start >= w[1].layers.end,
                    "threshold {threshold}: {:?} then {:?}",
                    w[0].layers,
                    w[1].layers
                );
            }
            // ids are positions
            for (i, b) in plan.buckets.iter().enumerate() {
                assert_eq!(b.id, i);
            }
        }
    }

    #[test]
    fn tiny_layer_coalescing_respects_threshold() {
        let layout = layout();
        // threshold 4096: b2 (56) and b1 (96) are sub-threshold, w1/w2 are
        // not. Reverse walk: b2 opens a bucket; w2 (6016 >= 4096) closes it
        // as a singleton-of-b2 and stands alone; b1 opens; w1 stands alone.
        let plan = ReducePlan::build(&layout, 4096, 1);
        let ranges: Vec<Range<usize>> = plan.buckets.iter().map(|b| b.layers.clone()).collect();
        assert_eq!(ranges, vec![3..4, 2..3, 1..2, 0..1]);

        // threshold 1 MiB: everything sub-threshold -> one bucket
        let plan = ReducePlan::build(&layout, 1 << 20, 1);
        assert_eq!(plan.num_buckets(), 1);
        assert_eq!(plan.buckets[0].layers, 0..4);

        // threshold 1: per-layer buckets (the pre-plan wire shape)
        let plan = ReducePlan::build(&layout, 1, 1);
        assert_eq!(plan.num_buckets(), 4);
        assert!(plan.buckets.iter().all(|b| b.num_layers() == 1));

        // threshold 10000: b2 + w2 coalesce (56 + 6016 < 10000), b1 joins
        // (6168 < 10000), then w1 (8016 < 10000) joins and the cumulative
        // 14184 >= 10000 closes the bucket — all four in one message
        let plan = ReducePlan::build(&layout, 10000, 1);
        assert_eq!(plan.num_buckets(), 1);

        // a run of tiny layers closes once the *cumulative* size crosses
        let tiny = Layout::from_specs(&[
            ("t0", &[10], LayerKind::Fc),
            ("t1", &[10], LayerKind::Fc),
            ("t2", &[10], LayerKind::Fc),
            ("t3", &[10], LayerKind::Fc),
        ]);
        // each is 56 bytes; threshold 100 -> two buckets of two
        let plan = ReducePlan::build(&tiny, 100, 1);
        let ranges: Vec<Range<usize>> = plan.buckets.iter().map(|b| b.layers.clone()).collect();
        assert_eq!(ranges, vec![2..4, 0..2]);
        assert_eq!(plan.max_bucket_layers(), 2);
        assert_eq!(ReducePlan::build(&tiny, 1 << 20, 1).max_bucket_layers(), 4);
    }

    #[test]
    fn ports_partition_round_robin() {
        let layout = layout();
        let plan = ReducePlan::build(&layout, 1, 3);
        assert_eq!(plan.ports, 3);
        let ports: Vec<usize> = plan.buckets.iter().map(|b| b.port).collect();
        assert_eq!(ports, vec![0, 1, 2, 0]);
        // single port: everything on port 0
        let plan = ReducePlan::build(&layout, 1, 1);
        assert!(plan.buckets.iter().all(|b| b.port == 0));
    }

    #[test]
    fn auto_threshold_is_latency_bandwidth_product() {
        let link = LinkModel::default(); // 25us, 1.25 GB/s
        assert_eq!(ReducePlan::auto_threshold(&link), 31250);
        let tiny = LinkModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            ..LinkModel::default()
        };
        assert_eq!(ReducePlan::auto_threshold(&tiny), 1);
    }

    #[test]
    fn ports_aware_auto_threshold_scales_down_with_ports() {
        let link = LinkModel::default();
        // single-port topologies: unchanged α·β
        assert_eq!(ReducePlan::auto_threshold_for(&link, 1), 31250);
        // S ports slice S× finer (so the auto plan can feed all ports)
        assert_eq!(ReducePlan::auto_threshold_for(&link, 2), 15625);
        assert_eq!(ReducePlan::auto_threshold_for(&link, 4), 7812);
        // degenerate inputs clamp instead of dividing by zero / hitting 0
        assert_eq!(ReducePlan::auto_threshold_for(&link, 0), 31250);
        let tiny = LinkModel {
            latency_s: 0.0,
            bandwidth_bps: 1e9,
            ..LinkModel::default()
        };
        assert_eq!(ReducePlan::auto_threshold_for(&tiny, 8), 1);
    }

    #[test]
    fn dense_round_is_plan_shape_independent() {
        // the canonical baseline must not vary with the bucket threshold:
        // a finer plan changes the *compressed* message structure, never
        // the "before" system projected_speedup divides by
        let layout = layout();
        let lens = layout.layer_lens();
        let link = LinkModel::default();
        let whole = dense_bucket_s(&Bucket::whole_model(lens.len()), &lens, 4, &link);
        for threshold in [1usize, 200, 4096, 1 << 20] {
            let plan = ReducePlan::build(&layout, threshold, 2);
            let total = plan.dense_round_s(&lens, 4, &link);
            assert!((total - whole).abs() < 1e-18, "threshold {threshold}");
        }
        // one singleton bucket's dense bytes: frame + one dense sub-message
        let plan = ReducePlan::build(&layout, 4096, 2);
        let b = &plan.buckets[0]; // {b2}: 10 elements
        assert_eq!(
            b.dense_wire_bytes(&lens),
            bucket_wire_len(1, HEADER_BYTES + 4 * 10)
        );
        // more learners -> strictly costlier baseline
        assert!(plan.dense_round_s(&lens, 8, &link) > whole);
    }
}
