//! Exchange topologies over compressed gradient packets.
//!
//! Every topology implements the same *semantics* — each round, every
//! learner ends up holding the elementwise **sum** of all learners' packets
//! for the round's bucket (synchronous SGD with identical weights, as in
//! the paper) — but charges the fabric differently:
//!
//! * [`ParamServer`] (`ps`, `ps:<S>`): learners push bucket messages up;
//!   the server reduces and broadcasts the merged *sparse union* back down.
//!   A shard's in/out links serialize across learners (single-port model).
//!   With `S > 1` shards, each shard is an independent **port**: the reduce
//!   plan partitions buckets over ports and the engine overlaps rounds on
//!   disjoint ports on the simulated timeline — the sharding win is
//!   pipeline parallelism across buckets, not a cheaper single round.
//! * [`HierPs`] (`hier:<G>`): rack-local aggregators of G learners feed a
//!   root — a two-hop tree. Per round: members serialize into their
//!   aggregator (racks in parallel), aggregators serialize their rack
//!   unions into the root, the root serializes the global union back out,
//!   aggregators broadcast to members (racks in parallel). The root handles
//!   ceil(N/G) messages instead of N — the classic fan-in reduction.
//! * [`Ring`]: all-gather of compressed bucket messages around the ring
//!   (the paper-cited NCCL-style ring, Luehr'16): N-1 hops, per-hop time =
//!   latency + max message / bandwidth; all links run in parallel.
//!
//! **Granularity.** The unit of exchange is the reduce-plan
//! [`Bucket`](super::plan::Bucket): one
//! [bucket frame](crate::compress::wire::bucket_wire_len) per learner per
//! round coalescing the bucket's per-layer packets, so per-message latency
//! is charged per *bucket* — tiny layers (biases) ride along with their
//! neighbours instead of paying a full latency each
//! ([`exchange_bucket_into`](Topology::exchange_bucket_into)).
//! [`Topology::exchange_into`] drives the same path through a synthetic
//! whole-model bucket (the pre-plan coalesced barrier round) for benches
//! and tests.
//!
//! **Measured bytes.** On the engine's exchange path the packets handed to
//! [`exchange_bucket_into`](Topology::exchange_bucket_into) are *decoded
//! from the learner's serialized bucket frame*
//! ([`wire::decode_bucket_frame_into`]
//! (crate::compress::wire::decode_bucket_frame_into)), so each packet's
//! `wire_bytes` is the measured length of its sub-message and the bucket
//! message the fabric is charged sums to exactly the frame's byte length —
//! real encoded bytes, not an estimate. The analytic `*_wire_len` lens in
//! [`wire`](crate::compress::wire) survive as the compressors' a-priori
//! sizes (compression-rate stats, dense baselines) and as a cross-check:
//! v1 forms measure exactly analytic, v2 delta-vbyte forms measure at or
//! under it in the 16-bit slot regime.
//!
//! **Dense baseline.** Every round reports
//! [`RoundCost::dense_comm_s`] = [`plan::dense_bucket_s`] — the canonical
//! single-port uncompressed cost of the same bucket, *identical across
//! topologies and exchange modes* so `projected_speedup` always compares
//! against the same "before" system.
//!
//! **Determinism.** Packets are reduced densely in learner-id order within
//! each bucket ([`reduce_bucket_into`]) no matter the topology: the
//! simulated aggregation structure (shards, racks, ring hops) affects only
//! the *timeline*, never the float summation order. This is what keeps
//! results bit-identical across `ps`/`ps:S`/`hier:G`/`ring` × exchange mode
//! × thread count (rust/tests/engine_native.rs).
//!
//! Hot-path contract (DESIGN.md §Threading): exchanges reuse the caller's
//! buffers and each topology's internal scratch — a steady-state round
//! performs **zero heap allocation** (rust/tests/alloc_free.rs).

use anyhow::bail;

use super::fabric::{Fabric, LinkModel};
use super::plan::{dense_bucket_s, Bucket};
use crate::compress::wire::{bucket_wire_len, HEADER_BYTES};
use crate::compress::Packet;

/// Valid-form list for [`build`] errors (grammar, not literal names —
/// `ps:<S>`/`hier:<G>` take an integer parameter).
const VALID: &str = "valid: ring, ps, ps:<S> (S shard servers), hier:<G> (racks of G); \
                     alias: param_server = ps";

/// Ready-time inputs for placing one round on the simulated timeline
/// (the bounded-staleness scheduler's contract with the topologies): when
/// the bucket became exchangeable at every learner, and when its assigned
/// port last went idle. The default (both zero) reproduces the
/// placement-free cost accounting benches and tests use.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundSched {
    /// Simulated time the bucket's last learner published it.
    pub ready_s: f64,
    /// Simulated completion time of the previous round on this bucket's
    /// port (rounds on one port serialize; disjoint ports overlap).
    pub port_free_s: f64,
}

/// Simulated cost of one exchange round (one bucket, or the whole-model
/// bucket on the coalesced barrier path), including its placement on the
/// caller's port timeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundCost {
    /// Critical-path seconds for the compressed packets actually sent.
    pub comm_s: f64,
    /// The canonical dense baseline for the same bucket
    /// ([`plan::dense_bucket_s`]): uncompressed f32 through a single
    /// serialized port. Identical across topologies and exchange modes —
    /// the run-level baseline is [`ReducePlan::dense_round_s`]
    /// (super::plan::ReducePlan::dense_round_s), never a per-topology or
    /// per-granularity quantity.
    pub dense_comm_s: f64,
    /// When the round started: `max(sched.ready_s, sched.port_free_s)`.
    pub start_s: f64,
    /// When the round finished on its port: `start_s + comm_s`. The caller
    /// feeds this back as the port's next `port_free_s`.
    pub end_s: f64,
}

impl RoundCost {
    /// Place a round of `comm_s` seconds on the timeline described by
    /// `sched` — single definition of the start/end arithmetic so every
    /// topology schedules identically.
    fn place(sched: RoundSched, comm_s: f64, dense_comm_s: f64) -> RoundCost {
        let start_s = sched.ready_s.max(sched.port_free_s);
        RoundCost {
            comm_s,
            dense_comm_s,
            start_s,
            end_s: start_s + comm_s,
        }
    }
}

/// The dense per-layer sum of every learner's packet. Allocate once with
/// [`Reduced::new`] and reuse across rounds.
pub struct Reduced {
    /// One dense buffer per layer, layer order.
    pub sums: Vec<Vec<f32>>,
}

impl Reduced {
    pub fn new(layer_lens: &[usize]) -> Reduced {
        Reduced {
            sums: layer_lens.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    fn reset(&mut self, layer_lens: &[usize]) {
        // shape can change between runs (not between steps) — realloc only then
        if self.sums.len() != layer_lens.len()
            || self.sums.iter().zip(layer_lens).any(|(s, &n)| s.len() != n)
        {
            *self = Reduced::new(layer_lens);
            return;
        }
        for s in self.sums.iter_mut() {
            s.fill(0.0);
        }
    }
}

pub trait Topology: Send {
    /// Topology name as parsed (`ps`, `ps:4`, `hier:2`, `ring`).
    fn name(&self) -> &str;

    /// Number of independent fabric ports. Rounds on distinct ports may
    /// overlap on the engine's simulated timeline; rounds on one port
    /// serialize. The reduce plan partitions buckets over `0..ports()`.
    fn ports(&self) -> usize {
        1
    }

    /// One synchronous exchange round for one reduce-plan bucket,
    /// allocation-free in steady state.
    ///
    /// `per_learner[l]` holds learner l's packets for the bucket's layers,
    /// ascending layer order (matching `bucket.layers`). Zeroes the
    /// bucket's slices of `out` and accumulates the dense sums in
    /// learner-id order, records bytes/time on `fabric`, and returns the
    /// round's cost **placed** on the timeline described by `sched` (the
    /// round starts at `max(ready_s, port_free_s)`; the scheduler feeds
    /// `RoundCost::end_s` back as the port's next `port_free_s`). Each
    /// learner's packets travel as **one** bucket-framed message, so
    /// latency is charged once per learner per direction.
    fn exchange_bucket_into(
        &mut self,
        bucket: &Bucket,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        sched: RoundSched,
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost;

    /// One coalesced **whole-model barrier** round: every layer in a single
    /// synthetic bucket (benches/tests; the engine drives real plan buckets
    /// through [`exchange_bucket_into`](Self::exchange_bucket_into)).
    /// `per_learner[l]` holds one packet per layer in layer order.
    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        out.reset(layer_lens);
        let bucket = Bucket::whole_model(layer_lens.len());
        self.exchange_bucket_into(
            &bucket,
            per_learner,
            layer_lens,
            RoundSched::default(),
            fabric,
            out,
        )
    }

    /// Convenience wrapper that allocates a fresh `Reduced` per round
    /// (benches/tests; the engine reuses one).
    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced {
        let mut out = Reduced::new(layer_lens);
        self.exchange_into(per_learner, layer_lens, fabric, &mut out);
        out
    }
}

/// Dense reduce of one bucket in learner-id order — the determinism
/// contract: the float summation order is fixed by learner id regardless of
/// topology, thread schedule, or exchange mode.
fn reduce_bucket_into(bucket: &Bucket, per_learner: &[Vec<Packet>], out: &mut Reduced) {
    for li in bucket.layers.clone() {
        out.sums[li].fill(0.0);
    }
    for packets in per_learner {
        assert_eq!(
            packets.len(),
            bucket.num_layers(),
            "one packet per bucket layer"
        );
        for p in packets {
            debug_assert!(bucket.layers.contains(&p.layer));
            p.add_into(&mut out.sums[p.layer]);
        }
    }
}

/// What dense f32 would have sent in total for this bucket (payload only —
/// feeds `FabricStats::dense_bytes_equiv` / `effective_rate`).
fn dense_payload_equiv(bucket: &Bucket, layer_lens: &[usize], n_learners: usize) -> usize {
    4 * bucket.layers.clone().map(|li| layer_lens[li]).sum::<usize>() * n_learners
}

/// Wire bytes of one learner's bucket-framed upload.
fn bucket_msg_bytes(packets: &[Packet]) -> usize {
    bucket_wire_len(packets.len(), packets.iter().map(|p| p.wire_bytes).sum())
}

/// Reusable bitset scratch for exact sparse-union sizes.
#[derive(Default)]
struct UnionBits {
    bits: Vec<u64>,
}

impl UnionBits {
    fn clear(&mut self, len: usize) -> &mut [u64] {
        let words = len.div_ceil(64);
        if self.bits.len() < words {
            self.bits.resize(words, 0);
        }
        let bits = &mut self.bits[..words];
        bits.fill(0);
        bits
    }

    fn count(&self, len: usize) -> usize {
        self.bits[..len.div_ceil(64)]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }
}

/// Set a packet's indices in `bits`; returns false (dense, union = whole
/// layer) if the packet is dense.
fn set_packet_bits(bits: &mut [u64], p: &Packet) -> bool {
    if p.is_dense() {
        return false;
    }
    for &i in &p.idx {
        bits[(i / 64) as usize] |= 1u64 << (i % 64);
    }
    true
}

/// Per-layer downlink payload for a merged update of `union` of `len`
/// elements: (index u32, value f32) pairs or the dense layer when cheaper,
/// plus the per-layer sub-message header (charged once, outside the min).
fn union_payload(union: usize, len: usize) -> usize {
    (8 * union).min(4 * len) + HEADER_BYTES
}

/// Centralized parameter-server topology, optionally sharded (`ps:<S>`).
///
/// Holds reusable scratch (per-learner byte counts + the sparse-union
/// bitset) so rounds are allocation-free in steady state. The shard count
/// only sets [`ports`](Topology::ports) — each bucket's round runs on its
/// plan-assigned shard with the classic single-port cost; disjoint shards
/// overlap on the engine's timeline.
pub struct ParamServer {
    shards: usize,
    name: String,
    up: Vec<usize>,
    down: Vec<usize>,
    union: UnionBits,
}

impl Default for ParamServer {
    fn default() -> Self {
        ParamServer::sharded(1)
    }
}

impl ParamServer {
    pub fn sharded(shards: usize) -> ParamServer {
        assert!(shards >= 1);
        ParamServer {
            shards,
            name: if shards == 1 {
                "ps".to_string()
            } else {
                format!("ps:{shards}")
            },
            up: Vec::new(),
            down: Vec::new(),
            union: UnionBits::default(),
        }
    }

    /// Exact element count of the server's merged (union) packet for one
    /// layer: duplicates across learners merge; any dense packet forces the
    /// whole layer dense.
    fn union_sent<'p>(&mut self, packets: impl Iterator<Item = &'p Packet>, len: usize) -> usize {
        let bits = self.union.clear(len);
        for p in packets {
            if !set_packet_bits(bits, p) {
                return len;
            }
        }
        self.union.count(len)
    }
}

impl Topology for ParamServer {
    fn name(&self) -> &str {
        &self.name
    }

    fn ports(&self) -> usize {
        self.shards
    }

    fn exchange_bucket_into(
        &mut self,
        bucket: &Bucket,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        sched: RoundSched,
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        let n = per_learner.len();
        self.up.clear();
        self.up.extend(per_learner.iter().map(|ps| bucket_msg_bytes(ps)));
        // The merged update the shard broadcasts: per layer, the exact
        // sparse union of the learners' packets (reusable bitset, not a
        // capped sum) — or the dense layer when that is cheaper — framed as
        // one bucket message.
        let mut down_payload = 0usize;
        for (pos, li) in bucket.layers.clone().enumerate() {
            let len = layer_lens[li];
            let union = self.union_sent(per_learner.iter().map(|ps| &ps[pos]), len);
            down_payload += union_payload(union, len);
        }
        let down_one = bucket_wire_len(bucket.num_layers(), down_payload);
        self.down.clear();
        self.down.resize(n, down_one);

        // Single-port shard: uploads serialize into the shard, downloads
        // serialize out; learners' own links run in parallel.
        let t_up: f64 = self.up.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        let t_down: f64 = self.down.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        fabric.record_round(
            &self.up,
            &self.down,
            t_up + t_down,
            dense_payload_equiv(bucket, layer_lens, n),
        );

        reduce_bucket_into(bucket, per_learner, out);

        RoundCost::place(
            sched,
            t_up + t_down,
            dense_bucket_s(bucket, layer_lens, n, &fabric.link),
        )
    }
}

/// Two-level parameter server (`hier:<G>`): rack-local aggregators of G
/// learners feeding a root.
///
/// Timeline model (two-hop): members serialize into their aggregator
/// (racks in parallel → max over racks), aggregators serialize rack unions
/// into the root, the root serializes the global union back to each
/// aggregator, aggregators broadcast to their members (racks in parallel).
/// Fabric **bytes** are charged at the learner edge only (what each learner
/// sent/received); the aggregator↔root hop shows up in the round *time* —
/// byte totals stay comparable with `ps` at the same compression.
///
/// The numerical reduce stays the canonical flat learner-id-order sum
/// ([`reduce_bucket_into`]): the rack tree shapes the simulated timeline
/// only (DESIGN.md §Topologies, determinism contract).
pub struct HierPs {
    group: usize,
    name: String,
    up: Vec<usize>,
    down: Vec<usize>,
    /// Per-rack downlink payload scratch (rack-union bucket messages).
    rack_payload: Vec<usize>,
    rack_bits: UnionBits,
    global_bits: UnionBits,
}

impl HierPs {
    pub fn new(group: usize) -> HierPs {
        assert!(group >= 2);
        HierPs {
            group,
            name: format!("hier:{group}"),
            up: Vec::new(),
            down: Vec::new(),
            rack_payload: Vec::new(),
            rack_bits: UnionBits::default(),
            global_bits: UnionBits::default(),
        }
    }

    fn racks(&self, n: usize) -> usize {
        n.div_ceil(self.group)
    }
}

impl Topology for HierPs {
    fn name(&self) -> &str {
        &self.name
    }

    fn exchange_bucket_into(
        &mut self,
        bucket: &Bucket,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        sched: RoundSched,
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        let n = per_learner.len();
        let racks = self.racks(n);
        self.up.clear();
        self.up.extend(per_learner.iter().map(|ps| bucket_msg_bytes(ps)));

        // Per layer: rack unions (what each aggregator forwards) and the
        // global union (what the root broadcasts). A dense member packet
        // forces its rack — and therefore the global — union dense.
        self.rack_payload.clear();
        self.rack_payload.resize(racks, 0);
        let mut global_payload = 0usize;
        for (pos, li) in bucket.layers.clone().enumerate() {
            let len = layer_lens[li];
            self.global_bits.clear(len);
            let mut global_dense = false;
            for r in 0..racks {
                let members = (r * self.group)..((r + 1) * self.group).min(n);
                let bits = self.rack_bits.clear(len);
                let mut rack_dense = false;
                for l in members {
                    if !set_packet_bits(bits, &per_learner[l][pos]) {
                        rack_dense = true;
                    }
                }
                let rack_union = if rack_dense {
                    global_dense = true;
                    len
                } else {
                    self.rack_bits.count(len)
                };
                self.rack_payload[r] += union_payload(rack_union, len);
                let gbits = &mut self.global_bits.bits[..len.div_ceil(64)];
                for (g, w) in gbits.iter_mut().zip(self.rack_bits.bits.iter()) {
                    *g |= *w;
                }
            }
            let global_union = if global_dense {
                len
            } else {
                self.global_bits.count(len)
            };
            global_payload += union_payload(global_union, len);
        }
        let k = bucket.num_layers();
        let global_msg = bucket_wire_len(k, global_payload);
        self.down.clear();
        self.down.resize(n, global_msg);

        // Hop 1 up: members serialize into their aggregator, racks parallel.
        let mut t_rack_up = 0.0f64;
        // Hop 2 down: aggregators broadcast the global union, racks parallel.
        let mut t_rack_down = 0.0f64;
        for r in 0..racks {
            let members = (r * self.group)..((r + 1) * self.group).min(n);
            let m = members.len();
            let t_up: f64 = members.map(|l| fabric.link.transfer_time(self.up[l])).sum();
            t_rack_up = t_rack_up.max(t_up);
            t_rack_down = t_rack_down.max(m as f64 * fabric.link.transfer_time(global_msg));
        }
        // Root: rack unions serialize in, global unions serialize out.
        let t_root_in: f64 = self
            .rack_payload
            .iter()
            .map(|&p| fabric.link.transfer_time(bucket_wire_len(k, p)))
            .sum();
        let t_root_out = racks as f64 * fabric.link.transfer_time(global_msg);
        let t = t_rack_up + t_root_in + t_root_out + t_rack_down;

        fabric.record_round(
            &self.up,
            &self.down,
            t,
            dense_payload_equiv(bucket, layer_lens, n),
        );

        reduce_bucket_into(bucket, per_learner, out);

        RoundCost::place(sched, t, dense_bucket_s(bucket, layer_lens, n, &fabric.link))
    }
}

/// Ring all-gather of compressed bucket messages.
#[derive(Default)]
pub struct Ring {
    own: Vec<usize>,
    up: Vec<usize>,
    down: Vec<usize>,
}

impl Ring {
    /// All-gather byte/time accounting for one message per learner of
    /// `self.own[l]` bytes: every message traverses n-1 hops; all links are
    /// busy in parallel, so hop time = latency + max message / bandwidth.
    /// Fills `self.up`/`self.down` and returns the critical-path seconds.
    fn all_gather(&mut self, fabric: &Fabric) -> f64 {
        let n = self.own.len();
        self.up.clear();
        self.up.resize(n, 0);
        self.down.clear();
        self.down.resize(n, 0);
        let mut time = 0.0f64;
        if n > 1 {
            for hop in 0..n - 1 {
                let mut hop_max = 0usize;
                for l in 0..n {
                    let src = (l + n - hop) % n;
                    self.up[l] += self.own[src];
                    self.down[(l + 1) % n] += self.own[src];
                    hop_max = hop_max.max(self.own[src]);
                }
                time += fabric.link.transfer_time(hop_max);
            }
        }
        time
    }
}

impl Topology for Ring {
    fn name(&self) -> &str {
        "ring"
    }

    fn exchange_bucket_into(
        &mut self,
        bucket: &Bucket,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        sched: RoundSched,
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        let n = per_learner.len();
        self.own.clear();
        self.own.extend(per_learner.iter().map(|ps| bucket_msg_bytes(ps)));
        let time = self.all_gather(fabric);
        fabric.record_round(
            &self.up,
            &self.down,
            time,
            dense_payload_equiv(bucket, layer_lens, n),
        );
        reduce_bucket_into(bucket, per_learner, out);

        RoundCost::place(sched, time, dense_bucket_s(bucket, layer_lens, n, &fabric.link))
    }
}

/// Validate a topology spec against a learner count without constructing
/// it. Unknown names and out-of-bound `ps:<S>` / `hier:<G>` parameters
/// error with the valid-form list. [`build`] routes through this at
/// startup; the elastic-fleet rebuild calls it again on every membership
/// change, because a spec that was valid at the initial learner count can
/// stop being valid after the fleet shrinks (see [`fallback`]).
pub fn revalidate(name: &str, n_learners: usize) -> anyhow::Result<()> {
    if let Some(s) = name.strip_prefix("ps:") {
        let shards: usize = s.parse().map_err(|_| {
            anyhow::anyhow!("topology '{name}': '{s}' is not a shard count ({VALID})")
        })?;
        if shards < 1 || shards > n_learners {
            bail!(
                "topology '{name}': shard count must satisfy 1 <= S <= learner count \
                 ({n_learners}) ({VALID})"
            );
        }
        return Ok(());
    }
    if let Some(g) = name.strip_prefix("hier:") {
        let group: usize = g.parse().map_err(|_| {
            anyhow::anyhow!("topology '{name}': '{g}' is not a group size ({VALID})")
        })?;
        if group < 2 || group > n_learners {
            bail!(
                "topology '{name}': group size must satisfy 2 <= G <= learner count \
                 ({n_learners}) ({VALID})"
            );
        }
        return Ok(());
    }
    match name {
        "ps" | "param_server" | "ring" => Ok(()),
        other => bail!("unknown topology '{other}' ({VALID})"),
    }
}

/// Degrade a topology spec to one valid at `n_learners`, for the
/// elastic-fleet rebuild: aborting a run because `ps:4` lost its fourth
/// learner would turn every shrink event into a crash. `ps:<S>` with S
/// beyond the fleet shrinks to `ps:<n>`; `hier:<G>` shrinks its group to
/// the fleet while racks of >= 2 still form, else flattens to `ps`.
/// Returns the spec unchanged while it is still valid — so a later `join`
/// that restores the learner count restores the requested topology too.
pub fn fallback(name: &str, n_learners: usize) -> String {
    if revalidate(name, n_learners).is_ok() {
        return name.to_string();
    }
    if name.starts_with("ps:") {
        return format!("ps:{}", n_learners.max(1));
    }
    if name.starts_with("hier:") {
        if n_learners >= 2 {
            return format!("hier:{n_learners}");
        }
        return "ps".to_string();
    }
    // ring/ps have no parameters to outgrow; anything else was rejected at
    // startup by revalidate
    name.to_string()
}

/// Parse a topology spec; unknown names or invalid parameters error with
/// the valid-form list. `n_learners` bounds the `ps:<S>` shard count and
/// `hier:<G>` group size — a plan that shards wider than the learner count
/// is a config typo, not a topology.
pub fn build(name: &str, n_learners: usize) -> anyhow::Result<Box<dyn Topology>> {
    revalidate(name, n_learners)?;
    if let Some(s) = name.strip_prefix("ps:") {
        return Ok(Box::new(ParamServer::sharded(s.parse().expect("revalidated"))));
    }
    if let Some(g) = name.strip_prefix("hier:") {
        return Ok(Box::new(HierPs::new(g.parse().expect("revalidated"))));
    }
    match name {
        "ps" | "param_server" => Ok(Box::new(ParamServer::default())),
        "ring" => Ok(Box::new(Ring::default())),
        other => unreachable!("revalidate accepted unknown topology '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::LinkModel;
    use crate::comm::plan::ReducePlan;
    use crate::models::{LayerKind, Layout};

    fn sparse(layer: usize, n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
        let wire = 16 + 2 * idx.len();
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes: wire,
            paper_bits: 0,
        }
    }

    fn learners() -> (Vec<Vec<Packet>>, Vec<usize>) {
        let l0 = vec![sparse(0, 6, vec![0, 3], vec![1.0, -1.0])];
        let l1 = vec![sparse(0, 6, vec![0, 5], vec![0.5, 2.0])];
        (vec![l0, l1], vec![6])
    }

    /// 3-layer fixture with 4 learners for plan-driven bucket tests.
    fn bucketed() -> (Layout, Vec<Vec<Packet>>) {
        let layout = Layout::from_specs(&[
            ("w", &[40], LayerKind::Fc),
            ("b", &[8], LayerKind::Fc),
            ("head", &[12], LayerKind::Fc),
        ]);
        let per_learner = (0..4usize)
            .map(|l| {
                vec![
                    sparse(0, 40, vec![l as u32, 10 + l as u32], vec![1.0, -1.0]),
                    sparse(1, 8, vec![l as u32], vec![0.5]),
                    sparse(2, 12, vec![2 * l as u32], vec![2.0]),
                ]
            })
            .collect();
        (layout, per_learner)
    }

    /// Every buildable topology spec at 4 learners.
    const TOPOS4: &[&str] = &["ring", "ps", "ps:2", "ps:4", "hier:2", "hier:4"];

    #[test]
    fn all_topologies_same_sums() {
        let (pk, lens) = learners();
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for name in ["ring", "ps", "ps:2", "hier:2"] {
            let mut f = Fabric::new(LinkModel::default());
            let r = build(name, 2).unwrap().exchange(&pk, &lens, &mut f);
            assert_eq!(r.sums[0], vec![1.5, 0.0, 0.0, -1.0, 0.0, 2.0], "{name}");
            if let Some(expect) = &reference {
                assert_eq!(&r.sums, expect, "{name}");
            } else {
                reference = Some(r.sums);
            }
        }
    }

    #[test]
    fn exchange_into_reuses_buffers() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        let mut topo = Ring::default();
        let mut red = Reduced::new(&lens);
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        let first = red.sums[0].clone();
        // a second round must zero the buffer, not accumulate on top of it
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        assert_eq!(red.sums[0], first);
        assert_eq!(f.stats.rounds, 2);
    }

    #[test]
    fn bucket_exchange_matches_barrier_sums() {
        // exchanging plan buckets one by one must produce bit-identical sums
        // to the coalesced whole-model round, for every topology
        let (layout, pk) = bucketed();
        let lens = layout.layer_lens();
        // threshold 100: head (64B) + b (48B) coalesce, w (176B) alone
        let plan = ReducePlan::build(&layout, 100, 2);
        assert_eq!(plan.num_buckets(), 2);
        for name in TOPOS4 {
            let mut fa = Fabric::new(LinkModel::default());
            let mut fb = Fabric::new(LinkModel::default());
            let mut topo_a = build(name, 4).unwrap();
            let mut topo_b = build(name, 4).unwrap();
            let barrier = topo_a.exchange(&pk, &lens, &mut fa);
            let mut out = Reduced::new(&lens);
            // poison: the bucket exchange must zero its layers
            for s in out.sums.iter_mut() {
                s.fill(7.0);
            }
            for bucket in &plan.buckets {
                let gather: Vec<Vec<Packet>> = pk
                    .iter()
                    .map(|ps| bucket.layers.clone().map(|li| ps[li].clone()).collect())
                    .collect();
                let cost = topo_b.exchange_bucket_into(
                    bucket,
                    &gather,
                    &lens,
                    RoundSched::default(),
                    &mut fb,
                    &mut out,
                );
                assert!(cost.comm_s > 0.0, "{name}");
            }
            assert_eq!(out.sums, barrier.sums, "{name}");
            assert_eq!(fa.stats.dense_bytes_equiv, fb.stats.dense_bytes_equiv, "{name}");
        }
    }

    #[test]
    fn dense_baseline_is_topology_independent() {
        // satellite: RoundCost::dense_comm_s must be the canonical
        // per-bucket baseline — identical for every topology — and the
        // run-level plan baseline must be the whole-model coalesced round
        // (independent of the bucket structure)
        let (layout, pk) = bucketed();
        let lens = layout.layer_lens();
        let plan = ReducePlan::build(&layout, 100, 2);
        let link = LinkModel::default();
        let mut dense_totals = Vec::new();
        for name in TOPOS4 {
            let mut f = Fabric::new(LinkModel::default());
            let mut topo = build(name, 4).unwrap();
            let mut out = Reduced::new(&lens);
            let mut total = 0.0f64;
            for bucket in &plan.buckets {
                let gather: Vec<Vec<Packet>> = pk
                    .iter()
                    .map(|ps| bucket.layers.clone().map(|li| ps[li].clone()).collect())
                    .collect();
                total += topo
                    .exchange_bucket_into(
                        bucket,
                        &gather,
                        &lens,
                        RoundSched::default(),
                        &mut f,
                        &mut out,
                    )
                    .dense_comm_s;
            }
            dense_totals.push(total);
        }
        let expect: f64 = plan
            .buckets
            .iter()
            .map(|b| dense_bucket_s(b, &lens, 4, &link))
            .sum();
        for (name, &t) in TOPOS4.iter().zip(dense_totals.iter()) {
            assert!((t - expect).abs() < 1e-15, "{name}: {t} vs {expect}");
        }
        // the run-level baseline the engine divides by is the whole-model
        // coalesced round — same for any plan over this layout
        let whole = dense_bucket_s(&Bucket::whole_model(lens.len()), &lens, 4, &link);
        assert!((plan.dense_round_s(&lens, 4, &link) - whole).abs() < 1e-18);
        let finer = ReducePlan::build(&layout, 1, 2);
        assert!((finer.dense_round_s(&lens, 4, &link) - whole).abs() < 1e-18);
    }

    #[test]
    fn round_placement_honors_ready_and_port_times() {
        // RoundSched inputs (the bounded-staleness scheduler's contract):
        // a round starts at max(ready, port_free) and ends start + comm —
        // identically for every topology.
        let (pk, lens) = learners();
        let bucket = Bucket::whole_model(lens.len());
        for name in ["ring", "ps", "hier:2"] {
            let mut f = Fabric::new(LinkModel::default());
            let mut topo = build(name, 2).unwrap();
            let mut out = Reduced::new(&lens);
            // ready after the port went idle: the round starts at ready
            let c = topo.exchange_bucket_into(
                &bucket,
                &pk,
                &lens,
                RoundSched { ready_s: 2.0, port_free_s: 1.0 },
                &mut f,
                &mut out,
            );
            assert!((c.start_s - 2.0).abs() < 1e-15, "{name}");
            assert!((c.end_s - (2.0 + c.comm_s)).abs() < 1e-15, "{name}");
            // port still busy past the ready stamp: the round queues
            let c2 = topo.exchange_bucket_into(
                &bucket,
                &pk,
                &lens,
                RoundSched { ready_s: 2.5, port_free_s: c.end_s },
                &mut f,
                &mut out,
            );
            assert!((c2.start_s - c.end_s.max(2.5)).abs() < 1e-15, "{name}");
            // the default sched is the placement-free origin
            let c3 = topo.exchange_into(&pk, &lens, &mut f, &mut out);
            assert_eq!(c3.start_s, 0.0, "{name}");
            assert!((c3.end_s - c3.comm_s).abs() < 1e-15, "{name}");
        }
    }

    #[test]
    fn ports_reflect_shards() {
        assert_eq!(build("ps", 4).unwrap().ports(), 1);
        assert_eq!(build("ps:4", 4).unwrap().ports(), 4);
        assert_eq!(build("ps:2", 4).unwrap().ports(), 2);
        assert_eq!(build("ring", 4).unwrap().ports(), 1);
        assert_eq!(build("hier:2", 4).unwrap().ports(), 1);
    }

    #[test]
    fn sharded_ps_round_cost_matches_single_shard() {
        // a single bucket's round is the same single-port cost at any shard
        // count — the sharding win is overlap across ports, not a cheaper
        // round (the engine's per-port timeline claims it)
        let (pk, lens) = learners();
        let mut f1 = Fabric::new(LinkModel::default());
        let mut f2 = Fabric::new(LinkModel::default());
        let c1 = build("ps", 2)
            .unwrap()
            .exchange_into(&pk, &lens, &mut f1, &mut Reduced::new(&lens));
        let c2 = build("ps:2", 2)
            .unwrap()
            .exchange_into(&pk, &lens, &mut f2, &mut Reduced::new(&lens));
        assert!((c1.comm_s - c2.comm_s).abs() < 1e-18);
        assert_eq!(f1.stats.bytes_up, f2.stats.bytes_up);
        assert_eq!(f1.stats.bytes_down, f2.stats.bytes_down);
    }

    #[test]
    fn hier_root_fan_in_beats_flat_ps_at_scale() {
        // 16 learners in racks of 4: the root serializes 4 rack messages
        // instead of 16 learner messages — on a latency-dominated round the
        // two-hop tree must beat the flat single-port server; with one rack
        // (G = N) the extra hop must cost strictly more than flat ps
        let n = 16usize;
        let lens = vec![64usize];
        let pk: Vec<Vec<Packet>> = (0..n)
            .map(|l| vec![sparse(0, 64, vec![l as u32], vec![1.0])])
            .collect();
        let cost = |name: &str| {
            let mut f = Fabric::new(LinkModel::default());
            build(name, n)
                .unwrap()
                .exchange_into(&pk, &lens, &mut f, &mut Reduced::new(&lens))
                .comm_s
        };
        assert!(cost("hier:4") < cost("ps"), "two-hop tree must win at 16 learners");
        assert!(cost("hier:16") > cost("ps"), "one rack = flat ps plus two extra hops");
    }

    #[test]
    fn ring_bytes_scale_with_n_minus_1() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        Ring::default().exchange(&pk, &lens, &mut f);
        // each learner's 20-byte packet rides a 32-byte bucket frame
        // (8 header + 4 length prefix) and travels n-1 = 1 hop
        assert_eq!(f.stats.bytes_up, 2 * 32);
        assert_eq!(f.stats.rounds, 1);
    }

    #[test]
    fn ps_charges_upload_plus_broadcast() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        assert_eq!(f.stats.bytes_up, 2 * 32);
        assert!(f.stats.bytes_down > 0);
        assert!(f.stats.sim_time_s > 0.0);
    }

    #[test]
    fn ps_broadcast_uses_exact_sparse_union() {
        // learners overlap on index 0: union = {0, 3, 5} = 3 elements, not 4.
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        let payload = (8 * 3usize).min(4 * 6) + HEADER_BYTES;
        let expect_down_one = bucket_wire_len(1, payload);
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn ps_dense_packet_forces_dense_union() {
        let l0 = vec![Packet::dense(0, vec![1.0; 6])];
        let l1 = vec![sparse(0, 6, vec![2], vec![1.0])];
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&[l0, l1], &[6], &mut f);
        // dense fallback (4 bytes/elem beats 8) + one sub-header, framed
        let expect_down_one = bucket_wire_len(1, 4 * 6 + HEADER_BYTES);
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn hier_learner_edge_bytes_match_ps() {
        // hier charges learner-edge bytes only (aggregator<->root traffic is
        // time, not learner bytes): byte totals must equal flat ps
        let (layout, pk) = bucketed();
        let lens = layout.layer_lens();
        let mut fp = Fabric::new(LinkModel::default());
        let mut fh = Fabric::new(LinkModel::default());
        build("ps", 4).unwrap().exchange(&pk, &lens, &mut fp);
        build("hier:2", 4).unwrap().exchange(&pk, &lens, &mut fh);
        assert_eq!(fp.stats.bytes_up, fh.stats.bytes_up);
        assert_eq!(fp.stats.bytes_down, fh.stats.bytes_down);
    }

    #[test]
    fn barrier_cost_reports_dense_baseline() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        let cost = Ring::default().exchange_into(&pk, &lens, &mut f, &mut Reduced::new(&lens));
        assert!((cost.comm_s - f.stats.sim_time_s).abs() < 1e-15);
        // tiny sparse packets: dense must cost strictly more
        assert!(cost.dense_comm_s > cost.comm_s);
    }

    #[test]
    fn single_learner_ring_is_free() {
        let pk = vec![vec![sparse(0, 4, vec![1], vec![1.0])]];
        let mut f = Fabric::new(LinkModel::default());
        let r = Ring::default().exchange(&pk, &[4], &mut f);
        assert_eq!(f.stats.bytes_up, 0);
        assert_eq!(r.sums[0], vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn build_by_name() {
        assert!(build("ps", 1).is_ok());
        assert!(build("ring", 1).is_ok());
        assert_eq!(build("param_server", 1).unwrap().name(), "ps");
        assert_eq!(build("ps:4", 8).unwrap().name(), "ps:4");
        assert_eq!(build("hier:2", 8).unwrap().name(), "hier:2");
        let err = build("mesh", 1).unwrap_err().to_string();
        assert!(err.contains("ring") && err.contains("ps") && err.contains("hier"), "{err}");
    }

    #[test]
    fn build_validates_shard_and_group_params() {
        // satellite: fail fast, valid-form list in every error
        for (spec, n) in [
            ("ps:0", 4),    // S < 1
            ("ps:8", 4),    // S > learners
            ("ps:x", 4),    // not an integer
            ("ps:", 4),     // empty
            ("hier:1", 4),  // G < 2
            ("hier:8", 4),  // G > learners
            ("hier:two", 4),
        ] {
            let err = build(spec, n).unwrap_err().to_string();
            assert!(
                err.contains("valid: ring, ps, ps:<S>") && err.contains("hier:<G>"),
                "{spec}: {err}"
            );
        }
        // boundary cases that must pass
        assert!(build("ps:1", 1).is_ok());
        assert!(build("ps:4", 4).is_ok());
        assert!(build("hier:2", 2).is_ok());
        assert!(build("hier:4", 4).is_ok());
    }

    #[test]
    fn revalidate_matches_build_and_carries_valid_forms() {
        // satellite: the churn rebuild re-checks specs against the *new*
        // learner count through the same validation build uses — the two
        // must agree, and the error text must keep the valid-form list
        for (spec, n) in [
            ("ring", 1), ("ring", 8), ("ps", 1), ("ps:2", 4), ("ps:4", 4),
            ("hier:2", 4), ("hier:4", 4), ("param_server", 3),
        ] {
            assert!(revalidate(spec, n).is_ok(), "{spec}@{n}");
            assert!(build(spec, n).is_ok(), "{spec}@{n}");
        }
        for (spec, n) in [
            ("ps:0", 4), ("ps:8", 4), ("ps:x", 4), ("hier:1", 4),
            ("hier:8", 4), ("mesh", 4),
        ] {
            let err = revalidate(spec, n).unwrap_err().to_string();
            assert!(
                err.contains("valid: ring, ps, ps:<S>") && err.contains("hier:<G>"),
                "{spec}: {err}"
            );
            assert!(build(spec, n).is_err(), "{spec}@{n}");
        }
        // the same spec flips validity as the fleet shrinks — the churn case
        assert!(revalidate("ps:4", 4).is_ok());
        assert!(revalidate("ps:4", 3).is_err());
    }

    #[test]
    fn fallback_degrades_instead_of_aborting() {
        // still-valid specs pass through unchanged (a re-grown fleet gets
        // its requested topology back)
        for (spec, n) in [("ring", 1), ("ps", 1), ("ps:4", 4), ("hier:2", 4)] {
            assert_eq!(fallback(spec, n), spec);
        }
        // ps:S shrinks with the fleet
        assert_eq!(fallback("ps:4", 3), "ps:3");
        assert_eq!(fallback("ps:4", 1), "ps:1");
        // hier:G shrinks its group while racks still form, else flattens
        assert_eq!(fallback("hier:4", 3), "hier:3");
        assert_eq!(fallback("hier:4", 2), "hier:2");
        assert_eq!(fallback("hier:2", 1), "ps");
        // every fallback result must actually build at that learner count
        for (spec, n) in [("ps:4", 3), ("ps:4", 1), ("hier:4", 3), ("hier:2", 1)] {
            assert!(build(&fallback(spec, n), n).is_ok(), "{spec}@{n}");
        }
    }
}
