//! Exchange topologies over compressed gradient packets.
//!
//! Both topologies implement the same *semantics* — every learner ends the
//! round holding the elementwise **sum** of all learners' packets (synchronous
//! SGD with identical weights, as in the paper) — but charge the fabric
//! differently:
//!
//! * `ParamServer`: learners push packets up (their wire bytes); the server
//!   reduces and broadcasts the merged *sparse union* back down. Round time =
//!   max(upload) + max(download) with the server's in/out links serialized
//!   across learners (single-port model).
//! * `Ring`: all-gather of compressed packets around the ring (the
//!   paper-cited NCCL-style ring, Luehr'16). Each learner forwards every
//!   other learner's packet once: N-1 hops, per-hop time = latency + max
//!   chunk / bandwidth; all links run in parallel.
//!
//! Packets stay compressed end-to-end (this is the point of the paper:
//! reduction of *sparse ternary* vectors), and the reduce is a dense
//! accumulate into a reusable buffer.
//!
//! Hot-path contract (see DESIGN.md §Threading): `exchange_into` reuses the
//! caller's [`Reduced`] buffers and each topology's internal scratch, so a
//! steady-state exchange performs **zero heap allocation** (pinned by
//! rust/tests/alloc_free.rs). Packets are reduced in learner-id order — the
//! float summation order is part of the engine's determinism contract.

use super::fabric::Fabric;
use crate::compress::wire::HEADER_BYTES;
use crate::compress::Packet;

/// The dense per-layer sum of every learner's packet. Allocate once with
/// [`Reduced::new`] and reuse across rounds via `exchange_into`.
pub struct Reduced {
    /// One dense buffer per layer, layer order.
    pub sums: Vec<Vec<f32>>,
}

impl Reduced {
    pub fn new(layer_lens: &[usize]) -> Reduced {
        Reduced {
            sums: layer_lens.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    fn reset(&mut self, layer_lens: &[usize]) {
        // shape can change between runs (not between steps) — realloc only then
        if self.sums.len() != layer_lens.len()
            || self.sums.iter().zip(layer_lens).any(|(s, &n)| s.len() != n)
        {
            *self = Reduced::new(layer_lens);
            return;
        }
        for s in self.sums.iter_mut() {
            s.fill(0.0);
        }
    }
}

pub trait Topology: Send {
    fn name(&self) -> &'static str;

    /// One synchronous exchange round, allocation-free in steady state.
    ///
    /// `per_learner[l]` holds learner l's packets, one per layer, in layer
    /// order. `layer_lens` gives each layer's dense length. Zeroes `out` and
    /// accumulates the per-layer dense sums into it (learner-id order), and
    /// records bytes/time on `fabric`.
    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    );

    /// Convenience wrapper that allocates a fresh `Reduced` per round
    /// (benches/tests; the engine uses `exchange_into`).
    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced {
        let mut out = Reduced::new(layer_lens);
        self.exchange_into(per_learner, layer_lens, fabric, &mut out);
        out
    }
}

/// Dense reduce in learner-id order (the determinism contract: float
/// summation order is fixed regardless of how learners were scheduled).
fn reduce_into(per_learner: &[Vec<Packet>], layer_lens: &[usize], out: &mut Reduced) {
    out.reset(layer_lens);
    for packets in per_learner {
        assert_eq!(packets.len(), layer_lens.len(), "one packet per layer");
        for p in packets {
            p.add_into(&mut out.sums[p.layer]);
        }
    }
}

fn dense_equiv(layer_lens: &[usize], n_learners: usize) -> usize {
    4 * layer_lens.iter().sum::<usize>() * n_learners
}

/// Centralized parameter-server topology.
///
/// Holds reusable scratch (per-learner byte counts + the sparse-union
/// bitset) so rounds are allocation-free in steady state.
#[derive(Default)]
pub struct ParamServer {
    up: Vec<usize>,
    down: Vec<usize>,
    /// Reusable bitset words for the per-layer sparse-union size.
    union_bits: Vec<u64>,
}

impl ParamServer {
    /// Exact element count of the server's merged (union) packet for one
    /// layer: duplicates across learners merge. Any dense packet forces the
    /// whole layer dense.
    fn union_sent(&mut self, per_learner: &[Vec<Packet>], layer: usize, len: usize) -> usize {
        let words = len.div_ceil(64);
        if self.union_bits.len() < words {
            self.union_bits.resize(words, 0);
        }
        let bits = &mut self.union_bits[..words];
        bits.fill(0);
        for packets in per_learner {
            let p = &packets[layer];
            if p.is_dense() {
                return len;
            }
            for &i in &p.idx {
                bits[(i / 64) as usize] |= 1u64 << (i % 64);
            }
        }
        bits.iter().map(|w| w.count_ones() as usize).sum()
    }
}

impl Topology for ParamServer {
    fn name(&self) -> &'static str {
        "ps"
    }

    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) {
        let n = per_learner.len();
        self.up.clear();
        self.up.extend(
            per_learner
                .iter()
                .map(|ps| ps.iter().map(|p| p.wire_bytes).sum::<usize>()),
        );
        // The merged update the server broadcasts: the exact sparse union of
        // the learners' packets (a reusable bitset, not a capped sum), as
        // (index u32, value f32) pairs — or the dense layer when that is
        // cheaper. The header is charged once per layer, outside the min.
        let mut down_one = 0usize;
        for (layer, &len) in layer_lens.iter().enumerate() {
            let union = self.union_sent(per_learner, layer, len);
            down_one += (8 * union).min(4 * len) + HEADER_BYTES;
        }
        self.down.clear();
        self.down.resize(n, down_one);

        // Single-port server: uploads serialize into the server, downloads
        // serialize out; learners' own links run in parallel.
        let t_up: f64 = self.up.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        let t_down: f64 = self.down.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        fabric.record_round(&self.up, &self.down, t_up + t_down, dense_equiv(layer_lens, n));

        reduce_into(per_learner, layer_lens, out);
    }
}

/// Ring all-gather of compressed packets.
#[derive(Default)]
pub struct Ring {
    own: Vec<usize>,
    up: Vec<usize>,
    down: Vec<usize>,
}

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) {
        let n = per_learner.len();
        self.own.clear();
        self.own.extend(
            per_learner
                .iter()
                .map(|ps| ps.iter().map(|p| p.wire_bytes).sum::<usize>()),
        );
        // Every packet traverses n-1 hops: learner l transmits, per hop, the
        // packet originated by (l - hop); all links are busy in parallel, so
        // hop time = latency + max packet / bandwidth.
        self.up.clear();
        self.up.resize(n, 0);
        self.down.clear();
        self.down.resize(n, 0);
        let mut time = 0.0f64;
        if n > 1 {
            for hop in 0..n - 1 {
                let mut hop_max = 0usize;
                for l in 0..n {
                    let src = (l + n - hop) % n;
                    self.up[l] += self.own[src];
                    self.down[(l + 1) % n] += self.own[src];
                    hop_max = hop_max.max(self.own[src]);
                }
                time += fabric.link.transfer_time(hop_max);
            }
        }
        fabric.record_round(&self.up, &self.down, time, dense_equiv(layer_lens, n));
        reduce_into(per_learner, layer_lens, out);
    }
}

/// Parse a topology by name.
pub fn build(name: &str) -> Option<Box<dyn Topology>> {
    match name {
        "ps" | "param_server" => Some(Box::new(ParamServer::default())),
        "ring" => Some(Box::new(Ring::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::LinkModel;

    fn sparse(layer: usize, n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
        let wire = 16 + 2 * idx.len();
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes: wire,
            paper_bits: 0,
        }
    }

    fn learners() -> (Vec<Vec<Packet>>, Vec<usize>) {
        let l0 = vec![sparse(0, 6, vec![0, 3], vec![1.0, -1.0])];
        let l1 = vec![sparse(0, 6, vec![0, 5], vec![0.5, 2.0])];
        (vec![l0, l1], vec![6])
    }

    #[test]
    fn ps_and_ring_same_sums() {
        let (pk, lens) = learners();
        let mut f1 = Fabric::new(LinkModel::default());
        let mut f2 = Fabric::new(LinkModel::default());
        let a = ParamServer::default().exchange(&pk, &lens, &mut f1);
        let b = Ring::default().exchange(&pk, &lens, &mut f2);
        assert_eq!(a.sums, b.sums);
        assert_eq!(a.sums[0], vec![1.5, 0.0, 0.0, -1.0, 0.0, 2.0]);
    }

    #[test]
    fn exchange_into_reuses_buffers() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        let mut topo = Ring::default();
        let mut red = Reduced::new(&lens);
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        let first = red.sums[0].clone();
        // a second round must zero the buffer, not accumulate on top of it
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        assert_eq!(red.sums[0], first);
        assert_eq!(f.stats.rounds, 2);
    }

    #[test]
    fn ring_bytes_scale_with_n_minus_1() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        Ring::default().exchange(&pk, &lens, &mut f);
        // each learner's 20-byte packet travels n-1 = 1 hop
        assert_eq!(f.stats.bytes_up, 40);
        assert_eq!(f.stats.rounds, 1);
    }

    #[test]
    fn ps_charges_upload_plus_broadcast() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        assert_eq!(f.stats.bytes_up, 40);
        assert!(f.stats.bytes_down > 0);
        assert!(f.stats.sim_time_s > 0.0);
    }

    #[test]
    fn ps_broadcast_uses_exact_sparse_union() {
        // learners overlap on index 0: union = {0, 3, 5} = 3 elements, not 4.
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        let expect_down_one = (8 * 3).min(4 * 6) + crate::compress::wire::HEADER_BYTES;
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn ps_dense_packet_forces_dense_union() {
        let l0 = vec![Packet::dense(0, vec![1.0; 6])];
        let l1 = vec![sparse(0, 6, vec![2], vec![1.0])];
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&[l0, l1], &[6], &mut f);
        // dense fallback (4 bytes/elem beats 8) + one header, per learner
        let expect_down_one = 4 * 6 + crate::compress::wire::HEADER_BYTES;
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn single_learner_ring_is_free() {
        let pk = vec![vec![sparse(0, 4, vec![1], vec![1.0])]];
        let mut f = Fabric::new(LinkModel::default());
        let r = Ring::default().exchange(&pk, &[4], &mut f);
        assert_eq!(f.stats.bytes_up, 0);
        assert_eq!(r.sums[0], vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn build_by_name() {
        assert!(build("ps").is_some());
        assert!(build("ring").is_some());
        assert!(build("mesh").is_none());
    }
}
