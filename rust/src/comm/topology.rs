//! Exchange topologies over compressed gradient packets.
//!
//! Both topologies implement the same *semantics* — every learner ends the
//! round holding the elementwise **sum** of all learners' packets (synchronous
//! SGD with identical weights, as in the paper) — but charge the fabric
//! differently:
//!
//! * `ParamServer`: learners push packets up (their wire bytes); the server
//!   reduces and broadcasts the merged *sparse union* back down. Round time =
//!   max(upload) + max(download) with the server's in/out links serialized
//!   across learners (single-port model).
//! * `Ring`: all-gather of compressed packets around the ring (the
//!   paper-cited NCCL-style ring, Luehr'16). Each learner forwards every
//!   other learner's packet once: N-1 hops, per-hop time = latency + max
//!   chunk / bandwidth; all links run in parallel.
//!
//! Packets stay compressed end-to-end (this is the point of the paper:
//! reduction of *sparse ternary* vectors), and the reduce is a dense
//! accumulate into a reusable buffer.

use super::fabric::Fabric;
use crate::compress::Packet;

/// The dense per-layer sum of every learner's packet.
pub struct Reduced {
    /// One dense buffer per layer, layer order.
    pub sums: Vec<Vec<f32>>,
}

pub trait Topology: Send {
    fn name(&self) -> &'static str;

    /// One synchronous exchange round.
    ///
    /// `per_learner[l]` holds learner l's packets, one per layer, in layer
    /// order. `layer_lens` gives each layer's dense length. Returns the
    /// per-layer dense sums and records bytes/time on `fabric`.
    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced;
}

fn reduce_dense(per_learner: &[Vec<Packet>], layer_lens: &[usize]) -> Reduced {
    let mut sums: Vec<Vec<f32>> = layer_lens.iter().map(|&n| vec![0.0; n]).collect();
    for packets in per_learner {
        assert_eq!(packets.len(), layer_lens.len(), "one packet per layer");
        for p in packets {
            p.add_into(&mut sums[p.layer]);
        }
    }
    Reduced { sums }
}

fn dense_equiv(layer_lens: &[usize], n_learners: usize) -> usize {
    4 * layer_lens.iter().sum::<usize>() * n_learners
}

/// Centralized parameter-server topology.
pub struct ParamServer;

impl Topology for ParamServer {
    fn name(&self) -> &'static str {
        "ps"
    }

    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced {
        let n = per_learner.len();
        let up: Vec<usize> = per_learner
            .iter()
            .map(|ps| ps.iter().map(|p| p.wire_bytes).sum())
            .collect();
        // The merged update the server broadcasts: the union of sparse
        // packets. Upper-bounded by the sum of packet payloads (duplicates
        // merge); we charge the union size per layer.
        let mut down_one = 0usize;
        for layer in 0..layer_lens.len() {
            let mut total_sent: usize = per_learner.iter().map(|ps| ps[layer].sent()).sum();
            total_sent = total_sent.min(layer_lens[layer]);
            // merged packet: sent elements as (index u32, value f32) + header
            let dense_cost = 4 * layer_lens[layer];
            down_one += (8 * total_sent + super::super::compress::wire::HEADER_BYTES).min(dense_cost + super::super::compress::wire::HEADER_BYTES);
        }
        let down = vec![down_one; n];

        // Single-port server: uploads serialize into the server, downloads
        // serialize out; learners' own links run in parallel.
        let t_up: f64 = up.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        let t_down: f64 = down.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        fabric.record_round(&up, &down, t_up + t_down, dense_equiv(layer_lens, n));

        reduce_dense(per_learner, layer_lens)
    }
}

/// Ring all-gather of compressed packets.
pub struct Ring;

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced {
        let n = per_learner.len();
        let own: Vec<usize> = per_learner
            .iter()
            .map(|ps| ps.iter().map(|p| p.wire_bytes).sum())
            .collect();
        // Every packet traverses n-1 hops: learner l transmits, per hop, the
        // packet originated by (l - hop); all links are busy in parallel, so
        // hop time = latency + max packet / bandwidth.
        let mut up = vec![0usize; n];
        let mut down = vec![0usize; n];
        let mut time = 0.0f64;
        if n > 1 {
            for hop in 0..n - 1 {
                let mut hop_max = 0usize;
                for l in 0..n {
                    let src = (l + n - hop) % n;
                    up[l] += own[src];
                    down[(l + 1) % n] += own[src];
                    hop_max = hop_max.max(own[src]);
                }
                time += fabric.link.transfer_time(hop_max);
            }
        }
        fabric.record_round(&up, &down, time, dense_equiv(layer_lens, n));
        reduce_dense(per_learner, layer_lens)
    }
}

/// Parse a topology by name.
pub fn build(name: &str) -> Option<Box<dyn Topology>> {
    match name {
        "ps" | "param_server" => Some(Box::new(ParamServer)),
        "ring" => Some(Box::new(Ring)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::LinkModel;

    fn sparse(layer: usize, n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
        let wire = 16 + 2 * idx.len();
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes: wire,
            paper_bits: 0,
        }
    }

    fn learners() -> (Vec<Vec<Packet>>, Vec<usize>) {
        let l0 = vec![sparse(0, 6, vec![0, 3], vec![1.0, -1.0])];
        let l1 = vec![sparse(0, 6, vec![0, 5], vec![0.5, 2.0])];
        (vec![l0, l1], vec![6])
    }

    #[test]
    fn ps_and_ring_same_sums() {
        let (pk, lens) = learners();
        let mut f1 = Fabric::new(LinkModel::default());
        let mut f2 = Fabric::new(LinkModel::default());
        let a = ParamServer.exchange(&pk, &lens, &mut f1);
        let b = Ring.exchange(&pk, &lens, &mut f2);
        assert_eq!(a.sums, b.sums);
        assert_eq!(a.sums[0], vec![1.5, 0.0, 0.0, -1.0, 0.0, 2.0]);
    }

    #[test]
    fn ring_bytes_scale_with_n_minus_1() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        Ring.exchange(&pk, &lens, &mut f);
        // each learner's 20-byte packet travels n-1 = 1 hop
        assert_eq!(f.stats.bytes_up, 40);
        assert_eq!(f.stats.rounds, 1);
    }

    #[test]
    fn ps_charges_upload_plus_broadcast() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer.exchange(&pk, &lens, &mut f);
        assert_eq!(f.stats.bytes_up, 40);
        assert!(f.stats.bytes_down > 0);
        assert!(f.stats.sim_time_s > 0.0);
    }

    #[test]
    fn single_learner_ring_is_free() {
        let pk = vec![vec![sparse(0, 4, vec![1], vec![1.0])]];
        let mut f = Fabric::new(LinkModel::default());
        let r = Ring.exchange(&pk, &[4], &mut f);
        assert_eq!(f.stats.bytes_up, 0);
        assert_eq!(r.sums[0], vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn build_by_name() {
        assert!(build("ps").is_some());
        assert!(build("ring").is_some());
        assert!(build("mesh").is_none());
    }
}
