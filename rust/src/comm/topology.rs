//! Exchange topologies over compressed gradient packets.
//!
//! Both topologies implement the same *semantics* — every learner ends the
//! round holding the elementwise **sum** of all learners' packets (synchronous
//! SGD with identical weights, as in the paper) — but charge the fabric
//! differently:
//!
//! * `ParamServer`: learners push packets up (their wire bytes); the server
//!   reduces and broadcasts the merged *sparse union* back down. Round time =
//!   max(upload) + max(download) with the server's in/out links serialized
//!   across learners (single-port model).
//! * `Ring`: all-gather of compressed packets around the ring (the
//!   paper-cited NCCL-style ring, Luehr'16). Each learner forwards every
//!   other learner's packet once: N-1 hops, per-hop time = latency + max
//!   chunk / bandwidth; all links run in parallel.
//!
//! Packets stay compressed end-to-end (this is the point of the paper:
//! reduction of *sparse ternary* vectors), and the reduce is a dense
//! accumulate into a reusable buffer.
//!
//! Two exchange granularities share those semantics:
//!
//! * `exchange_into` — the **barrier** path: one round covering every layer,
//!   each learner's layers coalesced into one message (one latency charge
//!   per learner per direction).
//! * `exchange_layer_into` — the **streamed** path: one round covering a
//!   single layer, so the engine can reduce layer *k* while layers
//!   *k-1..0* are still in backward. Each layer travels as its own message,
//!   so the per-message latency is charged per layer — the honest cost of
//!   streaming. The float math is identical to the corresponding slice of
//!   the barrier reduce (same learner-id summation order per element).
//!
//! Both return a [`RoundCost`] so the engine can place the round on the
//! overlap timeline ([`Fabric::record_step`](super::fabric::Fabric)).
//!
//! Hot-path contract (see DESIGN.md §Threading): both exchange entry points
//! reuse the caller's buffers and each topology's internal scratch, so a
//! steady-state exchange performs **zero heap allocation** (pinned by
//! rust/tests/alloc_free.rs). Packets are reduced in learner-id order — the
//! float summation order is part of the engine's determinism contract.

use super::fabric::{Fabric, LinkModel};
use crate::compress::wire::HEADER_BYTES;
use crate::compress::Packet;

/// Valid topology names for [`build`] (aliases listed in the error text).
pub const NAMES: &[&str] = &["ring", "ps"];

/// Simulated cost of one exchange round (whole-step barrier round or one
/// layer's streamed round).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundCost {
    /// Critical-path seconds for the compressed packets actually sent.
    pub comm_s: f64,
    /// What the same round would have cost with dense f32 payloads, at the
    /// same message granularity (whole step for `exchange_into`, one layer
    /// for `exchange_layer_into`). For the run-level no-compression
    /// baseline use [`Topology::dense_round_s`] — the coalesced dense
    /// barrier round — so the baseline does not vary with the exchange
    /// mode's message granularity.
    pub dense_comm_s: f64,
}

/// The dense per-layer sum of every learner's packet. Allocate once with
/// [`Reduced::new`] and reuse across rounds via `exchange_into`.
pub struct Reduced {
    /// One dense buffer per layer, layer order.
    pub sums: Vec<Vec<f32>>,
}

impl Reduced {
    pub fn new(layer_lens: &[usize]) -> Reduced {
        Reduced {
            sums: layer_lens.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    fn reset(&mut self, layer_lens: &[usize]) {
        // shape can change between runs (not between steps) — realloc only then
        if self.sums.len() != layer_lens.len()
            || self.sums.iter().zip(layer_lens).any(|(s, &n)| s.len() != n)
        {
            *self = Reduced::new(layer_lens);
            return;
        }
        for s in self.sums.iter_mut() {
            s.fill(0.0);
        }
    }
}

pub trait Topology: Send {
    fn name(&self) -> &'static str;

    /// One synchronous **barrier** exchange round, allocation-free in steady
    /// state.
    ///
    /// `per_learner[l]` holds learner l's packets, one per layer, in layer
    /// order. `layer_lens` gives each layer's dense length. Zeroes `out` and
    /// accumulates the per-layer dense sums into it (learner-id order),
    /// records bytes/time on `fabric`, and returns the round's cost.
    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost;

    /// One **streamed** exchange round covering a single layer: `packets`
    /// holds one packet per learner in learner-id order, all for `layer`
    /// (dense length `len`). Zeroes `out` (the layer's dense sum buffer)
    /// and accumulates into it in learner-id order — bit-identical to the
    /// same layer's slice of `exchange_into`. Allocation-free in steady
    /// state. The layer travels as its own message, so latency is charged
    /// per layer.
    fn exchange_layer_into(
        &mut self,
        layer: usize,
        packets: &[Packet],
        len: usize,
        fabric: &mut Fabric,
        out: &mut [f32],
    ) -> RoundCost;

    /// Simulated cost of one coalesced **dense-f32 barrier** round — the
    /// no-compression baseline both exchange granularities are judged
    /// against: every learner ships all layers as one message each way.
    /// Constant for a fixed (layout, learner count), so the engine computes
    /// it once per run; using the coalesced structure keeps the baseline
    /// identical across `--exchange` modes (the streamed path's extra
    /// per-layer latency is charged to the streamed packets, never to the
    /// dense baseline).
    fn dense_round_s(&self, layer_lens: &[usize], n_learners: usize, link: &LinkModel) -> f64;

    /// Convenience wrapper that allocates a fresh `Reduced` per round
    /// (benches/tests; the engine uses `exchange_into`).
    fn exchange(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
    ) -> Reduced {
        let mut out = Reduced::new(layer_lens);
        self.exchange_into(per_learner, layer_lens, fabric, &mut out);
        out
    }
}

/// Dense reduce in learner-id order (the determinism contract: float
/// summation order is fixed regardless of how learners were scheduled).
fn reduce_into(per_learner: &[Vec<Packet>], layer_lens: &[usize], out: &mut Reduced) {
    out.reset(layer_lens);
    for packets in per_learner {
        assert_eq!(packets.len(), layer_lens.len(), "one packet per layer");
        for p in packets {
            p.add_into(&mut out.sums[p.layer]);
        }
    }
}

/// Single-layer reduce in learner-id order — the streamed counterpart of
/// [`reduce_into`], same per-element float summation order.
fn reduce_layer_into(packets: &[Packet], out: &mut [f32]) {
    out.fill(0.0);
    for p in packets {
        p.add_into(out);
    }
}

fn dense_equiv(layer_lens: &[usize], n_learners: usize) -> usize {
    4 * layer_lens.iter().sum::<usize>() * n_learners
}

/// Centralized parameter-server topology.
///
/// Holds reusable scratch (per-learner byte counts + the sparse-union
/// bitset) so rounds are allocation-free in steady state.
#[derive(Default)]
pub struct ParamServer {
    up: Vec<usize>,
    down: Vec<usize>,
    /// Reusable bitset words for the per-layer sparse-union size.
    union_bits: Vec<u64>,
}

impl ParamServer {
    /// Exact element count of the server's merged (union) packet for one
    /// layer: duplicates across learners merge. Any dense packet forces the
    /// whole layer dense. `packets` yields one packet per learner for the
    /// same layer.
    fn union_sent<'p>(
        &mut self,
        packets: impl Iterator<Item = &'p Packet>,
        len: usize,
    ) -> usize {
        let words = len.div_ceil(64);
        if self.union_bits.len() < words {
            self.union_bits.resize(words, 0);
        }
        let bits = &mut self.union_bits[..words];
        bits.fill(0);
        for p in packets {
            if p.is_dense() {
                return len;
            }
            for &i in &p.idx {
                bits[(i / 64) as usize] |= 1u64 << (i % 64);
            }
        }
        bits.iter().map(|w| w.count_ones() as usize).sum()
    }
}

impl Topology for ParamServer {
    fn name(&self) -> &'static str {
        "ps"
    }

    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        let n = per_learner.len();
        self.up.clear();
        self.up.extend(
            per_learner
                .iter()
                .map(|ps| ps.iter().map(|p| p.wire_bytes).sum::<usize>()),
        );
        // The merged update the server broadcasts: the exact sparse union of
        // the learners' packets (a reusable bitset, not a capped sum), as
        // (index u32, value f32) pairs — or the dense layer when that is
        // cheaper. The header is charged once per layer, outside the min.
        let mut down_one = 0usize;
        for (layer, &len) in layer_lens.iter().enumerate() {
            let union = self.union_sent(per_learner.iter().map(|ps| &ps[layer]), len);
            down_one += (8 * union).min(4 * len) + HEADER_BYTES;
        }
        self.down.clear();
        self.down.resize(n, down_one);

        // Single-port server: uploads serialize into the server, downloads
        // serialize out; learners' own links run in parallel.
        let t_up: f64 = self.up.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        let t_down: f64 = self.down.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        fabric.record_round(&self.up, &self.down, t_up + t_down, dense_equiv(layer_lens, n));

        reduce_into(per_learner, layer_lens, out);

        RoundCost {
            comm_s: t_up + t_down,
            dense_comm_s: self.dense_round_s(layer_lens, n, &fabric.link),
        }
    }

    fn dense_round_s(&self, layer_lens: &[usize], n_learners: usize, link: &LinkModel) -> f64 {
        // single-port server: n dense uploads serialize in, n broadcasts out
        let bytes = 4 * layer_lens.iter().sum::<usize>() + HEADER_BYTES;
        2.0 * n_learners as f64 * link.transfer_time(bytes)
    }

    fn exchange_layer_into(
        &mut self,
        _layer: usize,
        packets: &[Packet],
        len: usize,
        fabric: &mut Fabric,
        out: &mut [f32],
    ) -> RoundCost {
        let n = packets.len();
        self.up.clear();
        self.up.extend(packets.iter().map(|p| p.wire_bytes));
        let union = self.union_sent(packets.iter(), len);
        let down_one = (8 * union).min(4 * len) + HEADER_BYTES;
        self.down.clear();
        self.down.resize(n, down_one);

        let t_up: f64 = self.up.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        let t_down: f64 = self.down.iter().map(|&b| fabric.link.transfer_time(b)).sum();
        fabric.record_round(&self.up, &self.down, t_up + t_down, 4 * len * n);

        reduce_layer_into(packets, out);

        let dense_one = fabric.link.transfer_time(4 * len + HEADER_BYTES);
        RoundCost {
            comm_s: t_up + t_down,
            dense_comm_s: 2.0 * n as f64 * dense_one,
        }
    }
}

/// Ring all-gather of compressed packets.
#[derive(Default)]
pub struct Ring {
    own: Vec<usize>,
    up: Vec<usize>,
    down: Vec<usize>,
}

impl Ring {
    /// All-gather byte/time accounting for one message per learner of
    /// `self.own[l]` bytes: every message traverses n-1 hops; all links are
    /// busy in parallel, so hop time = latency + max message / bandwidth.
    /// Fills `self.up`/`self.down` and returns the critical-path seconds.
    fn all_gather(&mut self, fabric: &Fabric) -> f64 {
        let n = self.own.len();
        self.up.clear();
        self.up.resize(n, 0);
        self.down.clear();
        self.down.resize(n, 0);
        let mut time = 0.0f64;
        if n > 1 {
            for hop in 0..n - 1 {
                let mut hop_max = 0usize;
                for l in 0..n {
                    let src = (l + n - hop) % n;
                    self.up[l] += self.own[src];
                    self.down[(l + 1) % n] += self.own[src];
                    hop_max = hop_max.max(self.own[src]);
                }
                time += fabric.link.transfer_time(hop_max);
            }
        }
        time
    }
}

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn exchange_into(
        &mut self,
        per_learner: &[Vec<Packet>],
        layer_lens: &[usize],
        fabric: &mut Fabric,
        out: &mut Reduced,
    ) -> RoundCost {
        let n = per_learner.len();
        self.own.clear();
        self.own.extend(
            per_learner
                .iter()
                .map(|ps| ps.iter().map(|p| p.wire_bytes).sum::<usize>()),
        );
        let time = self.all_gather(fabric);
        fabric.record_round(&self.up, &self.down, time, dense_equiv(layer_lens, n));
        reduce_into(per_learner, layer_lens, out);

        RoundCost {
            comm_s: time,
            dense_comm_s: self.dense_round_s(layer_lens, n, &fabric.link),
        }
    }

    fn dense_round_s(&self, layer_lens: &[usize], n_learners: usize, link: &LinkModel) -> f64 {
        // all-gather of one coalesced dense message per learner: n-1 hops
        let bytes = 4 * layer_lens.iter().sum::<usize>() + HEADER_BYTES;
        n_learners.saturating_sub(1) as f64 * link.transfer_time(bytes)
    }

    fn exchange_layer_into(
        &mut self,
        _layer: usize,
        packets: &[Packet],
        len: usize,
        fabric: &mut Fabric,
        out: &mut [f32],
    ) -> RoundCost {
        let n = packets.len();
        self.own.clear();
        self.own.extend(packets.iter().map(|p| p.wire_bytes));
        let time = self.all_gather(fabric);
        fabric.record_round(&self.up, &self.down, time, 4 * len * n);
        reduce_layer_into(packets, out);

        let dense_hops = n.saturating_sub(1) as f64;
        RoundCost {
            comm_s: time,
            dense_comm_s: dense_hops * fabric.link.transfer_time(4 * len + HEADER_BYTES),
        }
    }
}

/// Parse a topology by name; unknown names error with the valid list.
pub fn build(name: &str) -> anyhow::Result<Box<dyn Topology>> {
    match name {
        "ps" | "param_server" => Ok(Box::new(ParamServer::default())),
        "ring" => Ok(Box::new(Ring::default())),
        other => anyhow::bail!(
            "unknown topology '{other}' (valid: {}; alias: param_server = ps)",
            NAMES.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::LinkModel;

    fn sparse(layer: usize, n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
        let wire = 16 + 2 * idx.len();
        Packet {
            layer,
            n,
            idx,
            val,
            wire_bytes: wire,
            paper_bits: 0,
        }
    }

    fn learners() -> (Vec<Vec<Packet>>, Vec<usize>) {
        let l0 = vec![sparse(0, 6, vec![0, 3], vec![1.0, -1.0])];
        let l1 = vec![sparse(0, 6, vec![0, 5], vec![0.5, 2.0])];
        (vec![l0, l1], vec![6])
    }

    #[test]
    fn ps_and_ring_same_sums() {
        let (pk, lens) = learners();
        let mut f1 = Fabric::new(LinkModel::default());
        let mut f2 = Fabric::new(LinkModel::default());
        let a = ParamServer::default().exchange(&pk, &lens, &mut f1);
        let b = Ring::default().exchange(&pk, &lens, &mut f2);
        assert_eq!(a.sums, b.sums);
        assert_eq!(a.sums[0], vec![1.5, 0.0, 0.0, -1.0, 0.0, 2.0]);
    }

    #[test]
    fn exchange_into_reuses_buffers() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        let mut topo = Ring::default();
        let mut red = Reduced::new(&lens);
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        let first = red.sums[0].clone();
        // a second round must zero the buffer, not accumulate on top of it
        topo.exchange_into(&pk, &lens, &mut f, &mut red);
        assert_eq!(red.sums[0], first);
        assert_eq!(f.stats.rounds, 2);
    }

    #[test]
    fn layer_exchange_matches_barrier_sums() {
        // the streamed per-layer reduce must be bit-identical to the same
        // layer's slice of the barrier reduce, for both topologies
        let (pk, lens) = learners();
        let layer0: Vec<Packet> = pk.iter().map(|ps| ps[0].clone()).collect();
        for name in NAMES {
            let mut fa = Fabric::new(LinkModel::default());
            let mut fb = Fabric::new(LinkModel::default());
            let mut topo_a = build(name).unwrap();
            let mut topo_b = build(name).unwrap();
            let barrier = topo_a.exchange(&pk, &lens, &mut fa);
            let mut out = vec![7.0f32; 6]; // must be zeroed by the call
            let cost = topo_b.exchange_layer_into(0, &layer0, 6, &mut fb, &mut out);
            assert_eq!(out, barrier.sums[0], "{name}");
            // same payload bytes either way; time differs (per-layer latency)
            assert_eq!(fa.stats.bytes_up, fb.stats.bytes_up, "{name}");
            assert_eq!(fa.stats.bytes_down, fb.stats.bytes_down, "{name}");
            assert!(cost.comm_s > 0.0 && cost.dense_comm_s > cost.comm_s, "{name}");
        }
    }

    #[test]
    fn dense_round_is_the_barrier_rounds_dense_baseline() {
        // the run-level dense baseline must equal the coalesced barrier
        // round's dense cost for both topologies (mode-independent baseline)
        let (pk, lens) = learners();
        for name in NAMES {
            let mut f = Fabric::new(LinkModel::default());
            let mut topo = build(name).unwrap();
            let cost = topo.exchange_into(&pk, &lens, &mut f, &mut Reduced::new(&lens));
            let dense = topo.dense_round_s(&lens, 2, &f.link);
            assert!((cost.dense_comm_s - dense).abs() < 1e-15, "{name}");
        }
    }

    #[test]
    fn barrier_cost_reports_dense_baseline() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        let cost = Ring::default().exchange_into(&pk, &lens, &mut f, &mut Reduced::new(&lens));
        assert!((cost.comm_s - f.stats.sim_time_s).abs() < 1e-15);
        // tiny sparse packets: dense must cost strictly more
        assert!(cost.dense_comm_s > cost.comm_s);
    }

    #[test]
    fn ring_bytes_scale_with_n_minus_1() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        Ring::default().exchange(&pk, &lens, &mut f);
        // each learner's 20-byte packet travels n-1 = 1 hop
        assert_eq!(f.stats.bytes_up, 40);
        assert_eq!(f.stats.rounds, 1);
    }

    #[test]
    fn ps_charges_upload_plus_broadcast() {
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        assert_eq!(f.stats.bytes_up, 40);
        assert!(f.stats.bytes_down > 0);
        assert!(f.stats.sim_time_s > 0.0);
    }

    #[test]
    fn ps_broadcast_uses_exact_sparse_union() {
        // learners overlap on index 0: union = {0, 3, 5} = 3 elements, not 4.
        let (pk, lens) = learners();
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&pk, &lens, &mut f);
        let expect_down_one = (8 * 3).min(4 * 6) + crate::compress::wire::HEADER_BYTES;
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn ps_dense_packet_forces_dense_union() {
        let l0 = vec![Packet::dense(0, vec![1.0; 6])];
        let l1 = vec![sparse(0, 6, vec![2], vec![1.0])];
        let mut f = Fabric::new(LinkModel::default());
        ParamServer::default().exchange(&[l0, l1], &[6], &mut f);
        // dense fallback (4 bytes/elem beats 8) + one header, per learner
        let expect_down_one = 4 * 6 + crate::compress::wire::HEADER_BYTES;
        assert_eq!(f.stats.bytes_down, 2 * expect_down_one as u64);
    }

    #[test]
    fn single_learner_ring_is_free() {
        let pk = vec![vec![sparse(0, 4, vec![1], vec![1.0])]];
        let mut f = Fabric::new(LinkModel::default());
        let r = Ring::default().exchange(&pk, &[4], &mut f);
        assert_eq!(f.stats.bytes_up, 0);
        assert_eq!(r.sums[0], vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn build_by_name() {
        assert!(build("ps").is_ok());
        assert!(build("ring").is_ok());
        let err = build("mesh").unwrap_err().to_string();
        assert!(err.contains("ring") && err.contains("ps"), "{err}");
    }
}
