//! Communication substrate: simulated fabric, reduce plan, and exchange
//! topologies.

pub mod fabric;
pub mod plan;
pub mod topology;

pub use fabric::{ControlDecision, Fabric, FabricStats, LinkModel, MembershipChange};
pub use plan::{Bucket, ReducePlan};
pub use topology::{HierPs, ParamServer, Reduced, Ring, RoundCost, RoundSched, Topology};
