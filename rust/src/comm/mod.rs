//! Communication substrate: simulated fabric + exchange topologies.

pub mod fabric;
pub mod topology;

pub use fabric::{Fabric, FabricStats, LinkModel};
pub use topology::{ParamServer, Reduced, Ring, RoundCost, Topology};
