//! Shared compute pool for within-learner kernel parallelism (DESIGN.md
//! §Compute kernels).
//!
//! The engine already fans out *across* learners (`train/pool.rs`); this
//! module is the tier below it — a process-wide pool of helper threads that
//! one kernel invocation (a single GEMM) can fan its macro-tiles across.
//! Two pieces live here:
//!
//! * **The core budget.** A single global `kernel_threads` knob, read by
//!   the public `tensor::gemm` wrappers on every call. The engine derives
//!   it as `max(1, total_thread_budget / active_learners)` (so intra-GEMM
//!   parallelism composes with the across-learner pool instead of
//!   oversubscribing) and re-derives it at every membership epoch when the
//!   elastic fleet grows or shrinks. `--kernel-threads N > 0` pins it.
//!   Because the parallel GEMM is bit-identical at every thread count (see
//!   `tensor/gemm.rs`), a stale or concurrently-updated budget can only
//!   ever change speed, never results.
//!
//! * **`parallel_for`.** Deterministic fork-join over `nslots` slots: the
//!   caller runs slot 0 inline, slots `1..nslots` are queued to the shared
//!   pool, and the caller helps drain the queue until its own slots have
//!   all completed. Helper threads are spawned lazily (first use), parked
//!   on a condvar when idle, and shared by every concurrently-running
//!   learner — the pool never holds more than [`MAX_KERNEL_THREADS`]
//!   helpers. Steady-state invocations are allocation-free: the task queue
//!   reuses its capacity and the job descriptor lives on the caller's
//!   stack (rust/tests/alloc_free.rs pins this through the GEMM path).
//!
//! Safety model: a job's closure reference is lifetime-erased so it can
//! sit in the shared queue, which is sound because `parallel_for` does not
//! return (or unwind) until every queued slot has finished — completion is
//! counted under the pool mutex, so the caller's stack frame outlives all
//! uses. Worker-side panics are caught, flagged on the job, and re-raised
//! on the caller's thread.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Upper bound on `--kernel-threads` (and on pool helper threads): a wider
/// request is a config typo, not a machine.
pub const MAX_KERNEL_THREADS: usize = 64;

/// The process-wide intra-kernel thread budget. 1 (the default) keeps every
/// kernel serial; the engine raises it per [`derive_budget`] at run start
/// and at membership epochs. Reads are racy on purpose — the budget is a
/// performance hint, and results are bit-identical at any value.
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the global kernel-thread budget (clamped to `1..=MAX_KERNEL_THREADS`).
pub fn set_kernel_threads(n: usize) {
    KERNEL_THREADS.store(n.clamp(1, MAX_KERNEL_THREADS), Ordering::Relaxed);
}

/// The current kernel-thread budget (>= 1).
pub fn kernel_threads() -> usize {
    KERNEL_THREADS.load(Ordering::Relaxed).max(1)
}

/// The auto core-budget rule (`--kernel-threads 0`): split the run's total
/// thread budget evenly over the live learners, never below 1. The engine
/// calls this at run start with the configured fleet size and again at
/// every membership epoch with the post-event size.
pub fn derive_budget(total_threads: usize, active_learners: usize) -> usize {
    (total_threads / active_learners.max(1)).max(1)
}

/// One queued slot of a fork-join job.
struct Task {
    job: *const Job,
    slot: usize,
}
// SAFETY: the raw job pointer crosses into pool threads, but the pointee
// (on the submitting caller's stack) outlives every task — `parallel_for`
// blocks until `pending` hits zero, and the final decrement happens under
// the pool mutex before the caller can observe completion.
unsafe impl Send for Task {}

/// A fork-join job: the slot closure plus completion bookkeeping. Lives on
/// the caller's stack for the duration of one `parallel_for`.
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    /// Slots not yet finished; decremented only under the pool mutex.
    pending: AtomicUsize,
    /// Set when any slot's closure panicked on a pool thread.
    panicked: AtomicBool,
}

struct PoolState {
    queue: VecDeque<Task>,
    workers: usize,
}

struct Pool {
    inner: Mutex<PoolState>,
    /// Workers park here when the queue is empty.
    work: Condvar,
    /// Callers park here while their job's slots are in flight elsewhere.
    done: Condvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        inner: Mutex::new(PoolState {
            queue: VecDeque::new(),
            workers: 0,
        }),
        work: Condvar::new(),
        done: Condvar::new(),
    })
}

/// Run one task body, trapping panics so a worker thread never dies with a
/// job's `pending` count stranded above zero.
fn run_task(task: &Task) {
    // SAFETY: see `Task` — the job outlives every queued task.
    let job = unsafe { &*task.job };
    if catch_unwind(AssertUnwindSafe(|| (job.f)(task.slot))).is_err() {
        job.panicked.store(true, Ordering::Relaxed);
    }
}

/// Mark one task finished. Must be called with the pool mutex held: the
/// lock orders the closure's memory effects before any caller that
/// observes `pending == 0`, and keeps the job alive until after the final
/// decrement (the caller frees it only once it reacquires the lock).
fn finish_task(pool: &Pool, task: &Task) {
    // SAFETY: the pool mutex is held, so the submitting caller cannot have
    // observed completion yet — the job pointer is still live.
    let job = unsafe { &*task.job };
    if job.pending.fetch_sub(1, Ordering::Relaxed) == 1 {
        pool.done.notify_all();
    }
}

fn spawn_worker(pool: &'static Pool) {
    std::thread::Builder::new()
        .name("adacomp-kernel".into())
        .spawn(move || {
            let mut st = pool.inner.lock().unwrap();
            loop {
                if let Some(task) = st.queue.pop_front() {
                    drop(st);
                    run_task(&task);
                    st = pool.inner.lock().unwrap();
                    finish_task(pool, &task);
                } else {
                    st = pool.work.wait(st).unwrap();
                }
            }
        })
        .expect("spawn compute-pool worker");
}

/// Fork-join over `nslots` slots: `f(0)` runs on the calling thread,
/// `f(1..nslots)` on the shared pool, and the call returns only when every
/// slot has completed. The slot partition is the caller's responsibility —
/// slots must touch disjoint output regions. Panics in any slot re-raise
/// on the caller's thread after all slots have drained.
pub fn parallel_for(nslots: usize, f: &(dyn Fn(usize) + Sync)) {
    if nslots <= 1 {
        f(0);
        return;
    }
    let pool = pool();
    // SAFETY: lifetime erasure only — the job (and thus this reference) is
    // dropped before `parallel_for` returns, and every queued use finishes
    // before that (counted under the pool mutex).
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    };
    let job = Job {
        f: f_static,
        pending: AtomicUsize::new(nslots - 1),
        panicked: AtomicBool::new(false),
    };
    {
        let mut st = pool.inner.lock().unwrap();
        for slot in 1..nslots {
            st.queue.push_back(Task { job: &job, slot });
        }
        // lazy provisioning: enough helpers for what is queued right now,
        // shared across every concurrent caller, hard-capped
        let want = st.queue.len().min(MAX_KERNEL_THREADS);
        while st.workers < want {
            st.workers += 1;
            spawn_worker(pool);
        }
    }
    pool.work.notify_all();

    // Slot 0 inline. A panic here must not unwind past live queued tasks,
    // so trap it and re-raise after the join below.
    let local = catch_unwind(AssertUnwindSafe(|| f(0)));

    // Join: help drain the queue (our own slots or another caller's — both
    // keep the pool making progress) until this job's slots are done.
    let mut st = pool.inner.lock().unwrap();
    while job.pending.load(Ordering::Relaxed) > 0 {
        if let Some(task) = st.queue.pop_front() {
            drop(st);
            run_task(&task);
            st = pool.inner.lock().unwrap();
            finish_task(pool, &task);
        } else {
            st = pool.done.wait(st).unwrap();
        }
    }
    drop(st);

    if let Err(payload) = local {
        resume_unwind(payload);
    }
    if job.panicked.load(Ordering::Relaxed) {
        panic!("compute-pool slot panicked (see worker thread output)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn budget_clamps_and_derives() {
        set_kernel_threads(0);
        assert_eq!(kernel_threads(), 1);
        set_kernel_threads(4);
        assert_eq!(kernel_threads(), 4);
        set_kernel_threads(10_000);
        assert_eq!(kernel_threads(), MAX_KERNEL_THREADS);
        set_kernel_threads(1); // restore the serial default for other tests

        assert_eq!(derive_budget(8, 2), 4);
        assert_eq!(derive_budget(8, 3), 2);
        assert_eq!(derive_budget(2, 8), 1);
        assert_eq!(derive_budget(0, 0), 1);
    }

    #[test]
    fn parallel_for_runs_every_slot_exactly_once() {
        for nslots in [1usize, 2, 3, 8, 17] {
            let hits: Vec<AtomicU32> = (0..nslots).map(|_| AtomicU32::new(0)).collect();
            parallel_for(nslots, &|slot| {
                hits[slot].fetch_add(1, Ordering::Relaxed);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "nslots={nslots} slot={s}");
            }
        }
    }

    #[test]
    fn parallel_for_disjoint_writes_land() {
        // each slot fills its own stripe of a shared buffer through a raw
        // pointer — the gemm tile-ownership pattern in miniature
        struct SendPtr(*mut u64);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let (nslots, per) = (6usize, 1000usize);
        let mut out = vec![0u64; nslots * per];
        let p = SendPtr(out.as_mut_ptr());
        parallel_for(nslots, &|slot| {
            for i in 0..per {
                // SAFETY: stripes are disjoint per slot
                unsafe { *p.0.add(slot * per + i) = (slot * per + i) as u64 };
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn nested_callers_share_the_pool() {
        // concurrent parallel_for calls from independent threads (the
        // multi-learner shape) must all complete without deadlock
        let total = AtomicU32::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        parallel_for(4, &|_slot| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 4);
    }

    #[test]
    fn slot_panic_surfaces_on_caller() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            parallel_for(4, &|slot| {
                if slot == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "pool-slot panic must re-raise on the caller");
        // and the pool must still be serviceable afterwards
        let n = AtomicU32::new(0);
        parallel_for(4, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
    }
}
