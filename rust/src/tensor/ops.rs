//! Flat-slice vector/matrix primitives shared by the optimizers, the
//! compression hot path and the native executor.
//!
//! Written to autovectorize: fixed-stride loops over exact-chunk slices.

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    // 4 accumulators: breaks the fp dependency chain so LLVM vectorizes.
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

// The naive ikj matmul kernels that used to live here (with their
// data-dependent `if av == 0.0` skips) are retired: every matmul variant now
// routes through the packed, register-tiled GEMM in `tensor::gemm` —
// branch-free inner loops, runtime AVX2+FMA dispatch, bit-identical scalar
// fallback. See DESIGN.md §Compute kernels; bench_kernels pins the speedup
// against a copy of the retired loops.

/// In-place ReLU; returns nothing. Pair with `relu_grad`.
#[inline]
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// dx = dy * (y > 0), where y is the *post*-activation value.
#[inline]
pub fn relu_grad(y: &[f32], dy: &mut [f32]) {
    assert_eq!(y.len(), dy.len());
    for (d, &v) in dy.iter_mut().zip(y.iter()) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Row-wise log-softmax + NLL loss; returns (mean loss, dlogits/mean).
/// logits [rows, c], labels [rows]. dlogits is overwritten.
pub fn softmax_xent(logits: &[f32], labels: &[i32], c: usize, dlogits: &mut [f32]) -> f32 {
    let rows = labels.len();
    assert_eq!(logits.len(), rows * c);
    assert_eq!(dlogits.len(), rows * c);
    let inv = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for r in 0..rows {
        let row = &logits[r * c..(r + 1) * c];
        let drow = &mut dlogits[r * c..(r + 1) * c];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        for (d, &x) in drow.iter_mut().zip(row.iter()) {
            let e = (x - maxv).exp();
            *d = e;
            sum += e;
        }
        let label = labels[r] as usize;
        debug_assert!(label < c);
        let logz = sum.ln() + maxv;
        loss += (logz - row[label]) as f64;
        let isum = 1.0 / sum;
        for d in drow.iter_mut() {
            *d *= isum * inv;
        }
        drow[label] -= inv;
    }
    loss as f32 * inv
}

/// argmax per row; returns count of rows where argmax == label.
pub fn count_correct(logits: &[f32], labels: &[i32], c: usize) -> usize {
    let rows = labels.len();
    let mut n = 0;
    for r in 0..rows {
        let row = &logits[r * c..(r + 1) * c];
        let mut best = 0usize;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[r] as usize {
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..103).map(|i| i as f32 * 0.01).collect();
        let y: Vec<f32> = (0..103).map(|i| 1.0 - i as f32 * 0.02).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }

    #[test]
    fn relu_and_grad() {
        let mut x = vec![-1.0, 0.5, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.5, 2.0]);
        let mut dy = vec![1.0, 1.0, 1.0];
        relu_grad(&x, &mut dy);
        assert_eq!(dy, vec![0.0, 1.0, 1.0]);
    }

    #[test]
    fn softmax_xent_uniform() {
        // uniform logits -> loss = ln(c), grads sum to 0 per row
        let c = 4;
        let logits = vec![0.0; 2 * c];
        let labels = vec![1, 3];
        let mut d = vec![0.0; 2 * c];
        let loss = softmax_xent(&logits, &labels, c, &mut d);
        assert!((loss - (c as f32).ln()).abs() < 1e-5);
        for r in 0..2 {
            let s: f32 = d[r * c..(r + 1) * c].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_gradient_numerical() {
        let c = 3;
        let logits = vec![0.2f32, -0.1, 0.5, 1.0, 0.0, -0.5];
        let labels = vec![2, 0];
        let mut d = vec![0.0; 6];
        softmax_xent(&logits, &labels, c, &mut d);
        let eps = 1e-3;
        for i in 0..6 {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let mut scratch = vec![0.0; 6];
            let fp = softmax_xent(&lp, &labels, c, &mut scratch);
            let fm = softmax_xent(&lm, &labels, c, &mut scratch);
            let num = (fp - fm) / (2.0 * eps);
            assert!((num - d[i]).abs() < 1e-3, "i={} num={} ana={}", i, num, d[i]);
        }
    }

    #[test]
    fn count_correct_basic() {
        let logits = vec![1.0, 2.0, 0.0, 5.0, 1.0, 1.0];
        let labels = vec![1, 0];
        assert_eq!(count_correct(&logits, &labels, 3), 2);
    }
}
