//! Flat f32 tensor with shape metadata.
//!
//! The coordinator's view of model state: parameters, gradients and residues
//! are flat `f32` buffers carved into per-layer views (see `models::Layout`).
//! Deliberately minimal — the heavy model math happens either in AOT-compiled
//! HLO (runtime::pjrt) or in `runtime::native`'s hand-written kernels; this
//! type provides the shared vector algebra (optimizers, reductions, norms).

pub mod conv;
pub mod embed;
pub mod gemm;
pub mod lstm;
pub mod ops;
pub mod parallel;

/// Per-executor kernel scratch arena (DESIGN.md §Compute kernels): the GEMM
/// packing pool plus every gather/cotangent buffer the conv and LSTM
/// kernels previously allocated per call. One instance lives in each
/// `NativeNet`; buffers grow to their high-water size during the first step
/// and are reused thereafter, so a full forward+backward step is
/// allocation-free in steady state (rust/tests/alloc_free.rs).
#[derive(Debug, Default)]
pub struct KernelScratch {
    /// Packed GEMM panels (shared by every matmul the executor runs).
    pub gemm: gemm::GemmScratch,
    /// conv backward: dcols = dy @ Wᵀ before the col2im scatter.
    pub dcols: Vec<f32>,
    // LSTM forward: per-timestep gathers and the pre-activation gate block.
    pub xt: Vec<f32>,
    pub z: Vec<f32>,
    pub h_prev: Vec<f32>,
    pub c_prev: Vec<f32>,
    // LSTM backward (BPTT): gate cotangents and carried h/c gradients.
    pub dz: Vec<f32>,
    pub dh_next: Vec<f32>,
    pub dc_next: Vec<f32>,
    pub dxt: Vec<f32>,
}

impl Clone for KernelScratch {
    /// Scratch carries no cross-call state — cloning an executor must not
    /// duplicate high-water buffers, so a clone starts empty.
    fn clone(&self) -> KernelScratch {
        KernelScratch::default()
    }
}

/// Dense f32 tensor, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on tensor of len {}", self.data.len());
        self.data[0]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // -- elementwise -------------------------------------------------------

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        ops::axpy(alpha, other.data(), self.data_mut());
    }

    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        self.axpy(1.0, other);
    }

    // -- reductions ----------------------------------------------------------

    pub fn dot(&self, other: &Tensor) -> f32 {
        ops::dot(self.data(), other.data())
    }

    pub fn l2_norm(&self) -> f32 {
        self.dot(self).sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.data()[2], 3.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn axpy_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![10.0, 10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 7.0, 8.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 14.0, 16.0]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, 0.0]);
        assert_eq!(t.abs_max(), 3.0);
        assert_eq!(t.mean(), 0.5);
        assert_eq!(t.count_nonzero(), 3);
        assert!((t.l2_norm() - 14.0f32.sqrt()).abs() < 1e-6);
        assert!(t.is_finite());
    }

    #[test]
    fn nonfinite_detected() {
        let t = Tensor::from_vec(&[2], vec![1.0, f32::NAN]);
        assert!(!t.is_finite());
    }

    #[test]
    fn reshape() {
        let t = Tensor::zeros(&[6]).reshape(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
    }
}
