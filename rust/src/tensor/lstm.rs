//! LSTM sequence kernels for the native layer-graph executor.
//!
//! Matches the exported JAX cell (`python/compile/layers.py::lstm_layer`):
//! parameters `wx [in, 4H]`, `wh [H, 4H]`, `b [4H]`, gate order `i, f, g, o`:
//!
//! ```text
//! z = x_t @ wx + h_{t-1} @ wh + b
//! c_t = sigmoid(f) * c_{t-1} + sigmoid(i) * tanh(g)
//! h_t = sigmoid(o) * tanh(c_t)
//! ```
//!
//! Activations are batch-major `[B, T, D]`; the forward caches the activated
//! gates plus `c_t`/`tanh(c_t)` time-major (`[T, B, ·]`) so the backward can
//! run BPTT without recomputing the nonlinearities. All *output* buffer
//! arguments are resized by the kernel, so callers reuse them across steps
//! (the layer tape does); the per-timestep gather/cotangent buffers (`xt`,
//! `z`, `dz`, ...) live in the caller's [`KernelScratch`] arena, so a
//! steady-state step allocates nothing (rust/tests/alloc_free.rs).

use super::gemm;
use super::ops::sigmoid;
use super::KernelScratch;

/// Forward over the whole sequence.
///
/// * `x` — `[B, T, in]` inputs.
/// * `gates` — out: activated `i,f,g,o`, `[T, B, 4H]`.
/// * `c`, `tanh_c` — out: cell state and its tanh, `[T, B, H]`.
/// * `y` — out: hidden states, `[B, T, H]`.
#[allow(clippy::too_many_arguments)]
pub fn forward(
    x: &[f32],
    wx: &[f32],
    wh: &[f32],
    bias: &[f32],
    bsz: usize,
    t_len: usize,
    in_dim: usize,
    hidden: usize,
    ks: &mut KernelScratch,
    gates: &mut Vec<f32>,
    c: &mut Vec<f32>,
    tanh_c: &mut Vec<f32>,
    y: &mut Vec<f32>,
) {
    let (h4, h) = (4 * hidden, hidden);
    assert_eq!(x.len(), bsz * t_len * in_dim);
    assert_eq!(wx.len(), in_dim * h4);
    assert_eq!(wh.len(), h * h4);
    assert_eq!(bias.len(), h4);
    gates.clear();
    gates.resize(t_len * bsz * h4, 0.0);
    c.clear();
    c.resize(t_len * bsz * h, 0.0);
    tanh_c.clear();
    tanh_c.resize(t_len * bsz * h, 0.0);
    y.clear();
    y.resize(bsz * t_len * h, 0.0);

    // disjoint-field borrows out of the arena (gemm scratch + gathers)
    let KernelScratch {
        gemm: gs,
        xt,
        z,
        h_prev,
        c_prev,
        ..
    } = ks;
    xt.clear();
    xt.resize(bsz * in_dim, 0.0);
    z.clear();
    z.resize(bsz * h4, 0.0);
    h_prev.clear();
    h_prev.resize(bsz * h, 0.0);
    c_prev.clear();
    c_prev.resize(bsz * h, 0.0);

    for t in 0..t_len {
        for b in 0..bsz {
            let src = (b * t_len + t) * in_dim;
            xt[b * in_dim..(b + 1) * in_dim].copy_from_slice(&x[src..src + in_dim]);
        }
        gemm::matmul(gs, xt, wx, z, bsz, in_dim, h4, false);
        gemm::matmul(gs, h_prev, wh, z, bsz, h, h4, true);

        let gt = &mut gates[t * bsz * h4..(t + 1) * bsz * h4];
        let ct = &mut c[t * bsz * h..(t + 1) * bsz * h];
        let tct = &mut tanh_c[t * bsz * h..(t + 1) * bsz * h];
        for b in 0..bsz {
            let zr = &z[b * h4..(b + 1) * h4];
            for j in 0..h {
                let ai = sigmoid(zr[j] + bias[j]);
                let af = sigmoid(zr[h + j] + bias[h + j]);
                let ag = (zr[2 * h + j] + bias[2 * h + j]).tanh();
                let ao = sigmoid(zr[3 * h + j] + bias[3 * h + j]);
                let cc = af * c_prev[b * h + j] + ai * ag;
                let tc = cc.tanh();
                gt[b * h4 + j] = ai;
                gt[b * h4 + h + j] = af;
                gt[b * h4 + 2 * h + j] = ag;
                gt[b * h4 + 3 * h + j] = ao;
                ct[b * h + j] = cc;
                tct[b * h + j] = tc;
                y[(b * t_len + t) * h + j] = ao * tc;
            }
        }
        c_prev.copy_from_slice(ct);
        for b in 0..bsz {
            let src = (b * t_len + t) * h;
            h_prev[b * h..(b + 1) * h].copy_from_slice(&y[src..src + h]);
        }
    }
}

/// BPTT over the whole sequence. `gwx`/`gwh`/`gb` are accumulated into
/// (caller zeroes them once); `dx` (when given) is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    x: &[f32],
    wx: &[f32],
    wh: &[f32],
    gates: &[f32],
    c: &[f32],
    tanh_c: &[f32],
    y: &[f32],
    dy: &[f32],
    bsz: usize,
    t_len: usize,
    in_dim: usize,
    hidden: usize,
    ks: &mut KernelScratch,
    gwx: &mut [f32],
    gwh: &mut [f32],
    gb: &mut [f32],
    mut dx: Option<&mut [f32]>,
) {
    let (h4, h) = (4 * hidden, hidden);
    assert_eq!(dy.len(), bsz * t_len * h);
    assert_eq!(gwx.len(), in_dim * h4);
    assert_eq!(gwh.len(), h * h4);
    assert_eq!(gb.len(), h4);
    if let Some(d) = dx.as_deref_mut() {
        assert_eq!(d.len(), bsz * t_len * in_dim);
    }

    let KernelScratch {
        gemm: gs,
        xt,
        h_prev,
        dz,
        dh_next,
        dc_next,
        dxt,
        ..
    } = ks;
    // clear + zero-fill resets carried state from the previous call
    dz.clear();
    dz.resize(bsz * h4, 0.0);
    dh_next.clear();
    dh_next.resize(bsz * h, 0.0);
    dc_next.clear();
    dc_next.resize(bsz * h, 0.0);
    xt.clear();
    xt.resize(bsz * in_dim, 0.0);
    h_prev.clear();
    h_prev.resize(bsz * h, 0.0);
    dxt.clear();
    dxt.resize(bsz * in_dim, 0.0);

    for t in (0..t_len).rev() {
        let gt = &gates[t * bsz * h4..(t + 1) * bsz * h4];
        let ct_prev = if t > 0 {
            Some(&c[(t - 1) * bsz * h..t * bsz * h])
        } else {
            None
        };
        let tct = &tanh_c[t * bsz * h..(t + 1) * bsz * h];
        for b in 0..bsz {
            for j in 0..h {
                let dh = dy[(b * t_len + t) * h + j] + dh_next[b * h + j];
                let ai = gt[b * h4 + j];
                let af = gt[b * h4 + h + j];
                let ag = gt[b * h4 + 2 * h + j];
                let ao = gt[b * h4 + 3 * h + j];
                let tc = tct[b * h + j];
                let cprev = ct_prev.map_or(0.0, |s| s[b * h + j]);
                let d_o = dh * tc;
                let dc = dh * ao * (1.0 - tc * tc) + dc_next[b * h + j];
                dc_next[b * h + j] = dc * af;
                dz[b * h4 + j] = dc * ag * ai * (1.0 - ai);
                dz[b * h4 + h + j] = dc * cprev * af * (1.0 - af);
                dz[b * h4 + 2 * h + j] = dc * ai * (1.0 - ag * ag);
                dz[b * h4 + 3 * h + j] = d_o * ao * (1.0 - ao);
            }
        }
        for b in 0..bsz {
            for j4 in 0..h4 {
                gb[j4] += dz[b * h4 + j4];
            }
        }
        for b in 0..bsz {
            let src = (b * t_len + t) * in_dim;
            xt[b * in_dim..(b + 1) * in_dim].copy_from_slice(&x[src..src + in_dim]);
            if t > 0 {
                let hsrc = (b * t_len + t - 1) * h;
                h_prev[b * h..(b + 1) * h].copy_from_slice(&y[hsrc..hsrc + h]);
            } else {
                h_prev[b * h..(b + 1) * h].iter_mut().for_each(|v| *v = 0.0);
            }
        }
        // per-t weight-gradient panels accumulate straight into gwx/gwh
        // (the packed kernel sums each tile in registers, then adds once —
        // no staging scratch, no extra axpy pass)
        gemm::matmul_at_b(gs, xt, dz, gwx, in_dim, bsz, h4, true);
        gemm::matmul_at_b(gs, h_prev, dz, gwh, h, bsz, h4, true);
        // dh_{t-1} += nothing else reaches it besides dz @ wh^T (dy[t-1] is
        // added at the top of the next iteration)
        gemm::matmul_a_bt(gs, dz, wh, dh_next, bsz, h4, h);
        if let Some(d) = dx.as_deref_mut() {
            gemm::matmul_a_bt(gs, dz, wx, dxt, bsz, h4, in_dim);
            for b in 0..bsz {
                let dst = (b * t_len + t) * in_dim;
                d[dst..dst + in_dim].copy_from_slice(&dxt[b * in_dim..(b + 1) * in_dim]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn loss_of(
        x: &[f32],
        wx: &[f32],
        wh: &[f32],
        b: &[f32],
        bsz: usize,
        t: usize,
        i: usize,
        h: usize,
    ) -> f32 {
        let mut ks = KernelScratch::default();
        let (mut g, mut c, mut tc, mut y) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        forward(x, wx, wh, b, bsz, t, i, h, &mut ks, &mut g, &mut c, &mut tc, &mut y);
        // simple scalar loss: sum of squares / 2 -> dy = y
        y.iter().map(|v| 0.5 * v * v).sum()
    }

    #[test]
    fn bptt_matches_numerical() {
        let (bsz, t, i, h) = (2usize, 3usize, 4usize, 3usize);
        let mut rng = Pcg32::seeded(5);
        let x = rng.normal_vec(bsz * t * i, 1.0);
        let wx = rng.normal_vec(i * 4 * h, 0.4);
        let wh = rng.normal_vec(h * 4 * h, 0.4);
        let mut bias = vec![0.0f32; 4 * h];
        bias[h..2 * h].iter_mut().for_each(|v| *v = 1.0);

        let mut ks = KernelScratch::default();
        let (mut g, mut c, mut tc, mut y) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        forward(&x, &wx, &wh, &bias, bsz, t, i, h, &mut ks, &mut g, &mut c, &mut tc, &mut y);
        let dy = y.clone(); // d(sum y^2/2)/dy = y
        let mut gwx = vec![0.0f32; wx.len()];
        let mut gwh = vec![0.0f32; wh.len()];
        let mut gb = vec![0.0f32; bias.len()];
        let mut dx = vec![0.0f32; x.len()];
        backward(
            &x, &wx, &wh, &g, &c, &tc, &y, &dy, bsz, t, i, h, &mut ks, &mut gwx, &mut gwh,
            &mut gb, Some(&mut dx),
        );

        let eps = 1e-2f32;
        let check = |ana: &[f32], param: &dyn Fn(usize, f32) -> f32, n: usize, tag: &str| {
            let mut rng = Pcg32::seeded(9);
            for _ in 0..8 {
                let k = rng.below(n as u32) as usize;
                let lp = param(k, eps);
                let lm = param(k, -eps);
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - ana[k]).abs() < 2e-2 * num.abs().max(1.0),
                    "{tag}[{k}] num {num} ana {}",
                    ana[k]
                );
            }
        };
        check(
            &gwx,
            &|k, e| {
                let mut p = wx.clone();
                p[k] += e;
                loss_of(&x, &p, &wh, &bias, bsz, t, i, h)
            },
            wx.len(),
            "gwx",
        );
        check(
            &gwh,
            &|k, e| {
                let mut p = wh.clone();
                p[k] += e;
                loss_of(&x, &wx, &p, &bias, bsz, t, i, h)
            },
            wh.len(),
            "gwh",
        );
        check(
            &gb,
            &|k, e| {
                let mut p = bias.clone();
                p[k] += e;
                loss_of(&x, &wx, &wh, &p, bsz, t, i, h)
            },
            bias.len(),
            "gb",
        );
        check(
            &dx,
            &|k, e| {
                let mut p = x.clone();
                p[k] += e;
                loss_of(&p, &wx, &wh, &bias, bsz, t, i, h)
            },
            x.len(),
            "dx",
        );
    }

    #[test]
    fn zero_params_stay_at_rest() {
        // all-zero parameters: gates sit at sigmoid(0)=0.5 / tanh(0)=0, so
        // the cell never accumulates state and the output stays exactly 0
        let (bsz, t, i, h) = (1usize, 4usize, 2usize, 2usize);
        let x = vec![0.0f32; bsz * t * i];
        let wx = vec![0.0f32; i * 4 * h];
        let wh = vec![0.0f32; h * 4 * h];
        let bias = vec![0.0f32; 4 * h];
        let mut ks = KernelScratch::default();
        let (mut g, mut c, mut tc, mut y) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        forward(&x, &wx, &wh, &bias, bsz, t, i, h, &mut ks, &mut g, &mut c, &mut tc, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
        assert!(c.iter().all(|&v| v == 0.0));
    }
}
