//! Convolution / pooling primitives for the native executor (NHWC, HWIO).
//!
//! im2col-based: correctness-first reference used by hermetic tests and for
//! cross-checking the PJRT numerics; the production training path runs the
//! XLA-compiled HLO instead.

/// im2col for SAME-padded stride-1 convolution.
/// x: [b, h, w, cin] -> cols: [b*h*w, kh*kw*cin]
pub fn im2col_same(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cols: &mut Vec<f32>,
) {
    let (ph, pw) = (kh / 2, kw / 2);
    cols.clear();
    cols.resize(b * h * w * kh * kw * cin, 0.0);
    let row_len = kh * kw * cin;
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let out_base = ((bi * h + i) * w + j) * row_len;
                for ki in 0..kh {
                    let si = i as isize + ki as isize - ph as isize;
                    if si < 0 || si >= h as isize {
                        continue;
                    }
                    for kj in 0..kw {
                        let sj = j as isize + kj as isize - pw as isize;
                        if sj < 0 || sj >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + si as usize) * w + sj as usize) * cin;
                        let dst = out_base + (ki * kw + kj) * cin;
                        cols[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                    }
                }
            }
        }
    }
}

/// col2im: scatter-add columns back into the input gradient.
pub fn col2im_same(
    cols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    dx: &mut [f32],
) {
    let (ph, pw) = (kh / 2, kw / 2);
    dx.iter_mut().for_each(|v| *v = 0.0);
    let row_len = kh * kw * cin;
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let col_base = ((bi * h + i) * w + j) * row_len;
                for ki in 0..kh {
                    let si = i as isize + ki as isize - ph as isize;
                    if si < 0 || si >= h as isize {
                        continue;
                    }
                    for kj in 0..kw {
                        let sj = j as isize + kj as isize - pw as isize;
                        if sj < 0 || sj >= w as isize {
                            continue;
                        }
                        let dst = ((bi * h + si as usize) * w + sj as usize) * cin;
                        let src = col_base + (ki * kw + kj) * cin;
                        for c in 0..cin {
                            dx[dst + c] += cols[src + c];
                        }
                    }
                }
            }
        }
    }
}

/// SAME stride-1 conv forward. w: [kh, kw, cin, cout] (HWIO, row-major).
/// Returns y [b,h,w,cout]; `cols` and `gs` are scratch reused across calls.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same(
    x: &[f32],
    wgt: &[f32],
    bias: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    cols: &mut Vec<f32>,
    gs: &mut super::gemm::GemmScratch,
    y: &mut Vec<f32>,
) {
    im2col_same(x, b, h, w_, cin, kh, kw, cols);
    let rows = b * h * w_;
    let k = kh * kw * cin;
    y.clear();
    y.resize(rows * cout, 0.0);
    super::gemm::matmul(gs, cols, wgt, y, rows, k, cout, false);
    for r in 0..rows {
        for c in 0..cout {
            y[r * cout + c] += bias[c];
        }
    }
}

/// Backward of SAME stride-1 conv.
/// dy: [b,h,w,cout]; fills dw [kh*kw*cin*cout], db [cout], dx [b,h,w,cin].
/// `cols`, `gs` and `dcols` are caller-pooled scratch (no per-call
/// allocation in steady state).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_bwd(
    x: &[f32],
    wgt: &[f32],
    dy: &[f32],
    b: usize,
    h: usize,
    w_: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    cols: &mut Vec<f32>,
    gs: &mut super::gemm::GemmScratch,
    dcols: &mut Vec<f32>,
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
) {
    let rows = b * h * w_;
    let k = kh * kw * cin;
    im2col_same(x, b, h, w_, cin, kh, kw, cols);
    // dW = cols^T @ dy  (cols [rows,k], dy [rows,cout])
    super::gemm::matmul_at_b(gs, cols, dy, dw, k, rows, cout, false);
    db.iter_mut().for_each(|v| *v = 0.0);
    for r in 0..rows {
        for c in 0..cout {
            db[c] += dy[r * cout + c];
        }
    }
    if let Some(dx) = dx {
        // dcols = dy @ W^T  (W [k,cout] row-major -> W^T is [cout,k])
        dcols.clear();
        dcols.resize(rows * k, 0.0);
        super::gemm::matmul_a_bt(gs, dy, wgt, dcols, rows, cout, k);
        col2im_same(dcols, b, h, w_, cin, kh, kw, dx);
    }
}

/// 2x2 max pool (stride 2). Records argmax for the backward pass.
/// x [b,h,w,c] -> y [b,h/2,w/2,c]; argmax stores the flat input index.
pub fn maxpool2(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    y: &mut Vec<f32>,
    argmax: &mut Vec<u32>,
) {
    let (oh, ow) = (h / 2, w / 2);
    y.clear();
    y.resize(b * oh * ow * c, 0.0);
    argmax.clear();
    argmax.resize(b * oh * ow * c, 0);
    for bi in 0..b {
        for i in 0..oh {
            for j in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for di in 0..2 {
                        for dj in 0..2 {
                            let src = ((bi * h + 2 * i + di) * w + 2 * j + dj) * c + ch;
                            if x[src] > best {
                                best = x[src];
                                bidx = src as u32;
                            }
                        }
                    }
                    let dst = ((bi * oh + i) * ow + j) * c + ch;
                    y[dst] = best;
                    argmax[dst] = bidx;
                }
            }
        }
    }
}

/// Backward of maxpool2: route dy to the recorded argmax positions.
pub fn maxpool2_bwd(dy: &[f32], argmax: &[u32], dx: &mut [f32]) {
    dx.iter_mut().for_each(|v| *v = 0.0);
    for (d, &i) in dy.iter().zip(argmax.iter()) {
        dx[i as usize] += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Naive direct convolution for cross-checking.
    #[allow(clippy::too_many_arguments)]
    fn conv_naive(
        x: &[f32],
        wgt: &[f32],
        bias: &[f32],
        b: usize,
        h: usize,
        w_: usize,
        cin: usize,
        kh: usize,
        kw: usize,
        cout: usize,
    ) -> Vec<f32> {
        let (ph, pw) = (kh / 2, kw / 2);
        let mut y = vec![0.0f32; b * h * w_ * cout];
        for bi in 0..b {
            for i in 0..h {
                for j in 0..w_ {
                    for co in 0..cout {
                        let mut acc = bias[co];
                        for ki in 0..kh {
                            for kj in 0..kw {
                                let si = i as isize + ki as isize - ph as isize;
                                let sj = j as isize + kj as isize - pw as isize;
                                if si < 0 || sj < 0 || si >= h as isize || sj >= w_ as isize {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let xv = x[((bi * h + si as usize) * w_ + sj as usize) * cin + ci];
                                    let wv = wgt[((ki * kw + kj) * cin + ci) * cout + co];
                                    acc += xv * wv;
                                }
                            }
                        }
                        y[((bi * h + i) * w_ + j) * cout + co] = acc;
                    }
                }
            }
        }
        y
    }

    #[test]
    fn conv_matches_naive() {
        let (b, h, w_, cin, kh, kw, cout) = (2, 6, 5, 3, 3, 3, 4);
        let mut rng = Pcg32::seeded(1);
        let x = rng.normal_vec(b * h * w_ * cin, 1.0);
        let wgt = rng.normal_vec(kh * kw * cin * cout, 0.5);
        let bias = rng.normal_vec(cout, 0.1);
        let mut cols = Vec::new();
        let mut gs = super::super::gemm::GemmScratch::default();
        let mut y = Vec::new();
        conv2d_same(&x, &wgt, &bias, b, h, w_, cin, kh, kw, cout, &mut cols, &mut gs, &mut y);
        let want = conv_naive(&x, &wgt, &bias, b, h, w_, cin, kh, kw, cout);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn conv_bwd_matches_finite_difference() {
        let (b, h, w_, cin, kh, kw, cout) = (1, 4, 4, 2, 3, 3, 2);
        let mut rng = Pcg32::seeded(2);
        let x = rng.normal_vec(b * h * w_ * cin, 1.0);
        let wgt = rng.normal_vec(kh * kw * cin * cout, 0.5);
        let bias = vec![0.0; cout];
        // loss = sum(y * m) for a fixed random mask m -> dy = m
        let m = rng.normal_vec(b * h * w_ * cout, 1.0);
        let loss = |x: &[f32], wgt: &[f32]| -> f32 {
            let mut cols = Vec::new();
            let mut gs = super::super::gemm::GemmScratch::default();
            let mut y = Vec::new();
            conv2d_same(x, wgt, &bias, b, h, w_, cin, kh, kw, cout, &mut cols, &mut gs, &mut y);
            y.iter().zip(m.iter()).map(|(a, b)| a * b).sum()
        };
        let mut cols = Vec::new();
        let mut gs = super::super::gemm::GemmScratch::default();
        let mut dcols = Vec::new();
        let mut dw = vec![0.0; wgt.len()];
        let mut db = vec![0.0; cout];
        let mut dx = vec![0.0; x.len()];
        conv2d_same_bwd(
            &x, &wgt, &m, b, h, w_, cin, kh, kw, cout, &mut cols, &mut gs, &mut dcols, &mut dw,
            &mut db, Some(&mut dx),
        );
        let eps = 1e-3;
        for idx in [0usize, 7, wgt.len() - 1] {
            let mut wp = wgt.clone();
            wp[idx] += eps;
            let mut wm = wgt.clone();
            wm[idx] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!((num - dw[idx]).abs() < 1e-2, "dw[{idx}] {num} vs {}", dw[idx]);
        }
        for idx in [0usize, 13, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (loss(&xp, &wgt) - loss(&xm, &wgt)) / (2.0 * eps);
            assert!((num - dx[idx]).abs() < 1e-2, "dx[{idx}] {num} vs {}", dx[idx]);
        }
    }

    #[test]
    fn maxpool_roundtrip() {
        let (b, h, w_, c) = (1, 4, 4, 2);
        let mut rng = Pcg32::seeded(3);
        let x = rng.normal_vec(b * h * w_ * c, 1.0);
        let mut y = Vec::new();
        let mut am = Vec::new();
        maxpool2(&x, b, h, w_, c, &mut y, &mut am);
        assert_eq!(y.len(), 2 * 2 * 2);
        // every output is the max of its window
        for (dst, &src) in am.iter().enumerate() {
            assert_eq!(y[dst], x[src as usize]);
        }
        // backward routes gradient to argmax only
        let dy = vec![1.0f32; y.len()];
        let mut dx = vec![0.0f32; x.len()];
        maxpool2_bwd(&dy, &am, &mut dx);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), y.len());
    }
}
