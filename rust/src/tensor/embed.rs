//! Embedding-table kernels for the native layer-graph executor.
//!
//! A lookup table `[vocab, dim]` maps integer token ids to dense rows. The
//! forward is a gather (row copies); the backward is a scatter-add of the
//! output gradient rows into the table gradient — the classic sparse
//! embedding gradient, which is also why `LayerKind::Embed` compresses like
//! an fc/lstm layer under AdaComp (few rows touched per minibatch, large
//! residual build-up elsewhere; L_T default 500, see `compress::Config`).

/// y[r, :] = table[ids[r], :] for every row r. `y` is resized to
/// `ids.len() * dim`. Ids must be in `[0, vocab)` where
/// `vocab = table.len() / dim`.
pub fn gather(table: &[f32], ids: &[i32], dim: usize, y: &mut Vec<f32>) {
    assert_eq!(table.len() % dim, 0, "table len not a multiple of dim");
    let vocab = table.len() / dim;
    y.clear();
    y.resize(ids.len() * dim, 0.0);
    for (r, &id) in ids.iter().enumerate() {
        let id = id as usize;
        assert!(id < vocab, "token id {id} out of range (vocab {vocab})");
        y[r * dim..(r + 1) * dim].copy_from_slice(&table[id * dim..(id + 1) * dim]);
    }
}

/// dtable[ids[r], :] += dy[r, :] for every row r (accumulates — caller
/// zeroes `dtable` once per step). Repeated ids accumulate in row order,
/// so the result is deterministic.
pub fn scatter_add(dtable: &mut [f32], ids: &[i32], dim: usize, dy: &[f32]) {
    assert_eq!(dtable.len() % dim, 0, "table len not a multiple of dim");
    assert_eq!(dy.len(), ids.len() * dim, "dy/ids length mismatch");
    let vocab = dtable.len() / dim;
    for (r, &id) in ids.iter().enumerate() {
        let id = id as usize;
        assert!(id < vocab, "token id {id} out of range (vocab {vocab})");
        let dst = &mut dtable[id * dim..(id + 1) * dim];
        let src = &dy[r * dim..(r + 1) * dim];
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_copies_rows() {
        // vocab 3, dim 2
        let table = vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0];
        let mut y = Vec::new();
        gather(&table, &[2, 0, 2], 2, &mut y);
        assert_eq!(y, vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
    }

    #[test]
    fn scatter_accumulates_repeats() {
        let mut dt = vec![0.0f32; 6];
        let dy = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        scatter_add(&mut dt, &[1, 1, 0], 2, &dy);
        assert_eq!(dt, vec![5.0, 6.0, 4.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_id_panics() {
        let table = vec![0.0f32; 4];
        let mut y = Vec::new();
        gather(&table, &[2], 2, &mut y);
    }
}
