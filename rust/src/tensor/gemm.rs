//! Packed, cache-blocked f32 GEMM — the compute kernel behind every matmul
//! in the native executor (DESIGN.md §Compute kernels).
//!
//! BLIS-style 5-loop blocking: the operand matrices are copied into packed
//! panels (`GemmScratch`, pooled across calls — zero steady-state
//! allocation) and the innermost tile is a register-resident MR x NR
//! microkernel. Two microkernel implementations sit behind one runtime
//! dispatch, per the vbyte.rs precedent:
//!
//! * AVX2+FMA (x86_64, detected at runtime via `is_x86_feature_detected!`,
//!   forced off by `ADACOMP_NO_SIMD=1`): 12 ymm accumulators (6 rows x two
//!   8-lane halves), one `vfmadd` per accumulator per k.
//! * scalar fallback: the *same* packing, tiling and accumulation order,
//!   with each lane's fused multiply-add done by `f32::mul_add` (correctly
//!   rounded, IEEE-754 `fusedMultiplyAdd` — exactly what the hardware FMA
//!   computes per lane).
//!
//! Because both paths execute identical FP operations in identical order on
//! identically packed data, their outputs are **bit-identical** — the
//! determinism contract (bit-equal across thread counts, exchange modes and
//! ISA paths) holds by construction, pinned by
//! rust/tests/kernel_equivalence.rs. The trade-off is also the vbyte one:
//! without the compile-time `fma` target feature `f32::mul_add` lowers to a
//! libm call, so the scalar lane is the correctness/portability path, not a
//! fast path.
//!
//! All three matmul layouts (`A@B`, `Aᵀ@B`, `A@Bᵀ`) route through one
//! strided driver — transposition is just a (row-stride, col-stride) choice
//! at packing time, so no variant pays a materialized transpose. Inner
//! loops are branch-free in the data (no `if av == 0.0` skips — the old
//! naive kernels' input-dependent timing is gone with them).
//!
//! **Within-learner parallelism.** Above the microkernel, the macro loops
//! fan out over the shared compute pool (`tensor::parallel`): C is cut
//! into a static grid of (MC row-block × NR-panel column-chunk) units, and
//! each unit is packed and accumulated end-to-end by exactly one pool slot
//! — its own KC loop, in ascending-`pc` order, into its own scratch shard.
//! Per C element the accumulation is therefore the *same* fmadd chain the
//! single-threaded kernel runs (the KC partition of k never changes, and
//! the jc/ic split never touches FP order), so results are bit-identical
//! at every thread count — the same contract as SIMD-vs-scalar, pinned by
//! rust/tests/kernel_equivalence.rs. The public wrappers read the global
//! core budget (`parallel::kernel_threads()`, derived by the engine from
//! `threads / active_learners` and re-derived at membership epochs);
//! `gemm_with_threads` pins an explicit count for tests and benches. Small
//! products (under [`MIN_PAR_FLOPS`]) stay serial — the fork-join handoff
//! would cost more than it buys.

use std::sync::OnceLock;

use crate::tensor::parallel;

/// Microkernel tile height (rows of C per tile).
pub const MR: usize = 6;
/// Microkernel tile width (cols of C per tile) — two 8-lane ymm halves.
pub const NR: usize = 16;
/// k-blocking: one packed A panel strip (MC x KC) stays L2-resident.
const KC: usize = 256;
/// m-blocking: rows of A packed per strip.
const MC: usize = 96;
/// n-blocking: cap on the packed B panel width.
const NC: usize = 1024;
/// Products below this flop count (2·m·k·n) always run serially: the
/// fork-join handoff (~µs) would dominate the kernel itself. Deterministic
/// in the shape, so the serial/parallel decision is too.
pub const MIN_PAR_FLOPS: u64 = 4_000_000;

/// One pool slot's packing buffers (an A micro-panel block and a B
/// micro-panel chunk). Grows to the high-water block size on first use.
#[derive(Debug, Default, Clone)]
struct PackBufs {
    a_pack: Vec<f32>,
    b_pack: Vec<f32>,
}

/// Pooled packing buffers for one executor, sharded by pool slot: shard 0
/// serves the serial path, shard `w` is owned exclusively by slot `w` of a
/// parallel invocation — no cross-worker contention, no locking. Shards
/// grow to their high-water block size on first use, then every later call
/// reuses the capacity — the steady-state GEMM is allocation-free
/// (rust/tests/alloc_free.rs).
#[derive(Debug, Default, Clone)]
pub struct GemmScratch {
    shards: Vec<PackBufs>,
}

impl GemmScratch {
    fn ensure_shards(&mut self, n: usize) {
        if self.shards.len() < n {
            self.shards.resize_with(n, PackBufs::default);
        }
    }
}

/// True when the AVX2+FMA microkernel is in use: compiled for x86_64, the
/// CPU reports both features, and `ADACOMP_NO_SIMD` is unset/empty. Cached
/// after the first call (which reads the environment once).
pub fn simd_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let forced_off = std::env::var_os("ADACOMP_NO_SIMD")
            .map(|v| !v.is_empty())
            .unwrap_or(false);
        if forced_off {
            return false;
        }
        #[cfg(target_arch = "x86_64")]
        {
            std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// C[m,n] = A[m,k] @ B[k,n]  (+= if `accumulate`). Both row-major.
pub fn matmul(
    s: &mut GemmScratch,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    gemm_with(!simd_enabled(), s, a, k, 1, b, n, 1, c, m, k, n, accumulate);
}

/// C[m,n] = Aᵀ @ B  (+= if `accumulate`), A stored row-major as [k, m].
pub fn matmul_at_b(
    s: &mut GemmScratch,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    gemm_with(!simd_enabled(), s, a, 1, m, b, n, 1, c, m, k, n, accumulate);
}

/// C[m,n] = A @ Bᵀ, B stored row-major as [n, k]. Overwrites C.
pub fn matmul_a_bt(
    s: &mut GemmScratch,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    gemm_with(!simd_enabled(), s, a, k, 1, b, 1, k, c, m, k, n, false);
}

/// The strided driver: C[m,n] (row-major) = op(A) @ op(B), where element
/// (i, p) of the effective A is `a[i * rs_a + p * cs_a]` and element (p, j)
/// of the effective B is `b[p * rs_b + j * cs_b]`.
///
/// `force_scalar` pins the scalar microkernel regardless of CPU features —
/// the cross-comparison entry point for tests and benches (the public
/// wrappers pass `!simd_enabled()`). The thread count comes from the
/// global core budget; results are identical at every value.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with(
    force_scalar: bool,
    s: &mut GemmScratch,
    a: &[f32],
    rs_a: usize,
    cs_a: usize,
    b: &[f32],
    rs_b: usize,
    cs_b: usize,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    gemm_with_threads(
        force_scalar,
        parallel::kernel_threads(),
        s,
        a,
        rs_a,
        cs_a,
        b,
        rs_b,
        cs_b,
        c,
        m,
        k,
        n,
        accumulate,
    );
}

/// [`gemm_with`] at an explicit kernel-thread count — the entry point for
/// the parallel-equivalence tests and the bench's 1-vs-N sweep. `threads`
/// caps the pool slots used; the C-tile grid, per-unit KC order, and hence
/// every FP operation per C element are independent of it, so the output
/// is bit-identical for every value (including 1).
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_threads(
    force_scalar: bool,
    threads: usize,
    s: &mut GemmScratch,
    a: &[f32],
    rs_a: usize,
    cs_a: usize,
    b: &[f32],
    rs_b: usize,
    cs_b: usize,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    assert_eq!(c.len(), m * n, "C length must be m*n");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            c.iter_mut().for_each(|x| *x = 0.0);
        }
        return;
    }
    debug_assert!((m - 1) * rs_a + (k - 1) * cs_a < a.len(), "A view out of bounds");
    debug_assert!((k - 1) * rs_b + (n - 1) * cs_b < b.len(), "B view out of bounds");
    let simd = !force_scalar && simd_enabled();
    let c_len = c.len();
    let cp = SendPtr(c.as_mut_ptr());

    if let Some(grid) = Grid::plan(m, k, n, threads) {
        s.ensure_shards(grid.nslots);
        let shards = ShardsPtr(s.shards.as_mut_ptr());
        parallel::parallel_for(grid.nslots, &|slot| {
            // SAFETY: shard `slot` is owned exclusively by this slot for
            // the duration of the call (ensure_shards sized the vec), and
            // the units assigned to a slot write disjoint C tiles — the
            // grid partitions C, and each unit is run by exactly one slot.
            let bufs = unsafe { &mut *shards.0.add(slot) };
            for u in grid.units_for(slot) {
                let (i0, i1, j0, j1) = grid.unit(u);
                run_span(
                    simd, bufs, a, rs_a, cs_a, b, rs_b, cs_b, cp, c_len, n, i0, i1, k, j0,
                    j1, accumulate,
                );
            }
        });
    } else {
        // Serial: one slot walks the NC column chunks in order — the exact
        // macro-loop order the pre-parallel kernel ran.
        s.ensure_shards(1);
        let bufs = &mut s.shards[0];
        for jc in (0..n).step_by(NC) {
            let j1 = n.min(jc + NC);
            run_span(
                simd, bufs, a, rs_a, cs_a, b, rs_b, cs_b, cp, c_len, n, 0, m, k, jc, j1,
                accumulate,
            );
        }
    }
}

/// Raw C base pointer, shared across pool slots. Sound because the unit
/// grid hands every C tile to exactly one slot (static ownership).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Raw pointer to the scratch-shard array; slot `w` touches only shard `w`.
#[derive(Clone, Copy)]
struct ShardsPtr(*mut PackBufs);
unsafe impl Send for ShardsPtr {}
unsafe impl Sync for ShardsPtr {}

/// The static C-tile partition for one parallel GEMM: a `row_blocks x
/// col_chunks` grid of units, row blocks MC-aligned and column chunks
/// NR-panel-aligned (capped at NC wide, so packed-B shards stay bounded).
/// Unit boundaries depend only on (m, n, threads) — never on data — and
/// every unit runs its full KC loop privately, so any assignment of units
/// to slots yields bit-identical C.
struct Grid {
    col_chunks: usize,
    chunk_cols: usize,
    units: usize,
    nslots: usize,
    m: usize,
    n: usize,
}

impl Grid {
    fn plan(m: usize, k: usize, n: usize, threads: usize) -> Option<Grid> {
        if threads <= 1 {
            return None;
        }
        if 2 * (m as u64) * (k as u64) * (n as u64) < MIN_PAR_FLOPS {
            return None;
        }
        let row_blocks = m.div_ceil(MC);
        let n_panels = n.div_ceil(NR);
        // start from the chunking the serial kernel uses (NC-wide), then
        // split columns finer until the grid has at least `threads` units
        // (real model shapes are often a single NC x MC macro-tile)
        let mut col_chunks = n_panels.div_ceil(NC / NR);
        while row_blocks * col_chunks < threads && col_chunks < n_panels {
            col_chunks += 1;
        }
        let chunk_panels = n_panels.div_ceil(col_chunks);
        let col_chunks = n_panels.div_ceil(chunk_panels);
        let units = row_blocks * col_chunks;
        if units <= 1 {
            return None;
        }
        Some(Grid {
            col_chunks,
            chunk_cols: chunk_panels * NR,
            units,
            nslots: threads.min(units),
            m,
            n,
        })
    }

    /// Unit `u`'s C tile: rows `[i0, i1)`, cols `[j0, j1)`.
    fn unit(&self, u: usize) -> (usize, usize, usize, usize) {
        let (rb, cc) = (u / self.col_chunks, u % self.col_chunks);
        let i0 = rb * MC;
        let j0 = cc * self.chunk_cols;
        (i0, self.m.min(i0 + MC), j0, self.n.min(j0 + self.chunk_cols))
    }

    /// Slot `w`'s contiguous unit range — the static ownership map.
    fn units_for(&self, slot: usize) -> std::ops::Range<usize> {
        let (q, r) = (self.units / self.nslots, self.units % self.nslots);
        let start = slot * q + slot.min(r);
        start..start + q + usize::from(slot < r)
    }
}

/// One C span (rows `[i0, i1)` x cols `[j0, j1)`): the full blocked KC loop
/// over that region, packing into this slot's private `bufs`. The serial
/// kernel is exactly this with `[0, m) x [jc, jc+NC)` spans in ascending
/// `jc` order; parallel units are `[MC block) x [NR-panel chunk)` spans.
/// Per C element the FP operations and their order are identical either
/// way, which is the whole bit-identity argument.
#[allow(clippy::too_many_arguments)]
fn run_span(
    simd: bool,
    bufs: &mut PackBufs,
    a: &[f32],
    rs_a: usize,
    cs_a: usize,
    b: &[f32],
    rs_b: usize,
    cs_b: usize,
    c: SendPtr,
    c_len: usize,
    ldc: usize,
    i0: usize,
    i1: usize,
    k: usize,
    j0: usize,
    j1: usize,
    accumulate: bool,
) {
    let nc = j1 - j0;
    let nb_panels = nc.div_ceil(NR);
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        ensure_len(&mut bufs.b_pack, nb_panels * kc * NR);
        pack_b(&mut bufs.b_pack, b, rs_b, cs_b, j0, nc, pc, kc);
        // The first k-panel honors `accumulate`; every later panel adds
        // onto the partial products already in C.
        let acc_into = accumulate || pc > 0;
        for ic in (i0..i1).step_by(MC) {
            let mc = MC.min(i1 - ic);
            let ma_panels = mc.div_ceil(MR);
            ensure_len(&mut bufs.a_pack, ma_panels * kc * MR);
            pack_a(&mut bufs.a_pack, a, rs_a, cs_a, ic, mc, pc, kc);
            for jp in 0..nb_panels {
                let col0 = j0 + jp * NR;
                let nr_eff = NR.min(nc - jp * NR);
                let bp = &bufs.b_pack[jp * kc * NR..][..kc * NR];
                for ip in 0..ma_panels {
                    let row0 = ic + ip * MR;
                    let mr_eff = MR.min(mc - ip * MR);
                    let ap = &bufs.a_pack[ip * kc * MR..][..kc * MR];
                    debug_assert!(
                        row0 * ldc + col0 + (mr_eff - 1) * ldc + nr_eff <= c_len,
                        "C tile out of bounds"
                    );
                    // SAFETY: the tile [row0.., col0..] is in bounds (assert
                    // above) and owned exclusively by this span.
                    let tile = unsafe { c.0.add(row0 * ldc + col0) };
                    micro_dispatch(simd, kc, ap, bp, tile, ldc, mr_eff, nr_eff, acc_into);
                }
            }
        }
    }
}

#[inline]
fn ensure_len(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

/// Software-prefetch the source strip starting at `p` into L1. Value- and
/// order-neutral by definition — a prefetch never changes architectural
/// state — so the determinism contract is untouched. No-op off x86_64.
#[inline(always)]
fn prefetch(p: *const f32) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch has no architectural effect and may not fault; the
    // callers pass in-bounds addresses anyway.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Pack an `mc x kc` block of the effective A into MR-row micro-panels:
/// panel `ip` holds k-major groups of MR consecutive row values, rows past
/// `mc` zero-padded. Padding rows multiply into lanes whose results are
/// never written back, so it is FP-neutral. While copying depth `p`, the
/// next depth's source strip is prefetched — A's k-stride walk is the
/// cache-hostile access of the two packs (`cs` is the matrix row length
/// for the plain layout).
fn pack_a(dst: &mut [f32], a: &[f32], rs: usize, cs: usize, ic: usize, mc: usize, pc: usize, kc: usize) {
    for ip in 0..mc.div_ceil(MR) {
        let base = ip * MR;
        let pbase = ip * kc * MR;
        for p in 0..kc {
            let col = (pc + p) * cs;
            if p + 1 < kc {
                prefetch(unsafe { a.as_ptr().add((ic + base) * rs + col + cs) });
            }
            let d = pbase + p * MR;
            for r in 0..MR {
                let row = base + r;
                dst[d + r] = if row < mc { a[(ic + row) * rs + col] } else { 0.0 };
            }
        }
    }
}

/// Pack a `kc x nc` block of the effective B into NR-column micro-panels:
/// panel `jp` holds k-major groups of NR consecutive column values, columns
/// past `nc` zero-padded (FP-neutral, as with A). Prefetches the next
/// depth's source strip while copying the current one.
fn pack_b(dst: &mut [f32], b: &[f32], rs: usize, cs: usize, jc: usize, nc: usize, pc: usize, kc: usize) {
    for jp in 0..nc.div_ceil(NR) {
        let base = jp * NR;
        let pbase = jp * kc * NR;
        for p in 0..kc {
            let row = (pc + p) * rs;
            if p + 1 < kc {
                prefetch(unsafe { b.as_ptr().add(row + rs + (jc + base) * cs) });
            }
            let d = pbase + p * NR;
            for j in 0..NR {
                let col = base + j;
                dst[d + j] = if col < nc { b[row + (jc + col) * cs] } else { 0.0 };
            }
        }
    }
}

/// Dispatch one micro-tile. `c` points at the tile's top-left element; the
/// caller (the span runner) owns the `mr_eff x nr_eff` region exclusively
/// and has bounds-checked it — raw pointers here because concurrent spans
/// legally interleave within one C allocation (disjoint tiles), which a
/// shared `&mut [f32]` could not express.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_dispatch(
    simd: bool,
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: *mut f32,
    ldc: usize,
    mr_eff: usize,
    nr_eff: usize,
    acc_into: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: `simd` implies AVX2+FMA were detected at runtime; `ap`/`bp`
        // hold kc full micro-panels; writes touch only the mr_eff x nr_eff
        // valid tile region, in bounds and exclusively owned per the caller.
        unsafe {
            mk_avx2(kc, ap.as_ptr(), bp.as_ptr(), c, ldc, mr_eff, nr_eff, acc_into);
        }
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    mk_scalar(kc, ap, bp, c, ldc, mr_eff, nr_eff, acc_into);
}

/// Scalar microkernel: the exact FP-operation mirror of [`mk_avx2`]. Each
/// accumulator lane performs one correctly-rounded fused multiply-add per k
/// (`f32::mul_add` == per-lane `vfmadd`), and the write-out does the same
/// single add (or overwrite) the vector path does — so the two paths agree
/// bit-for-bit on every output.
#[allow(clippy::too_many_arguments)]
fn mk_scalar(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: *mut f32,
    ldc: usize,
    mr_eff: usize,
    nr_eff: usize,
    acc_into: bool,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let av = &ap[p * MR..p * MR + MR];
        let bv = &bp[p * NR..p * NR + NR];
        for (accr, &ar) in acc.iter_mut().zip(av) {
            for (al, &bl) in accr.iter_mut().zip(bv) {
                *al = ar.mul_add(bl, *al);
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr_eff) {
        // SAFETY: each tile row segment is in bounds and exclusively owned
        // by this tile (see micro_dispatch) — rows of concurrent tiles
        // never overlap, so the short-lived &mut slices are unique.
        let row = unsafe { std::slice::from_raw_parts_mut(c.add(r * ldc), nr_eff) };
        if acc_into {
            for (dst, &v) in row.iter_mut().zip(accr.iter()) {
                *dst += v;
            }
        } else {
            row.copy_from_slice(&accr[..nr_eff]);
        }
    }
}

/// AVX2+FMA microkernel: 6x16 tile in 12 ymm accumulators. `c` points at
/// the tile's top-left element; partial tiles spill to a stack tile and
/// copy back only the valid region.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn mk_avx2(
    kc: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f32,
    ldc: usize,
    mr_eff: usize,
    nr_eff: usize,
    acc_into: bool,
) {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); 2 * MR];
    for p in 0..kc {
        let b0 = _mm256_loadu_ps(bp.add(p * NR));
        let b1 = _mm256_loadu_ps(bp.add(p * NR + 8));
        for r in 0..MR {
            let av = _mm256_set1_ps(*ap.add(p * MR + r));
            acc[2 * r] = _mm256_fmadd_ps(av, b0, acc[2 * r]);
            acc[2 * r + 1] = _mm256_fmadd_ps(av, b1, acc[2 * r + 1]);
        }
    }
    if mr_eff == MR && nr_eff == NR {
        for r in 0..MR {
            let pr = c.add(r * ldc);
            let (mut v0, mut v1) = (acc[2 * r], acc[2 * r + 1]);
            if acc_into {
                v0 = _mm256_add_ps(_mm256_loadu_ps(pr), v0);
                v1 = _mm256_add_ps(_mm256_loadu_ps(pr.add(8)), v1);
            }
            _mm256_storeu_ps(pr, v0);
            _mm256_storeu_ps(pr.add(8), v1);
        }
    } else {
        let mut tile = [0.0f32; MR * NR];
        for r in 0..MR {
            _mm256_storeu_ps(tile.as_mut_ptr().add(r * NR), acc[2 * r]);
            _mm256_storeu_ps(tile.as_mut_ptr().add(r * NR + 8), acc[2 * r + 1]);
        }
        for r in 0..mr_eff {
            for j in 0..nr_eff {
                let dst = c.add(r * ldc + j);
                let v = tile[r * NR + j];
                if acc_into {
                    *dst += v;
                } else {
                    *dst = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// f64 reference, plain ijk — the correctness oracle.
    fn naive_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as f64;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as f64;
                }
            }
        }
        c.iter().map(|&x| x as f32).collect()
    }

    fn close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!((g - w).abs() <= tol * w.abs().max(1.0), "[{i}] {g} vs {w}");
        }
    }

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::seeded(seed);
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn matmul_small_identity() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        let mut s = GemmScratch::default();
        matmul(&mut s, &a, &b, &mut c, 2, 2, 2, false);
        assert_eq!(c, a);
    }

    #[test]
    fn ragged_shapes_match_reference() {
        let mut s = GemmScratch::default();
        // shapes chosen to hit partial MR, partial NR, multi-KC and multi-MC
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (5, 3, 17),
            (6, 300, 16),
            (7, 257, 33),
            (97, 64, 10),
            (130, 520, 19),
        ] {
            let a = fill(m * k, 1 + m as u64);
            let b = fill(k * n, 2 + n as u64);
            let mut c = vec![0.0; m * n];
            matmul(&mut s, &a, &b, &mut c, m, k, n, false);
            close(&c, &naive_ref(&a, &b, m, k, n), 1e-4);
        }
    }

    #[test]
    fn accumulate_adds_onto_existing() {
        let (m, k, n) = (9usize, 37usize, 21usize);
        let a = fill(m * k, 3);
        let b = fill(k * n, 4);
        let init = fill(m * n, 5);
        let mut s = GemmScratch::default();
        let mut c = init.clone();
        matmul(&mut s, &a, &b, &mut c, m, k, n, true);
        let mut want = naive_ref(&a, &b, m, k, n);
        for (w, i) in want.iter_mut().zip(init.iter()) {
            *w += i;
        }
        close(&c, &want, 1e-4);
    }

    #[test]
    fn transposes_agree_with_plain() {
        let (m, k, n) = (13usize, 29usize, 18usize);
        let a = fill(m * k, 6);
        let b = fill(k * n, 7);
        let mut s = GemmScratch::default();
        let mut c = vec![0.0; m * n];
        matmul(&mut s, &a, &b, &mut c, m, k, n, false);

        // A^T stored as [k, m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul_at_b(&mut s, &at, &b, &mut c2, m, k, n, false);
        // same packed values, same accumulation order -> bitwise equal
        assert_eq!(c, c2);

        // B^T stored as [n, k]
        let mut bt = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut c3 = vec![0.0; m * n];
        matmul_a_bt(&mut s, &a, &bt, &mut c3, m, k, n);
        assert_eq!(c, c3);
    }

    #[test]
    fn at_b_accumulate_matches_two_rounds() {
        let (m, k, n) = (11usize, 8usize, 40usize);
        let at = fill(k * m, 8);
        let b = fill(k * n, 9);
        let mut s = GemmScratch::default();
        let mut once = vec![0.0; m * n];
        matmul_at_b(&mut s, &at, &b, &mut once, m, k, n, false);
        let mut acc = once.clone();
        matmul_at_b(&mut s, &at, &b, &mut acc, m, k, n, true);
        close(
            &acc,
            &once.iter().map(|v| 2.0 * v).collect::<Vec<_>>(),
            1e-5,
        );
    }

    #[test]
    fn k_zero_zeroes_or_preserves() {
        let mut s = GemmScratch::default();
        let mut c = vec![7.0f32; 6];
        matmul(&mut s, &[], &[], &mut c, 2, 0, 3, false);
        assert!(c.iter().all(|&v| v == 0.0));
        let mut c = vec![7.0f32; 6];
        matmul(&mut s, &[], &[], &mut c, 2, 0, 3, true);
        assert!(c.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn forced_scalar_is_bit_identical_to_dispatch() {
        // the detailed sweep lives in tests/kernel_equivalence.rs; this is
        // the in-module smoke for one ragged multi-panel shape
        let (m, k, n) = (23usize, 301usize, 41usize);
        let a = fill(m * k, 10);
        let b = fill(k * n, 11);
        let mut s = GemmScratch::default();
        let mut auto_c = vec![0.0; m * n];
        matmul(&mut s, &a, &b, &mut auto_c, m, k, n, false);
        let mut scalar_c = vec![0.0; m * n];
        gemm_with(true, &mut s, &a, k, 1, &b, n, 1, &mut scalar_c, m, k, n, false);
        let ab: Vec<u32> = auto_c.iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = scalar_c.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, sb);
    }
}
