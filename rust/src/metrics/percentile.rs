//! Percentile estimation for Fig 5 (95th percentile of |dW| and |RG|).
//!
//! Exact selection via quickselect on a scratch copy — O(N) expected, no
//! full sort (matching the paper's computational argument).

/// p-th percentile (0..=100) of |values|. Returns 0 for empty input.
pub fn percentile(values: &[f32], p: f64) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    let mut scratch: Vec<f32> = values.iter().map(|x| x.abs()).collect();
    let rank = (((p / 100.0) * (scratch.len() - 1) as f64).round() as usize)
        .min(scratch.len() - 1);
    *order_stat(&mut scratch, rank)
}

/// k-th smallest (0-based) via iterative median-of-three quickselect.
fn order_stat(s: &mut [f32], k: usize) -> &f32 {
    let (mut lo, mut hi) = (0usize, s.len());
    loop {
        if hi - lo <= 1 {
            return &s[lo];
        }
        let mid = lo + (hi - lo) / 2;
        // median-of-three pivot
        let (a, b, c) = (s[lo], s[mid], s[hi - 1]);
        let pivot = a.max(b).min(a.min(b).max(c));
        let (mut i, mut j, mut m) = (lo, lo, hi);
        while j < m {
            if s[j] < pivot {
                s.swap(i, j);
                i += 1;
                j += 1;
            } else if s[j] > pivot {
                m -= 1;
                s.swap(j, m);
            } else {
                j += 1;
            }
        }
        if k < i {
            hi = i;
        } else if k < m {
            return &s[k];
        } else {
            lo = m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn matches_sort_based() {
        let mut rng = Pcg32::seeded(1);
        for n in [1usize, 2, 10, 1000, 4097] {
            let xs = rng.normal_vec(n, 1.0);
            for p in [0.0, 50.0, 95.0, 100.0] {
                let got = percentile(&xs, p);
                let mut sorted: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let rank = (((p / 100.0) * (n - 1) as f64).round() as usize).min(n - 1);
                assert_eq!(got, sorted[rank], "n={n} p={p}");
            }
        }
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(percentile(&[], 95.0), 0.0);
    }

    #[test]
    fn absolute_values() {
        assert_eq!(percentile(&[-10.0, 1.0], 100.0), 10.0);
    }
}
