//! Training metrics: everything the paper's figures plot.
//!
//! * per-epoch convergence records (Fig 2/3, Table 2)
//! * per-layer compression statistics (Fig 4/7, the ~40x/~200x headline)
//! * percentile tracking of |dW| and |RG| (Fig 5)
//! * residual-gradient histograms (Fig 6)

pub mod histogram;
pub mod percentile;

pub use histogram::LogHistogram;
pub use percentile::percentile;

use crate::util::json::{self, Json};

/// Per-layer compression accounting accumulated over an epoch.
#[derive(Debug, Clone, Default)]
pub struct CompStat {
    pub elements: u64,
    pub sent: u64,
    pub wire_bytes: u64,
    pub paper_bits: u64,
}

impl CompStat {
    pub fn add(&mut self, p: &crate::compress::Packet) {
        self.elements += p.n as u64;
        self.sent += p.sent() as u64;
        self.wire_bytes += p.wire_bytes as u64;
        self.paper_bits += p.paper_bits as u64;
    }

    /// Effective compression rate vs dense f32, from real wire bytes.
    pub fn rate_wire(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            4.0 * self.elements as f64 / self.wire_bytes as f64
        }
    }

    /// The paper's idealized accounting.
    pub fn rate_paper(&self) -> f64 {
        if self.paper_bits == 0 {
            1.0
        } else {
            32.0 * self.elements as f64 / self.paper_bits as f64
        }
    }

    pub fn sparsity(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.sent as f64 / self.elements as f64
        }
    }
}

/// One epoch's record.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_error_pct: f64,
    pub test_loss: f64,
    pub lr: f32,
    /// Aggregated over conv layers / over fc+lstm layers / over all.
    pub comp_conv: CompStat,
    pub comp_fc: CompStat,
    pub comp_all: CompStat,
    /// 95th percentile of |residual gradient| (largest over layers), Fig 5.
    pub rg_p95: f32,
    /// 95th percentile of |dW| (largest over layers), Fig 5.
    pub dw_p95: f32,
    pub wall_secs: f64,
}

/// Full run record: convergence curve + provenance.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub name: String,
    pub model: String,
    pub scheme: String,
    pub learners: usize,
    pub batch_per_learner: usize,
    pub optimizer: String,
    pub epochs: Vec<EpochRecord>,
    pub diverged: bool,
    pub fabric: crate::comm::FabricStats,
}

impl RunRecord {
    pub fn final_test_error(&self) -> f64 {
        self.epochs.last().map(|e| e.test_error_pct).unwrap_or(100.0)
    }

    /// Best (lowest) test error over the run — the paper reports final, but
    /// best is useful for stress-test tables.
    pub fn best_test_error(&self) -> f64 {
        self.epochs
            .iter()
            .map(|e| e.test_error_pct)
            .fold(100.0, f64::min)
    }

    /// Mean effective compression rate over the run (wire accounting).
    pub fn mean_rate_wire(&self) -> f64 {
        let (mut el, mut by) = (0u64, 0u64);
        for e in &self.epochs {
            el += e.comp_all.elements;
            by += e.comp_all.wire_bytes;
        }
        if by == 0 {
            1.0
        } else {
            4.0 * el as f64 / by as f64
        }
    }

    pub fn mean_rate_paper(&self) -> f64 {
        let (mut el, mut bits) = (0u64, 0u64);
        for e in &self.epochs {
            el += e.comp_all.elements;
            bits += e.comp_all.paper_bits;
        }
        if bits == 0 {
            1.0
        } else {
            32.0 * el as f64 / bits as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let comp = |c: &CompStat| {
            json::obj(vec![
                ("rate_wire", json::num(c.rate_wire())),
                ("rate_paper", json::num(c.rate_paper())),
                ("sparsity", json::num(c.sparsity())),
            ])
        };
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("model", json::s(&self.model)),
            ("scheme", json::s(&self.scheme)),
            ("learners", json::num(self.learners as f64)),
            ("batch_per_learner", json::num(self.batch_per_learner as f64)),
            ("optimizer", json::s(&self.optimizer)),
            ("diverged", Json::Bool(self.diverged)),
            ("final_test_error", json::num(self.final_test_error())),
            ("mean_rate_wire", json::num(self.mean_rate_wire())),
            ("mean_rate_paper", json::num(self.mean_rate_paper())),
            (
                "epochs",
                json::arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("epoch", json::num(e.epoch as f64)),
                                ("train_loss", json::num(e.train_loss)),
                                ("test_error_pct", json::num(e.test_error_pct)),
                                ("test_loss", json::num(e.test_loss)),
                                ("lr", json::num(e.lr as f64)),
                                ("rg_p95", json::num(e.rg_p95 as f64)),
                                ("dw_p95", json::num(e.dw_p95 as f64)),
                                ("comp_conv", comp(&e.comp_conv)),
                                ("comp_fc", comp(&e.comp_fc)),
                                ("comp_all", comp(&e.comp_all)),
                                ("wall_secs", json::num(e.wall_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "fabric",
                json::obj(vec![
                    ("bytes_up", json::num(self.fabric.bytes_up as f64)),
                    ("bytes_down", json::num(self.fabric.bytes_down as f64)),
                    ("rounds", json::num(self.fabric.rounds as f64)),
                    ("sim_time_s", json::num(self.fabric.sim_time_s)),
                    ("effective_rate", json::num(self.fabric.effective_rate())),
                    ("steps", json::num(self.fabric.steps as f64)),
                    ("sim_step_s", json::num(self.fabric.sim_step_s())),
                    ("sim_overlap_s", json::num(self.fabric.sim_overlap_s)),
                    ("sim_barrier_s", json::num(self.fabric.sim_barrier_s)),
                    ("sim_dense_s", json::num(self.fabric.sim_dense_s)),
                    ("projected_speedup", json::num(self.fabric.projected_speedup())),
                    ("stall_s", json::num(self.fabric.stall_s)),
                    (
                        "crit_share",
                        json::arr(self.fabric.crit_share().into_iter().map(json::num).collect()),
                    ),
                    ("rebuild_s", json::num(self.fabric.rebuild_s)),
                    ("drain_stall_s", json::num(self.fabric.drain_stall_s)),
                    ("lost_residual_l1", json::num(self.fabric.lost_residual_l1)),
                    ("handover_l1", json::num(self.fabric.handover_l1)),
                    (
                        "membership",
                        json::arr(
                            self.fabric
                                .membership
                                .iter()
                                .map(|m| {
                                    json::obj(vec![
                                        ("step", json::num(m.step as f64)),
                                        ("kind", json::s(&m.kind)),
                                        ("count", json::num(m.count as f64)),
                                        ("n_after", json::num(m.n_after as f64)),
                                        ("topology", json::s(&m.topology)),
                                        ("degraded", Json::Bool(m.degraded)),
                                        ("rebuild_s", json::num(m.rebuild_s)),
                                        ("drain_stall_s", json::num(m.drain_stall_s)),
                                        ("lost_l1", json::num(m.lost_l1)),
                                        ("handover_l1", json::num(m.handover_l1)),
                                        ("threshold_bytes", json::num(m.threshold_bytes as f64)),
                                        ("n_buckets", json::num(m.n_buckets as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("control_retunes", json::num(self.fabric.control_retunes as f64)),
                    (
                        "control",
                        json::arr(
                            self.fabric
                                .control
                                .iter()
                                .map(|d| {
                                    json::obj(vec![
                                        ("epoch", json::num(d.epoch as f64)),
                                        ("knob", json::s(&d.knob)),
                                        ("old", json::num(d.old)),
                                        ("new", json::num(d.new)),
                                        ("signal", json::s(&d.signal)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
    }

    /// Append a CSV row per epoch to a writer-friendly string.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "name,model,scheme,learners,epoch,train_loss,test_error_pct,rate_wire_all,rate_paper_all,rg_p95,dw_p95\n",
        );
        for e in &self.epochs {
            out.push_str(&format!(
                "{},{},{},{},{},{:.6},{:.3},{:.2},{:.2},{:.6e},{:.6e}\n",
                self.name,
                self.model,
                self.scheme,
                self.learners,
                e.epoch,
                e.train_loss,
                e.test_error_pct,
                e.comp_all.rate_wire(),
                e.comp_all.rate_paper(),
                e.rg_p95,
                e.dw_p95,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Packet;

    fn packet(n: usize, sent: usize) -> Packet {
        Packet {
            layer: 0,
            n,
            idx: (0..sent as u32).collect(),
            val: vec![1.0; sent],
            wire_bytes: sent + 16,
            paper_bits: 8 * sent,
        }
    }

    #[test]
    fn compstat_rates() {
        let mut c = CompStat::default();
        c.add(&packet(1000, 10));
        assert!((c.rate_wire() - 4000.0 / 26.0).abs() < 1e-9);
        assert!((c.rate_paper() - 32000.0 / 80.0).abs() < 1e-9);
        assert!((c.sparsity() - 0.01).abs() < 1e-12);
    }

    fn rec() -> RunRecord {
        let mut comp = CompStat::default();
        comp.add(&packet(100, 5));
        RunRecord {
            name: "t".into(),
            model: "m".into(),
            scheme: "adacomp".into(),
            learners: 2,
            batch_per_learner: 8,
            optimizer: "sgd".into(),
            epochs: vec![EpochRecord {
                epoch: 0,
                train_loss: 1.0,
                test_error_pct: 20.0,
                test_loss: 1.2,
                lr: 0.1,
                comp_conv: comp.clone(),
                comp_fc: CompStat::default(),
                comp_all: comp,
                rg_p95: 0.5,
                dw_p95: 0.1,
                wall_secs: 1.0,
            }],
            diverged: false,
            fabric: Default::default(),
        }
    }

    #[test]
    fn run_record_json_roundtrips() {
        let r = rec();
        let j = r.to_json().to_string();
        let v = Json::from_str_slice(&j).unwrap();
        assert_eq!(v.get("final_test_error").as_f64(), Some(20.0));
        assert_eq!(v.get("epochs").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn run_record_json_carries_membership_timeline() {
        let mut r = rec();
        r.fabric.membership.push(crate::comm::MembershipChange {
            step: 20,
            kind: "leave".into(),
            count: 1,
            n_after: 1,
            topology: "ps".into(),
            degraded: true,
            rebuild_s: 1e-3,
            drain_stall_s: 2e-3,
            lost_l1: 0.0,
            handover_l1: 4.25,
            threshold_bytes: 31250,
            n_buckets: 2,
        });
        r.fabric.handover_l1 = 4.25;
        let j = r.to_json().to_string();
        let v = Json::from_str_slice(&j).unwrap();
        let fab = v.get("fabric");
        assert_eq!(fab.get("handover_l1").as_f64(), Some(4.25));
        let ms = fab.get("membership").as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("kind").as_str(), Some("leave"));
        assert_eq!(ms[0].get("n_after").as_f64(), Some(1.0));
        assert_eq!(ms[0].get("threshold_bytes").as_f64(), Some(31250.0));
        assert_eq!(ms[0].get("n_buckets").as_f64(), Some(2.0));
    }

    #[test]
    fn run_record_json_carries_control_decision_timeline() {
        // the adaptive controller's per-epoch decisions land in the fabric
        // object: a knob trajectory a plotting script can replay
        let mut r = rec();
        r.fabric.control.push(crate::comm::ControlDecision {
            epoch: 1,
            knob: "staleness".into(),
            old: 1.0,
            new: 2.0,
            signal: "straggler_excess=0.210>0.1".into(),
        });
        r.fabric.control.push(crate::comm::ControlDecision {
            epoch: 2,
            knob: "lt:0".into(),
            old: 50.0,
            new: 100.0,
            signal: "comm_share=0.40 vs elems_share=0.10 (hot)".into(),
        });
        r.fabric.control_retunes = 2;
        let j = r.to_json().to_string();
        let v = Json::from_str_slice(&j).unwrap();
        let fab = v.get("fabric");
        assert_eq!(fab.get("control_retunes").as_f64(), Some(2.0));
        let ds = fab.get("control").as_arr().unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].get("knob").as_str(), Some("staleness"));
        assert_eq!(ds[0].get("old").as_f64(), Some(1.0));
        assert_eq!(ds[0].get("new").as_f64(), Some(2.0));
        assert!(ds[0]
            .get("signal")
            .as_str()
            .unwrap()
            .contains("straggler_excess"));
        assert_eq!(ds[1].get("knob").as_str(), Some("lt:0"));
        assert_eq!(ds[1].get("epoch").as_f64(), Some(2.0));
    }

    #[test]
    fn csv_has_rows() {
        let r = rec();
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("t,m,adacomp,2,0,"));
    }

    #[test]
    fn final_and_best() {
        let mut r = rec();
        let mut e2 = r.epochs[0].clone();
        e2.epoch = 1;
        e2.test_error_pct = 30.0;
        r.epochs.push(e2);
        assert_eq!(r.final_test_error(), 30.0);
        assert_eq!(r.best_test_error(), 20.0);
    }
}
