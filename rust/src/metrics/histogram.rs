//! Signed log-scale histogram for Fig 6 (residual-gradient distributions).
//!
//! The paper's Fig 6 plots RG histograms whose tails differ by *orders of
//! magnitude* between LS and AdaComp, so linear bins are useless; we bin by
//! sign x log2|x| with a configurable floor.

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Smallest magnitude resolved; everything below lands in the zero bin.
    pub floor: f32,
    /// log2 range covered above the floor.
    pub span: usize,
    /// counts[0..span] = negative side (largest magnitude first),
    /// counts[span] = zero bin, counts[span+1..] = positive side.
    counts: Vec<u64>,
}

impl LogHistogram {
    pub fn new(floor: f32, span: usize) -> LogHistogram {
        LogHistogram {
            floor,
            span,
            counts: vec![0; 2 * span + 1],
        }
    }

    #[inline]
    fn mag_bin(&self, x: f32) -> Option<usize> {
        let m = x.abs();
        if m < self.floor {
            return None;
        }
        let b = ((m / self.floor).log2().floor() as isize).clamp(0, self.span as isize - 1);
        Some(b as usize)
    }

    pub fn add(&mut self, x: f32) {
        match self.mag_bin(x) {
            None => self.counts[self.span] += 1,
            Some(b) => {
                if x < 0.0 {
                    self.counts[self.span - 1 - b] += 1;
                } else {
                    self.counts[self.span + 1 + b] += 1;
                }
            }
        }
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Largest |value| bucket edge that holds any mass — the "tail length"
    /// the paper's Fig 6 contrasts (LS: +/-240K; AdaComp: tiny).
    pub fn max_magnitude_edge(&self) -> f32 {
        let mut best: isize = -1;
        for b in 0..self.span {
            if self.counts[self.span - 1 - b] > 0 || self.counts[self.span + 1 + b] > 0 {
                best = best.max(b as isize);
            }
        }
        if best < 0 {
            0.0
        } else {
            self.floor * 2f32.powi(best as i32 + 1)
        }
    }

    /// (bucket center, count) pairs for plotting; negative side first.
    pub fn series(&self) -> Vec<(f32, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        for b in (0..self.span).rev() {
            let c = self.counts[self.span - 1 - b];
            out.push((-(self.floor * 2f32.powi(b as i32)), c));
        }
        out.push((0.0, self.counts[self.span]));
        for b in 0..self.span {
            let c = self.counts[self.span + 1 + b];
            out.push((self.floor * 2f32.powi(b as i32), c));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::arr(
            self.series()
                .into_iter()
                .map(|(edge, count)| {
                    json::obj(vec![
                        ("edge", json::num(edge as f64)),
                        ("count", json::num(count as f64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bin() {
        let mut h = LogHistogram::new(1e-3, 10);
        h.add(0.0);
        h.add(1e-4);
        assert_eq!(h.total(), 2);
        assert_eq!(h.max_magnitude_edge(), 0.0);
    }

    #[test]
    fn sign_split() {
        let mut h = LogHistogram::new(1.0, 4);
        h.add(1.5); // bin 0 positive
        h.add(-1.5); // bin 0 negative
        h.add(5.0); // bin 2 positive
        let s = h.series();
        assert_eq!(s.len(), 9);
        assert_eq!(h.total(), 3);
        // negative 1.5 in center-left bucket
        assert_eq!(s[3], (-1.0, 1));
        assert_eq!(s[5], (1.0, 1));
        assert_eq!(s[7], (4.0, 1));
    }

    #[test]
    fn tail_edge_grows() {
        let mut h = LogHistogram::new(1e-3, 40);
        h.add(0.5);
        let small = h.max_magnitude_edge();
        h.add(240_000.0);
        assert!(h.max_magnitude_edge() > small * 1e5);
    }

    #[test]
    fn clamps_huge_values() {
        let mut h = LogHistogram::new(1e-3, 8);
        h.add(1e30);
        assert_eq!(h.total(), 1);
        // lands in the last bin
        let s = h.series();
        assert_eq!(s.last().unwrap().1, 1);
    }
}
