//! Terminal tables + results files for the figure/table harnesses.

use std::path::Path;

use crate::metrics::RunRecord;
use crate::util::json::{self, Json};

/// Fixed-width table printer (the harnesses print the same rows/series the
/// paper's tables and figures report).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write run records (JSON array + CSV) under results/.
pub fn save_runs(tag: &str, runs: &[RunRecord]) -> std::io::Result<(String, String)> {
    std::fs::create_dir_all("results")?;
    let json_path = format!("results/{tag}.json");
    let csv_path = format!("results/{tag}.csv");
    let arr = json::arr(runs.iter().map(|r| r.to_json()).collect());
    std::fs::write(&json_path, arr.to_string())?;
    let mut csv = String::new();
    for (i, r) in runs.iter().enumerate() {
        let body = r.to_csv();
        if i == 0 {
            csv.push_str(&body);
        } else {
            // skip header
            csv.push_str(body.split_once('\n').map(|x| x.1).unwrap_or(""));
        }
    }
    std::fs::write(&csv_path, csv)?;
    Ok((json_path, csv_path))
}

/// Load previously saved runs (ablation/plot tooling).
pub fn load_runs(path: &Path) -> anyhow::Result<Json> {
    let txt = std::fs::read_to_string(path)?;
    Json::from_str_slice(&txt).map_err(|e| anyhow::anyhow!("{e}"))
}

/// One-line convergence summary for live output.
pub fn epoch_line(r: &RunRecord) -> String {
    let e = r.epochs.last().unwrap();
    format!(
        "[{}] epoch {:>3}  loss {:.4}  test-err {:5.2}%  rate(wire) {:7.1}x  rate(paper) {:7.1}x  rg95 {:.3e}",
        r.name,
        e.epoch,
        e.train_loss,
        e.test_error_pct,
        e.comp_all.rate_wire(),
        e.comp_all.rate_paper(),
        e.rg_p95,
    )
}

/// One-line adaptive-control summary, `None` when the controller never
/// re-tuned a knob (the static-engine case prints nothing extra).
pub fn control_line(r: &RunRecord) -> Option<String> {
    let c = &r.fabric.control;
    if c.is_empty() {
        return None;
    }
    let mut s = format!("controller: {} retunes |", r.fabric.control_retunes);
    for d in c {
        s.push_str(&format!(" e{} {} {}->{}", d.epoch, d.knob, d.old, d.new));
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "err%"]);
        t.row(vec!["cifar_cnn".into(), "18.4".into()]);
        t.row(vec!["x".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("cifar_cnn"));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn control_line_summarizes_decisions() {
        let mut r = RunRecord {
            name: "t".into(),
            model: "m".into(),
            scheme: "adacomp".into(),
            learners: 2,
            batch_per_learner: 8,
            optimizer: "sgd".into(),
            epochs: Vec::new(),
            diverged: false,
            fabric: Default::default(),
        };
        assert!(control_line(&r).is_none());
        r.fabric.control.push(crate::comm::ControlDecision {
            epoch: 1,
            knob: "staleness".into(),
            old: 1.0,
            new: 2.0,
            signal: "straggler_excess=0.21>0.1".into(),
        });
        r.fabric.control_retunes = 1;
        let line = control_line(&r).unwrap();
        assert!(line.contains("1 retunes"), "{line}");
        assert!(line.contains("e1 staleness 1->2"), "{line}");
    }
}
