//! Experiment harness shared by the CLI and the examples/ binaries.
//!
//! Maps each paper workload (Table 1 row) to its dataset substitute +
//! exported model + scaled default hyper-parameters, and provides the
//! run/report plumbing every figure harness uses. Workload sizes are scaled
//! for a CPU testbed (paper: weeks of K40 time); every harness takes
//! `--epochs/--train/--test` to run larger.

pub mod report;

use anyhow::{bail, Result};

use crate::comm::LinkModel;
use crate::compress;
use crate::data::{
    cifar_like::CifarLike, fbank_like::FbankLike, mnist_gen::MnistGen,
    shakespeare::Shakespeare, Dataset,
};
use crate::models::{Layout, Manifest, ModelMeta};
use crate::optim::LrSchedule;
use crate::runtime::{Executor, ExecutorFactory};
use crate::train::TrainConfig;
use crate::util::cli::Args;

/// Scaled default workload per model (paper epochs in parentheses).
pub struct Defaults {
    pub train: usize,
    pub test: usize,
    pub epochs: usize,
    pub lr: LrSchedule,
    pub optimizer: &'static str,
    pub momentum: f32,
    pub batch: usize,
    pub clip_norm: f32,
}

pub fn defaults_for(model: &str) -> Defaults {
    match model {
        // paper: batch 100, 100 epochs
        "mnist_dnn" | "mnist_cnn" => Defaults {
            train: 2000,
            test: 500,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 100,
            clip_norm: 0.0,
        },
        // paper: batch 128, 140 epochs, Caffe quick lr policy.
        // Scaled hard: this testbed exposes a single CPU core (see
        // EXPERIMENTS.md §Testbed), so a paper-scale CIFAR run is ~days.
        "cifar_cnn" => Defaults {
            train: 2560,
            test: 512,
            epochs: 8,
            lr: LrSchedule::Milestones {
                base: 0.02,
                points: vec![(6, 0.004)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 128,
            clip_norm: 0.0,
        },
        // paper: batch 256, 45 epochs (AlexNet/ImageNet)
        "alexnet_s" => Defaults {
            train: 1280,
            test: 320,
            epochs: 6,
            lr: LrSchedule::Milestones {
                base: 0.02,
                points: vec![(4, 0.004)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 64,
            clip_norm: 0.0,
        },
        "resnet18_s" | "resnet50_s" => Defaults {
            train: 1280,
            test: 320,
            epochs: 6,
            lr: LrSchedule::Milestones {
                base: 0.01,
                points: vec![(4, 0.002)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 32,
            clip_norm: 1.0,
        },
        // paper: batch 256, 13 epochs
        "bn50_dnn" | "bn50_dnn_s" => Defaults {
            train: 6400,
            test: 640,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 128,
            clip_norm: 0.0,
        },
        // paper: batch 10, 45 epochs (char-rnn)
        "char_lstm" => Defaults {
            train: 400,
            test: 50,
            epochs: 4,
            lr: LrSchedule::Constant(2e-3),
            optimizer: "adam",
            momentum: 0.0,
            batch: 10,
            clip_norm: 5.0,
        },
        // e2e driver
        "transformer" => Defaults {
            train: 4096,
            test: 64,
            epochs: 6,
            lr: LrSchedule::Constant(3e-4),
            optimizer: "adam",
            momentum: 0.0,
            batch: 4,
            clip_norm: 1.0,
        },
        _ => Defaults {
            train: 2000,
            test: 400,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 32,
            clip_norm: 0.0,
        },
    }
}

/// Instantiate the dataset substitute for a model (DESIGN.md §Substitutions).
pub fn dataset_for(model: &str, seed: u64, train: usize, test: usize, seq_len: usize) -> Result<Box<dyn Dataset>> {
    Ok(match model {
        "mnist_dnn" | "mnist_cnn" => Box::new(MnistGen::new(seed, train, test)),
        "cifar_cnn" => Box::new(CifarLike::cifar10(seed, train, test)),
        "alexnet_s" | "resnet18_s" | "resnet50_s" => {
            Box::new(CifarLike::imagenet100(seed, train, test))
        }
        "bn50_dnn" => Box::new(FbankLike::new(seed, 5999, train, test)),
        "bn50_dnn_s" => Box::new(FbankLike::new(seed, 1500, train, test)),
        "char_lstm" | "transformer" => Box::new(Shakespeare::new(
            seed,
            200_000,
            seq_len,
            train,
            test,
        )),
        other => bail!("no dataset mapping for model '{other}'"),
    })
}

/// Models with a hermetic layer-graph builder (`runtime::net`) — trainable
/// with no artifacts and no PJRT, via `--backend native` (or `auto` when
/// the artifacts/pjrt path is unavailable).
///
/// Registering a model means updating all three of: this list,
/// [`native_factory`], and [`native_spec`] (the
/// `native_specs_build_for_all_registered_models` test pins list → builder
/// agreement).
pub fn native_models() -> &'static [&'static str] {
    &["mnist_dnn", "mnist_cnn", "cifar_cnn", "bn50_dnn_s", "char_lstm"]
}

/// A hermetic native workload spec: the executor factory plus everything
/// the harness needs to wire a run without an artifacts manifest.
pub struct NativeSpec {
    pub factory: Box<dyn ExecutorFactory>,
    pub layout: Layout,
    /// Deterministic initial parameters (the expensive part — only built
    /// here, not on the per-run [`Workload::factory`] path).
    pub init: Vec<f32>,
    /// Default sequence length (0 for non-sequence models).
    pub seq_len: usize,
    /// Per-sample input/label element counts at the default seq_len; for
    /// sequence models both scale with the chosen `--seq-len`.
    pub x_elems: usize,
    pub y_elems: usize,
    pub num_classes: usize,
    pub x_is_int: bool,
}

const MNIST_DNN_DIMS: &[usize] = &[784, 300, 100, 10];
const BN50_S_DIMS: &[usize] = &[440, 512, 512, 512, 512, 512, 1500];

/// Executor factory only — cheap (no init-parameter generation); the
/// per-run [`Workload::factory`] path uses this.
pub fn native_factory(model: &str, eval_batch: usize) -> Result<Box<dyn ExecutorFactory>> {
    use crate::runtime::native::NativeMlp;
    use crate::runtime::native_cnn::NativeCnn;
    use crate::runtime::native_lstm::NativeCharLstm;
    let f: Box<dyn ExecutorFactory> = match model {
        "mnist_dnn" => Box::new(NativeMlp::new(MNIST_DNN_DIMS, eval_batch)),
        "bn50_dnn_s" => Box::new(NativeMlp::new(BN50_S_DIMS, eval_batch)),
        "mnist_cnn" => Box::new(mnist_cnn_model(eval_batch)?),
        "cifar_cnn" => Box::new(NativeCnn::cifar_quick(eval_batch)),
        "char_lstm" => Box::new(NativeCharLstm::scaled(eval_batch)),
        other => bail!(
            "no native backend for model '{other}' (native models: {})",
            native_models().join(", ")
        ),
    };
    Ok(f)
}

/// MNIST-CNN family: 2 conv stages + fc head on 28x28x1.
fn mnist_cnn_model(eval_batch: usize) -> Result<crate::runtime::native_cnn::NativeCnn> {
    use crate::runtime::native_cnn::{ConvStage, NativeCnn};
    NativeCnn::new(
        28,
        28,
        &[ConvStage { cin: 1, cout: 8 }, ConvStage { cin: 8, cout: 16 }],
        10,
        eval_batch,
    )
}

/// Build the full hermetic spec for a model (factory + layout +
/// deterministic init + dataset-facing metadata).
pub fn native_spec(model: &str, seed: u64, eval_batch: usize) -> Result<NativeSpec> {
    use crate::runtime::native::NativeMlp;
    use crate::runtime::native_cnn::NativeCnn;
    use crate::runtime::native_lstm::NativeCharLstm;
    Ok(match model {
        // paper MNIST-DNN 784-300-100-10 (python build_mnist_dnn)
        "mnist_dnn" => {
            let m = NativeMlp::new(MNIST_DNN_DIMS, eval_batch);
            let (layout, init) = (m.layout().clone(), m.init_params(seed));
            NativeSpec {
                factory: Box::new(m),
                layout,
                init,
                seq_len: 0,
                x_elems: 784,
                y_elems: 1,
                num_classes: 10,
                x_is_int: false,
            }
        }
        // scaled BN50 DNN 440-512x4-1500 (python build_bn50_dnn_s)
        "bn50_dnn_s" => {
            let m = NativeMlp::new(BN50_S_DIMS, eval_batch);
            let (layout, init) = (m.layout().clone(), m.init_params(seed));
            NativeSpec {
                factory: Box::new(m),
                layout,
                init,
                seq_len: 0,
                x_elems: 440,
                y_elems: 1,
                num_classes: 1500,
                x_is_int: false,
            }
        }
        "mnist_cnn" => {
            let m = mnist_cnn_model(eval_batch)?;
            let (layout, init) = (m.layout().clone(), m.init_params(seed));
            NativeSpec {
                factory: Box::new(m),
                layout,
                init,
                seq_len: 0,
                x_elems: 28 * 28, // 28x28x1
                y_elems: 1,
                num_classes: 10,
                x_is_int: false,
            }
        }
        // CIFAR10-CNN (Caffe-quick family): 3 conv stages + fc on 32x32x3
        "cifar_cnn" => {
            let m = NativeCnn::cifar_quick(eval_batch);
            let (layout, init) = (m.layout().clone(), m.init_params(seed));
            NativeSpec {
                factory: Box::new(m),
                layout,
                init,
                seq_len: 0,
                x_elems: 32 * 32 * 3,
                y_elems: 1,
                num_classes: 10,
                x_is_int: false,
            }
        }
        // paper Shakespeare char-RNN, scaled: embed 32 -> LSTM 64x2 -> fc
        "char_lstm" => {
            let m = NativeCharLstm::scaled(eval_batch);
            let (layout, init) = (m.layout().clone(), m.init_params(seed));
            NativeSpec {
                factory: Box::new(m),
                layout,
                init,
                seq_len: 50,
                x_elems: 50,
                y_elems: 50,
                num_classes: crate::data::shakespeare::VOCAB,
                x_is_int: true,
            }
        }
        other => bail!(
            "no native backend for model '{other}' (native models: {})",
            native_models().join(", ")
        ),
    })
}

/// A fully wired workload: dataset + executor + initial params + config.
pub struct Workload {
    /// Real artifacts manifest (pjrt backend) or a synthetic single-model
    /// manifest describing the native spec — either way,
    /// `manifest.model(&self.model)` resolves.
    pub manifest: Manifest,
    pub model: String,
    /// Resolved compute backend: "native" or "pjrt".
    pub backend: String,
    pub dataset: Box<dyn Dataset>,
    pub init_params: Vec<f32>,
    pub cfg: TrainConfig,
    eval_batch: usize,
}

impl Workload {
    /// Build from CLI args: common flags are --model --backend --epochs
    /// --learners --batch --train --test --scheme --lt (integer or
    /// conv=64,fc=500[,lstm=N][,embed=N]) --lt-conv --lt-fc --lt-lstm
    /// --lt-embed --optimizer --lr --topology (ring | ps | ps:S | hier:G)
    /// --bucket-bytes --seed --seq-len --artifacts --churn --mtbf
    /// --controller (off | on).
    pub fn from_args(args: &Args, default_model: &str) -> Result<Workload> {
        Workload::from_args_with_backend(args, default_model, None)
    }

    /// Like [`from_args`](Self::from_args) but with the backend forced by
    /// the caller (a config-JSON `backend` key overrides CLI `--backend`).
    pub fn from_args_with_backend(
        args: &Args,
        default_model: &str,
        backend_override: Option<&str>,
    ) -> Result<Workload> {
        let model = args.str_or("model", default_model);
        let dir = args.str_or("artifacts", default_artifacts_dir());
        let d = defaults_for(&model);

        let train = args.usize_or("train", d.train);
        let test = args.usize_or("test", d.test);
        let seed = args.u64_or("seed", 17);
        let eval_batch = d.batch.min(test.max(1)).max(1);

        // Resolve the compute backend. An explicit request wins; "auto"
        // prefers the AOT artifacts when both they and the pjrt feature are
        // available, otherwise falls back to the hermetic native builders.
        // The fallback only covers *absent* artifacts — a manifest that
        // exists but fails to load is a real error and must surface.
        let backend_req = match backend_override {
            Some(b) => b.to_string(),
            None => args.str_or("backend", "auto"),
        };
        let manifest_present = std::path::Path::new(&dir).join("manifest.json").exists();
        let (manifest, backend): (Option<Manifest>, &str) = match backend_req.as_str() {
            "native" => (None, "native"),
            "pjrt" => (Some(Manifest::load(&dir)?), "pjrt"),
            "auto" => {
                let native_ok = native_models().contains(&model.as_str());
                if cfg!(feature = "pjrt") && manifest_present {
                    let m = Manifest::load(&dir)?;
                    if m.model(&model).is_ok() || !native_ok {
                        (Some(m), "pjrt")
                    } else {
                        // a (possibly stale) manifest that lacks this model
                        // still falls back to the hermetic builder
                        (None, "native")
                    }
                } else if native_ok {
                    (None, "native")
                } else {
                    // keep the legacy artifact-centric error path for models
                    // that only exist as AOT exports
                    (Some(Manifest::load(&dir)?), "pjrt")
                }
            }
            other => bail!("unknown --backend '{other}' (native | pjrt | auto)"),
        };

        let (manifest, init_native, seq_len) = match (manifest, backend) {
            (Some(m), _) => {
                let seq = m.model(&model)?.seq_len;
                // the AOT executable is compiled for a fixed seq_len — an
                // explicit different request cannot be honored
                let req = args.usize_or("seq-len", seq);
                if req != seq {
                    bail!(
                        "--seq-len {req} conflicts with the AOT artifact for '{model}' \
                         (exported at seq_len {seq}); re-export the artifacts or use \
                         --backend native"
                    );
                }
                (m, None, seq)
            }
            (None, _) => {
                let spec = native_spec(&model, seed, eval_batch)?;
                let seq_len = args.usize_or("seq-len", spec.seq_len);
                // sequence models scale x/y per-sample elems with seq_len
                let (x_elems, y_elems) = if spec.seq_len > 0 {
                    (seq_len, seq_len)
                } else {
                    (spec.x_elems, spec.y_elems)
                };
                let meta = ModelMeta {
                    name: model.clone(),
                    layout: spec.layout,
                    step_hlo: String::new(),
                    eval_hlo: String::new(),
                    init_bin: String::new(),
                    batch: d.batch,
                    seq_len,
                    x_shape: vec![x_elems],
                    x_is_int: spec.x_is_int,
                    y_shape: vec![y_elems],
                    num_classes: spec.num_classes,
                };
                (
                    Manifest {
                        dir: "<native>".into(),
                        models: vec![meta],
                    },
                    Some(spec.init),
                    seq_len,
                )
            }
        };

        let dataset = dataset_for(&model, seed ^ 0xda7a, train, test, seq_len)?;

        let mut comp = compress::Config::default();
        if let Some(s) = args.get("scheme") {
            comp.kind = compress::Kind::parse_or_err(s)?;
        }
        comp.lt_conv = args.usize_or("lt-conv", comp.lt_conv);
        comp.lt_fc = args.usize_or("lt-fc", comp.lt_fc);
        comp.lt_lstm = args.usize_or("lt-lstm", comp.lt_lstm);
        comp.lt_embed = args.usize_or("lt-embed", comp.lt_embed);
        // --lt: a plain integer overrides every layer (the Fig 4 sweep
        // form); a per-kind list conv=64,fc=500[,lstm=N][,embed=N] sets
        // kinds individually. Parsed here so malformed specs fail at the
        // prompt with the valid forms, like --churn and --topology.
        if let Some(s) = args.get("lt") {
            comp.parse_lt_spec(s)?;
        }
        comp.topk_fraction = args.f32_or("topk", comp.topk_fraction as f32) as f64;
        comp.strom_tau = args.f32_or("tau", comp.strom_tau);
        if args.flag("per-bin-scale") {
            comp.per_bin_scale = true;
        }

        // validate by-name/by-range knobs at parse time: typos fail with
        // the valid list instead of a mid-run failure (learners resolves
        // first — the ps:<S>/hier:<G> parameter bounds depend on it)
        let learners = args.usize_or("learners", 1);
        let topology = args.str_or("topology", "ring");
        crate::comm::topology::build(&topology, learners)?;
        let exchange = args.str_or("exchange", "streamed");
        crate::train::ExchangeMode::parse(&exchange)?;
        // bounded-staleness window knobs (hand-parsed so a negative K or a
        // non-number fails with the valid range, not an integer-parse panic)
        let staleness = match args.get("staleness") {
            None => 0usize,
            Some(v) => {
                let k: i64 = v.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "--staleness '{v}' is not an integer (valid: 0 <= K <= {}; \
                         0 = synchronous)",
                        crate::train::MAX_STALENESS
                    )
                })?;
                if k < 0 {
                    bail!(
                        "staleness {k} out of range (valid: 0 <= K <= {}; 0 = synchronous)",
                        crate::train::MAX_STALENESS
                    );
                }
                k as usize
            }
        };
        let jitter = match args.get("jitter") {
            None => 0.0f64,
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "--jitter '{v}' is not a number (valid: 0.0 <= jitter < 1.0; 0 = no jitter)"
                )
            })?,
        };
        crate::train::validate_window(staleness, jitter)?;
        // intra-GEMM core budget (hand-parsed like --staleness so a
        // negative N or junk fails with the valid range)
        let kernel_threads = match args.get("kernel-threads") {
            None => 0usize,
            Some(v) => {
                let n: i64 = v.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "--kernel-threads '{v}' is not an integer (valid: 0 <= N <= {}; \
                         0 = auto budget)",
                        crate::tensor::parallel::MAX_KERNEL_THREADS
                    )
                })?;
                if n < 0 {
                    bail!(
                        "kernel-threads {n} out of range (valid: 0 <= N <= {}; \
                         0 = auto budget)",
                        crate::tensor::parallel::MAX_KERNEL_THREADS
                    );
                }
                n as usize
            }
        };
        crate::train::validate_kernel_threads(kernel_threads)?;
        // elastic-fleet knobs: the churn schedule parses (or fails with the
        // valid event forms) here, not at step N mid-run; mtbf hand-parsed
        // like --staleness so junk fails with the valid range
        let churn = args.str_or("churn", "");
        crate::train::churn::parse(&churn)?;
        let mtbf = match args.get("mtbf") {
            None => 0u64,
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "--mtbf '{v}' is not a step count (valid: integer steps >= 0; \
                     0 disables random failures)"
                )
            })?,
        };
        // adaptive control plane: mode validated by name at parse time
        let controller = args.str_or("controller", "off");
        crate::train::control::parse_mode(&controller)?;
        let batch = args.usize_or("batch", d.batch / learners.max(1)).max(1);
        let lr = match args.get("lr") {
            Some(v) => LrSchedule::Constant(v.parse()?),
            None => d.lr.clone(),
        };

        let cfg = TrainConfig {
            run_name: args.str_or("name", &format!("{model}-{}", comp.kind.name())),
            model_name: model.clone(),
            backend: backend.to_string(),
            n_learners: learners,
            batch_per_learner: batch,
            epochs: args.usize_or("epochs", d.epochs),
            steps_per_epoch: args.usize_or("steps", 0),
            lr,
            optimizer: args.str_or("optimizer", d.optimizer),
            momentum: args.f32_or("momentum", d.momentum),
            compression: comp,
            topology,
            link: LinkModel {
                jitter,
                ..Default::default()
            },
            seed,
            divergence_loss: 50.0, // classification losses; way past any sane value
            track_residue: true,
            clip_norm: args.f32_or("clip", d.clip_norm),
            threads: args.usize_or("threads", 0),
            exchange,
            bucket_bytes: args.usize_or("bucket-bytes", 0),
            staleness,
            churn,
            mtbf,
            kernel_threads,
            controller,
        };

        let mut init_params = match init_native {
            Some(p) => p,
            None => {
                let meta = manifest.model(&model)?.clone();
                manifest.load_init(&meta)?
            }
        };
        // --resume CKPT: continue from a saved checkpoint (same model).
        if let Some(ckpt_path) = args.get("resume") {
            let ck = crate::train::checkpoint::Checkpoint::load(std::path::Path::new(ckpt_path))?;
            if ck.model != model {
                anyhow::bail!(
                    "checkpoint {} is for model '{}', not '{}'",
                    ckpt_path,
                    ck.model,
                    model
                );
            }
            if ck.params.len() != init_params.len() {
                anyhow::bail!("checkpoint param count mismatch");
            }
            init_params = ck.params;
        }
        Ok(Workload {
            manifest,
            model,
            backend: backend.to_string(),
            dataset,
            init_params,
            cfg,
            eval_batch,
        })
    }

    /// Executor factory for this workload's resolved backend: the hermetic
    /// native layer-graph builders, or PJRT over the AOT artifacts.
    pub fn factory(&self) -> Result<Box<dyn ExecutorFactory>> {
        if self.backend == "native" {
            return native_factory(&self.model, self.eval_batch);
        }
        self.pjrt_factory()
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_factory(&self) -> Result<Box<dyn ExecutorFactory>> {
        Ok(Box::new(crate::runtime::pjrt::PjrtFactory::new(
            self.manifest.clone(),
            self.model.clone(),
        )))
    }

    /// See the `pjrt`-enabled variant: this build has no PJRT backend.
    #[cfg(not(feature = "pjrt"))]
    fn pjrt_factory(&self) -> Result<Box<dyn ExecutorFactory>> {
        anyhow::bail!(
            "model '{}' needs the PJRT backend, but this binary was built without \
             the `pjrt` feature — add the `xla` dependency and rebuild with \
             `--features pjrt`, or use `--backend native` for a hermetic model \
             (see rust/Cargo.toml and DESIGN.md §Interchange)",
            self.model
        )
    }

    /// A single executor on the calling thread (inspection / analyze paths).
    pub fn local_executor(&self) -> Result<Box<dyn Executor>> {
        self.factory()?.build_local()
    }

    /// Run training with the current config.
    pub fn run(&self) -> Result<crate::metrics::RunRecord> {
        Ok(self.run_full()?.0)
    }

    /// Run training, also returning the trained parameters (checkpointing).
    pub fn run_full(&self) -> Result<(crate::metrics::RunRecord, Vec<f32>)> {
        let factory = self.factory()?;
        let layout = self.manifest.model(&self.model)?.layout.clone();
        let mut engine =
            crate::train::Engine::new(factory.as_ref(), self.dataset.as_ref(), &layout);
        engine.run_full(&self.cfg, &self.init_params, None)
    }

    /// Run with a per-epoch hook (figure harnesses).
    pub fn run_with_hook(
        &self,
        hook: &mut crate::train::engine::EpochHook<'_>,
    ) -> Result<crate::metrics::RunRecord> {
        let factory = self.factory()?;
        let layout = self.manifest.model(&self.model)?.layout.clone();
        let mut engine =
            crate::train::Engine::new(factory.as_ref(), self.dataset.as_ref(), &layout);
        engine.run_with_hook(&self.cfg, &self.init_params, Some(hook))
    }
}

pub fn default_artifacts_dir() -> &'static str {
    // examples run from the repo root via cargo; fall back to the manifest dir
    if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_all_models() {
        for m in [
            "mnist_dnn",
            "mnist_cnn",
            "cifar_cnn",
            "alexnet_s",
            "resnet18_s",
            "resnet50_s",
            "bn50_dnn",
            "bn50_dnn_s",
            "char_lstm",
            "transformer",
        ] {
            let d = defaults_for(m);
            assert!(d.epochs > 0 && d.batch > 0);
            let ds = dataset_for(m, 1, 100, 50, 32).unwrap();
            assert_eq!(ds.train_len(), 100);
        }
    }

    #[test]
    fn unknown_model_dataset_errors() {
        assert!(dataset_for("nope", 1, 10, 5, 0).is_err());
    }

    #[test]
    fn native_specs_build_for_all_registered_models() {
        for m in native_models() {
            let spec = native_spec(m, 1, 8).unwrap();
            assert_eq!(spec.init.len(), spec.layout.total, "{m}");
            assert!(spec.factory.parallel(), "{m}");
            assert!(spec.factory.build_worker().is_ok(), "{m}");
            assert!(spec.num_classes > 1, "{m}");
            // the cheap factory-only path must agree on the backend name
            let f = native_factory(m, 8).unwrap();
            assert_eq!(f.backend(), spec.factory.backend(), "{m}");
        }
        assert!(native_spec("transformer", 1, 8).is_err());
        assert!(native_factory("transformer", 8).is_err());
    }

    #[test]
    fn native_workload_from_args_is_hermetic() {
        // no artifacts anywhere — the native backend must still wire a full
        // workload (synthetic manifest included) and train end-to-end.
        let args = Args::parse_from(
            [
                "--model",
                "char_lstm",
                "--backend",
                "native",
                "--train",
                "60",
                "--test",
                "20",
                "--epochs",
                "1",
                "--steps",
                "2",
                "--seq-len",
                "12",
                "--batch",
                "4",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&args, "char_lstm").unwrap();
        assert_eq!(w.backend, "native");
        assert_eq!(w.cfg.backend, "native");
        let meta = w.manifest.model("char_lstm").unwrap();
        assert!(meta.x_is_int);
        assert_eq!(meta.seq_len, 12);
        assert_eq!(w.init_params.len(), meta.layout.total);
        let rec = w.run().unwrap();
        assert_eq!(rec.epochs.len(), 1);
        assert!(rec.epochs[0].train_loss.is_finite());
    }

    #[test]
    fn sharded_topology_cli_validates_against_learners() {
        // satellite: ps:<S>/hier:<G> bounds check against --learners at
        // parse time, with the valid-form list in the error
        let ok = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native", "--learners", "4",
                "--topology", "ps:2", "--bucket-bytes", "4096",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.topology, "ps:2");
        assert_eq!(w.cfg.bucket_bytes, 4096);

        for (topo, learners) in [("ps:8", "4"), ("hier:1", "4"), ("hier:8", "4"), ("ps:2", "1")] {
            let args = Args::parse_from(
                [
                    "--model", "mnist_dnn", "--backend", "native", "--learners", learners,
                    "--topology", topo,
                ]
                .map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains("ps:<S>") && err.contains("hier:<G>"), "{topo}: {err}");
        }
    }

    #[test]
    fn staleness_and_jitter_cli_validate_at_parse_time() {
        // satellite: the window knobs fail fast with the valid range in
        // the error (the topology::build pattern), and wire through to
        // TrainConfig/LinkModel when in range
        let ok = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native", "--learners", "4",
                "--staleness", "2", "--jitter", "0.3",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.staleness, 2);
        assert!((w.cfg.link.jitter - 0.3).abs() < 1e-12);
        // defaults: synchronous, no jitter
        let none = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native"].map(String::from),
            &[],
        );
        let w = Workload::from_args(&none, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.staleness, 0);
        assert_eq!(w.cfg.link.jitter, 0.0);

        for (flag, val, needle) in [
            ("--staleness", "-1", "0 <= K <= 16"),
            ("--staleness", "99", "0 <= K <= 16"),
            ("--staleness", "two", "0 <= K <= 16"),
            ("--jitter", "1.0", "0.0 <= jitter < 1.0"),
            ("--jitter", "-0.5", "0.0 <= jitter < 1.0"),
            ("--jitter", "lots", "0.0 <= jitter < 1.0"),
        ] {
            let args = Args::parse_from(
                ["--model", "mnist_dnn", "--backend", "native", flag, val].map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains(needle), "{flag} {val}: {err}");
        }
    }

    #[test]
    fn kernel_threads_cli_validates_at_parse_time() {
        // satellite: the intra-GEMM core budget fails fast with the valid
        // range in the error (the --staleness pattern), and wires through
        // to TrainConfig when in range
        let ok = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native", "--learners", "2",
                "--kernel-threads", "4",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.kernel_threads, 4);
        // default: 0 = auto budget (threads / active learners)
        let none = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native"].map(String::from),
            &[],
        );
        let w = Workload::from_args(&none, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.kernel_threads, 0);

        for (val, needle) in [
            ("-1", "0 <= N <= 64"),
            ("65", "0 <= N <= 64"),
            ("four", "0 <= N <= 64"),
        ] {
            let args = Args::parse_from(
                [
                    "--model", "mnist_dnn", "--backend", "native",
                    "--kernel-threads", val,
                ]
                .map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains(needle), "--kernel-threads {val}: {err}");
        }
    }

    #[test]
    fn churn_and_mtbf_cli_validate_at_parse_time() {
        // satellite: the elastic-fleet knobs fail fast with the valid event
        // forms in the error (the topology::build pattern), and wire
        // through to TrainConfig when well-formed
        let ok = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native", "--learners", "4",
                "--churn", "fail@120:2, join@300:1 ,leave@500:1", "--mtbf", "800",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.churn, "fail@120:2, join@300:1 ,leave@500:1");
        assert_eq!(w.cfg.mtbf, 800);
        // defaults: static fleet
        let none = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native"].map(String::from),
            &[],
        );
        let w = Workload::from_args(&none, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.churn, "");
        assert_eq!(w.cfg.mtbf, 0);

        for (flag, val, needle) in [
            ("--churn", "fail120:2", "missing '@'"),
            ("--churn", "explode@9:1", "unknown kind"),
            ("--churn", "fail@x:1", "not a step number"),
            ("--churn", "join@9:0", "count must be >= 1"),
            ("--mtbf", "-5", "integer steps >= 0"),
            ("--mtbf", "often", "integer steps >= 0"),
        ] {
            let args = Args::parse_from(
                ["--model", "mnist_dnn", "--backend", "native", flag, val].map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains(needle), "{flag} {val}: {err}");
        }
    }

    #[test]
    fn lt_spec_cli_validates_at_parse_time() {
        // satellite: --lt takes a plain integer (all-layer override) or a
        // per-kind list, and malformed specs fail with the valid forms
        let ok = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native",
                "--lt", "conv=64,fc=500,embed=32",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.compression.lt_conv, 64);
        assert_eq!(w.cfg.compression.lt_fc, 500);
        assert_eq!(w.cfg.compression.lt_embed, 32);
        assert_eq!(w.cfg.compression.lt_override, 0);
        let plain = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native", "--lt", "200"].map(String::from),
            &[],
        );
        let w = Workload::from_args(&plain, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.compression.lt_override, 200);
        // dedicated per-kind flags still work alongside
        let kinds = Args::parse_from(
            [
                "--model", "mnist_dnn", "--backend", "native",
                "--lt-lstm", "80", "--lt-embed", "90",
            ]
            .map(String::from),
            &[],
        );
        let w = Workload::from_args(&kinds, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.compression.lt_lstm, 80);
        assert_eq!(w.cfg.compression.lt_embed, 90);

        for (val, needle) in [
            ("conv=64,disk=9", "valid kinds: conv, fc, lstm, embed"),
            ("conv=0", "out of range"),
            ("conv=64,", "bad --lt entry"),
            ("fc=big", "bad L_T"),
        ] {
            let args = Args::parse_from(
                ["--model", "mnist_dnn", "--backend", "native", "--lt", val]
                    .map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains(needle), "--lt {val}: {err}");
        }
    }

    #[test]
    fn controller_cli_validates_at_parse_time() {
        // satellite: the control-plane mode fails fast with the valid
        // list, wires through when named, and defaults to off
        let ok = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native", "--controller", "on"]
                .map(String::from),
            &[],
        );
        let w = Workload::from_args(&ok, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.controller, "on");
        let none = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native"].map(String::from),
            &[],
        );
        let w = Workload::from_args(&none, "mnist_dnn").unwrap();
        assert_eq!(w.cfg.controller, "off");
        let bad = Args::parse_from(
            ["--model", "mnist_dnn", "--backend", "native", "--controller", "auto"]
                .map(String::from),
            &[],
        );
        let err = format!("{:#}", Workload::from_args(&bad, "mnist_dnn").unwrap_err());
        assert!(err.contains("valid: off, on"), "{err}");
    }

    #[test]
    fn unknown_cli_names_error_with_valid_lists() {
        for (flag, val, needle) in [
            ("--topology", "mesh", "ring"),
            ("--exchange", "warp", "streamed"),
            ("--scheme", "gzip", "adacomp"),
        ] {
            let args = Args::parse_from(
                ["--model", "mnist_dnn", "--backend", "native", flag, val].map(String::from),
                &[],
            );
            let err = format!("{:#}", Workload::from_args(&args, "mnist_dnn").unwrap_err());
            assert!(err.contains(val) && err.contains(needle), "{flag}: {err}");
        }
    }

    #[test]
    fn unknown_backend_rejected() {
        let args = Args::parse_from(
            ["--model", "char_lstm", "--backend", "tpu"].map(String::from),
            &[],
        );
        assert!(Workload::from_args(&args, "char_lstm").is_err());
    }
}
