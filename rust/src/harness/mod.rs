//! Experiment harness shared by the CLI and the examples/ binaries.
//!
//! Maps each paper workload (Table 1 row) to its dataset substitute +
//! exported model + scaled default hyper-parameters, and provides the
//! run/report plumbing every figure harness uses. Workload sizes are scaled
//! for a CPU testbed (paper: weeks of K40 time); every harness takes
//! `--epochs/--train/--test` to run larger.

pub mod report;

use anyhow::{bail, Result};

use crate::compress;
use crate::data::{
    cifar_like::CifarLike, fbank_like::FbankLike, mnist_gen::MnistGen,
    shakespeare::Shakespeare, Dataset,
};
use crate::models::Manifest;
use crate::optim::LrSchedule;
use crate::runtime::{Executor, ExecutorFactory};
use crate::train::TrainConfig;
use crate::util::cli::Args;

/// Scaled default workload per model (paper epochs in parentheses).
pub struct Defaults {
    pub train: usize,
    pub test: usize,
    pub epochs: usize,
    pub lr: LrSchedule,
    pub optimizer: &'static str,
    pub momentum: f32,
    pub batch: usize,
    pub clip_norm: f32,
}

pub fn defaults_for(model: &str) -> Defaults {
    match model {
        // paper: batch 100, 100 epochs
        "mnist_dnn" | "mnist_cnn" => Defaults {
            train: 2000,
            test: 500,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 100,
            clip_norm: 0.0,
        },
        // paper: batch 128, 140 epochs, Caffe quick lr policy.
        // Scaled hard: this testbed exposes a single CPU core (see
        // EXPERIMENTS.md §Testbed), so a paper-scale CIFAR run is ~days.
        "cifar_cnn" => Defaults {
            train: 2560,
            test: 512,
            epochs: 8,
            lr: LrSchedule::Milestones {
                base: 0.02,
                points: vec![(6, 0.004)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 128,
            clip_norm: 0.0,
        },
        // paper: batch 256, 45 epochs (AlexNet/ImageNet)
        "alexnet_s" => Defaults {
            train: 1280,
            test: 320,
            epochs: 6,
            lr: LrSchedule::Milestones {
                base: 0.02,
                points: vec![(4, 0.004)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 64,
            clip_norm: 0.0,
        },
        "resnet18_s" | "resnet50_s" => Defaults {
            train: 1280,
            test: 320,
            epochs: 6,
            lr: LrSchedule::Milestones {
                base: 0.01,
                points: vec![(4, 0.002)],
            },
            optimizer: "sgd",
            momentum: 0.9,
            batch: 32,
            clip_norm: 1.0,
        },
        // paper: batch 256, 13 epochs
        "bn50_dnn" | "bn50_dnn_s" => Defaults {
            train: 6400,
            test: 640,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 128,
            clip_norm: 0.0,
        },
        // paper: batch 10, 45 epochs (char-rnn)
        "char_lstm" => Defaults {
            train: 400,
            test: 50,
            epochs: 4,
            lr: LrSchedule::Constant(2e-3),
            optimizer: "adam",
            momentum: 0.0,
            batch: 10,
            clip_norm: 5.0,
        },
        // e2e driver
        "transformer" => Defaults {
            train: 4096,
            test: 64,
            epochs: 6,
            lr: LrSchedule::Constant(3e-4),
            optimizer: "adam",
            momentum: 0.0,
            batch: 4,
            clip_norm: 1.0,
        },
        _ => Defaults {
            train: 2000,
            test: 400,
            epochs: 5,
            lr: LrSchedule::Constant(0.05),
            optimizer: "sgd",
            momentum: 0.9,
            batch: 32,
            clip_norm: 0.0,
        },
    }
}

/// Instantiate the dataset substitute for a model (DESIGN.md §Substitutions).
pub fn dataset_for(model: &str, seed: u64, train: usize, test: usize, seq_len: usize) -> Result<Box<dyn Dataset>> {
    Ok(match model {
        "mnist_dnn" | "mnist_cnn" => Box::new(MnistGen::new(seed, train, test)),
        "cifar_cnn" => Box::new(CifarLike::cifar10(seed, train, test)),
        "alexnet_s" | "resnet18_s" | "resnet50_s" => {
            Box::new(CifarLike::imagenet100(seed, train, test))
        }
        "bn50_dnn" => Box::new(FbankLike::new(seed, 5999, train, test)),
        "bn50_dnn_s" => Box::new(FbankLike::new(seed, 1500, train, test)),
        "char_lstm" | "transformer" => Box::new(Shakespeare::new(
            seed,
            200_000,
            seq_len,
            train,
            test,
        )),
        other => bail!("no dataset mapping for model '{other}'"),
    })
}

/// A fully wired workload: dataset + executor + initial params + config.
pub struct Workload {
    pub manifest: Manifest,
    pub model: String,
    pub dataset: Box<dyn Dataset>,
    pub init_params: Vec<f32>,
    pub cfg: TrainConfig,
}

impl Workload {
    /// Build from CLI args: common flags are --model --epochs --learners
    /// --batch --train --test --scheme --lt --lt-conv --lt-fc --optimizer
    /// --lr --topology --seed --artifacts.
    pub fn from_args(args: &Args, default_model: &str) -> Result<Workload> {
        let model = args.str_or("model", default_model);
        let dir = args.str_or("artifacts", default_artifacts_dir());
        let manifest = Manifest::load(&dir)?;
        let meta = manifest.model(&model)?.clone();
        let d = defaults_for(&model);

        let train = args.usize_or("train", d.train);
        let test = args.usize_or("test", d.test);
        let seed = args.u64_or("seed", 17);
        let dataset = dataset_for(&model, seed ^ 0xda7a, train, test, meta.seq_len)?;

        let mut comp = compress::Config::default();
        if let Some(s) = args.get("scheme") {
            comp.kind = compress::Kind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown scheme '{s}'"))?;
        }
        comp.lt_conv = args.usize_or("lt-conv", comp.lt_conv);
        comp.lt_fc = args.usize_or("lt-fc", comp.lt_fc);
        comp.lt_override = args.usize_or("lt", 0);
        comp.topk_fraction = args.f32_or("topk", comp.topk_fraction as f32) as f64;
        comp.strom_tau = args.f32_or("tau", comp.strom_tau);
        if args.flag("per-bin-scale") {
            comp.per_bin_scale = true;
        }

        let learners = args.usize_or("learners", 1);
        let batch = args.usize_or("batch", d.batch / learners.max(1)).max(1);
        let lr = match args.get("lr") {
            Some(v) => LrSchedule::Constant(v.parse()?),
            None => d.lr.clone(),
        };

        let cfg = TrainConfig {
            run_name: args.str_or("name", &format!("{model}-{}", comp.kind.name())),
            model_name: model.clone(),
            n_learners: learners,
            batch_per_learner: batch,
            epochs: args.usize_or("epochs", d.epochs),
            steps_per_epoch: args.usize_or("steps", 0),
            lr,
            optimizer: args.str_or("optimizer", d.optimizer),
            momentum: args.f32_or("momentum", d.momentum),
            compression: comp,
            topology: args.str_or("topology", "ring"),
            link: Default::default(),
            seed,
            divergence_loss: 50.0, // classification losses; way past any sane value
            track_residue: true,
            clip_norm: args.f32_or("clip", d.clip_norm),
            threads: args.usize_or("threads", 0),
        };

        let mut init_params = manifest.load_init(&meta)?;
        // --resume CKPT: continue from a saved checkpoint (same model).
        if let Some(ckpt_path) = args.get("resume") {
            let ck = crate::train::checkpoint::Checkpoint::load(std::path::Path::new(ckpt_path))?;
            if ck.model != model {
                anyhow::bail!(
                    "checkpoint {} is for model '{}', not '{}'",
                    ckpt_path,
                    ck.model,
                    model
                );
            }
            if ck.params.len() != init_params.len() {
                anyhow::bail!("checkpoint param count mismatch");
            }
            init_params = ck.params;
        }
        Ok(Workload {
            manifest,
            model,
            dataset,
            init_params,
            cfg,
        })
    }

    /// Executor factory for this workload's backend (PJRT over the AOT
    /// artifacts). Without the `pjrt` cargo feature this errors at runtime —
    /// hermetic tier-1 builds carry the harness but not the XLA binding.
    #[cfg(feature = "pjrt")]
    pub fn factory(&self) -> Result<Box<dyn ExecutorFactory>> {
        Ok(Box::new(crate::runtime::pjrt::PjrtFactory::new(
            self.manifest.clone(),
            self.model.clone(),
        )))
    }

    /// See the `pjrt`-enabled variant: this build has no PJRT backend.
    #[cfg(not(feature = "pjrt"))]
    pub fn factory(&self) -> Result<Box<dyn ExecutorFactory>> {
        anyhow::bail!(
            "model '{}' needs the PJRT backend, but this binary was built without \
             the `pjrt` feature — add the `xla` dependency and rebuild with \
             `--features pjrt` (see rust/Cargo.toml and DESIGN.md §Interchange)",
            self.model
        )
    }

    /// A single executor on the calling thread (inspection / analyze paths).
    pub fn local_executor(&self) -> Result<Box<dyn Executor>> {
        self.factory()?.build_local()
    }

    /// Run training with the current config.
    pub fn run(&self) -> Result<crate::metrics::RunRecord> {
        Ok(self.run_full()?.0)
    }

    /// Run training, also returning the trained parameters (checkpointing).
    pub fn run_full(&self) -> Result<(crate::metrics::RunRecord, Vec<f32>)> {
        let factory = self.factory()?;
        let layout = self.manifest.model(&self.model)?.layout.clone();
        let mut engine =
            crate::train::Engine::new(factory.as_ref(), self.dataset.as_ref(), &layout);
        engine.run_full(&self.cfg, &self.init_params, None)
    }

    /// Run with a per-epoch hook (figure harnesses).
    pub fn run_with_hook(
        &self,
        hook: &mut crate::train::engine::EpochHook<'_>,
    ) -> Result<crate::metrics::RunRecord> {
        let factory = self.factory()?;
        let layout = self.manifest.model(&self.model)?.layout.clone();
        let mut engine =
            crate::train::Engine::new(factory.as_ref(), self.dataset.as_ref(), &layout);
        engine.run_with_hook(&self.cfg, &self.init_params, Some(hook))
    }
}

pub fn default_artifacts_dir() -> &'static str {
    // examples run from the repo root via cargo; fall back to the manifest dir
    if std::path::Path::new("artifacts/manifest.json").exists() {
        "artifacts"
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_all_models() {
        for m in [
            "mnist_dnn",
            "mnist_cnn",
            "cifar_cnn",
            "alexnet_s",
            "resnet18_s",
            "resnet50_s",
            "bn50_dnn",
            "bn50_dnn_s",
            "char_lstm",
            "transformer",
        ] {
            let d = defaults_for(m);
            assert!(d.epochs > 0 && d.batch > 0);
            let ds = dataset_for(m, 1, 100, 50, 32).unwrap();
            assert_eq!(ds.train_len(), 100);
        }
    }

    #[test]
    fn unknown_model_dataset_errors() {
        assert!(dataset_for("nope", 1, 10, 5, 0).is_err());
    }
}
