//! Composable layer-graph runtime — the hermetic executor core.
//!
//! The former `NativeMlp`/`NativeCnn` monoliths duplicated forward/backward
//! plumbing per architecture; this module replaces them with a small graph
//! engine so "add a model" means "compose layers", not "write an executor":
//!
//! * [`Layer`] — one node: declares its parameter tensors (name/shape/
//!   [`LayerKind`]) and implements `forward`/`backward` over flat
//!   activations. Parameters arrive as one contiguous slice of the model's
//!   flat buffer, carved by the shared [`Layout`] — the same layout the
//!   compression path uses for per-kind L_T defaults.
//! * [`NativeNet`] — an ordered stack of layers plus a softmax-xent head.
//!   It owns the activation/tape storage, runs the chain forward (caching
//!   per-layer activations), applies the loss, and walks the chain backward
//!   accumulating the flat gradient. It implements [`Executor`] and
//!   [`ExecutorFactory`] (spec-is-the-factory: clones are cheap, layers are
//!   shared immutably via `Arc`, results are bit-identical per clone).
//!
//! Concrete layers: [`Fc`], [`Relu`], [`Conv5x5Same`], [`MaxPool2`],
//! [`Embedding`] (i32 ids -> rows), [`Lstm`] (full-sequence BPTT). The
//! model builders in `native.rs` / `native_cnn.rs` / `native_lstm.rs` are
//! thin wrappers that assemble these stacks.
//!
//! Determinism: layers call the same `tensor::` kernels in the same order
//! as the old monoliths did, so refactored models are bit-identical to
//! their pre-graph implementations (pinned by rust/tests/engine_native.rs).
//!
//! Thread budget: the GEMMs under every layer read the process-wide
//! intra-kernel budget (`tensor::parallel::kernel_threads()`, set by the
//! engine as `threads / active_learners` and re-derived at membership
//! epochs) and fan macro-tiles over the shared compute pool. No plumbing
//! reaches this module — and results are bit-identical at every budget, so
//! executors stay oblivious to how many helper threads served them.

// `Layer::backward` legitimately carries the whole (params, activations,
// tape, cotangents, grads) context — a context struct would just rename
// the arguments.
#![allow(clippy::too_many_arguments)]

use std::sync::Arc;

use anyhow::{bail, Result};

use super::{Batch, EvalOut, Executor, ExecutorFactory, GradReady, StepOut};
use crate::models::{LayerKind, Layout};
use crate::tensor::{conv, embed, gemm, lstm, ops, KernelScratch};

/// An activation flowing between layers: dense f32 for most of the graph,
/// i32 token ids feeding an [`Embedding`] front layer.
#[derive(Clone, Copy)]
pub enum Act<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> Act<'a> {
    fn f32s(&self) -> &'a [f32] {
        match *self {
            Act::F32(x) => x,
            Act::I32(_) => panic!("layer expected f32 activations, got i32 ids"),
        }
    }
    fn ids(&self) -> &'a [i32] {
        match *self {
            Act::I32(x) => x,
            Act::F32(_) => panic!("layer expected i32 ids, got f32 activations"),
        }
    }
}

/// Per-layer forward stash: whatever `backward` needs beyond the layer's
/// input/output activations (conv im2col scratch, pool argmaxes, LSTM gate
/// caches). Buffers persist across steps, so steady-state reuse is free.
#[derive(Debug, Default, Clone)]
pub struct Tape {
    pub f: Vec<Vec<f32>>,
    pub u: Vec<Vec<u32>>,
}

impl Tape {
    fn ensure_f(&mut self, n: usize) {
        while self.f.len() < n {
            self.f.push(Vec::new());
        }
    }
    fn ensure_u(&mut self, n: usize) {
        while self.u.len() < n {
            self.u.push(Vec::new());
        }
    }
}

/// One node of the graph. Implementations are immutable specs (`Send +
/// Sync`, shared via `Arc`); all mutable state lives in the caller's tape
/// and activation buffers.
pub trait Layer: Send + Sync {
    /// Parameter tensors this layer contributes to the flat [`Layout`],
    /// in order. Empty for stateless layers (ReLU, pooling).
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)>;

    /// Output element count for an input of `in_len` elements (both counts
    /// cover the whole batch). Lets the net validate the chain without
    /// fixing the batch or sequence length at build time.
    fn out_len(&self, in_len: usize) -> usize;

    /// Whether this layer consumes i32 token ids (embedding front).
    fn wants_ids(&self) -> bool {
        false
    }

    /// Compute `y` from `x`, stashing whatever `backward` needs in `tape`.
    /// `p` is this layer's contiguous parameter slice (spec order). `ks` is
    /// the net's shared kernel scratch arena (GEMM packing pool + reusable
    /// gather/cotangent buffers); stateless layers ignore it.
    fn forward(
        &self,
        p: &[f32],
        x: Act<'_>,
        bsz: usize,
        tape: &mut Tape,
        ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    );

    /// Accumulate parameter gradients into `g` (zeroed by the net once per
    /// step) and, when `dx` is given, fill the input gradient. `x`/`y` are
    /// the forward activations; `tape` is the forward stash.
    #[allow(clippy::too_many_arguments)]
    fn backward(
        &self,
        p: &[f32],
        x: Act<'_>,
        y: &[f32],
        tape: &mut Tape,
        dy: &[f32],
        bsz: usize,
        ks: &mut KernelScratch,
        g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    );
}

// ---------------------------------------------------------------------------
// Concrete layers
// ---------------------------------------------------------------------------

/// Fully-connected `x @ w + b`, applied row-wise: rows = `x.len() / in_dim`,
/// so the same layer serves an MLP (`rows = bsz`) and a per-timestep head
/// over a sequence (`rows = bsz * T`).
pub struct Fc {
    pub name: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub kind: LayerKind,
}

impl Fc {
    pub fn new(name: &str, in_dim: usize, out_dim: usize) -> Fc {
        Fc {
            name: name.to_string(),
            in_dim,
            out_dim,
            kind: LayerKind::Fc,
        }
    }
}

impl Layer for Fc {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        vec![
            (format!("{}_w", self.name), vec![self.in_dim, self.out_dim], self.kind),
            (format!("{}_b", self.name), vec![self.out_dim], self.kind),
        ]
    }

    fn out_len(&self, in_len: usize) -> usize {
        assert_eq!(in_len % self.in_dim, 0, "fc '{}' input not a multiple of {}", self.name, self.in_dim);
        in_len / self.in_dim * self.out_dim
    }

    fn forward(
        &self,
        p: &[f32],
        x: Act<'_>,
        _bsz: usize,
        _tape: &mut Tape,
        ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        let x = x.f32s();
        let (a, b) = (self.in_dim, self.out_dim);
        let rows = x.len() / a;
        let (w, bias) = p.split_at(a * b);
        y.clear();
        y.resize(rows * b, 0.0);
        gemm::matmul(&mut ks.gemm, x, w, y, rows, a, b, false);
        for r in 0..rows {
            for j in 0..b {
                y[r * b + j] += bias[j];
            }
        }
    }

    fn backward(
        &self,
        p: &[f32],
        x: Act<'_>,
        _y: &[f32],
        _tape: &mut Tape,
        dy: &[f32],
        _bsz: usize,
        ks: &mut KernelScratch,
        g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        let x = x.f32s();
        let (a, b) = (self.in_dim, self.out_dim);
        let rows = x.len() / a;
        let (w, _) = p.split_at(a * b);
        let (gw, gb) = g.split_at_mut(a * b);
        // dW = x^T @ dy   (x: [rows, a], dy: [rows, b])
        gemm::matmul_at_b(&mut ks.gemm, x, dy, gw, a, rows, b, false);
        for r in 0..rows {
            for j in 0..b {
                gb[j] += dy[r * b + j];
            }
        }
        if let Some(dx) = dx {
            dx.clear();
            dx.resize(rows * a, 0.0);
            gemm::matmul_a_bt(&mut ks.gemm, dy, w, dx, rows, b, a);
        }
    }
}

/// Elementwise ReLU.
pub struct Relu;

impl Layer for Relu {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        Vec::new()
    }

    fn out_len(&self, in_len: usize) -> usize {
        in_len
    }

    fn forward(
        &self,
        _p: &[f32],
        x: Act<'_>,
        _bsz: usize,
        _tape: &mut Tape,
        _ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        let x = x.f32s();
        y.clear();
        y.extend_from_slice(x);
        ops::relu(y);
    }

    fn backward(
        &self,
        _p: &[f32],
        _x: Act<'_>,
        y: &[f32],
        _tape: &mut Tape,
        dy: &[f32],
        _bsz: usize,
        _ks: &mut KernelScratch,
        _g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        if let Some(dx) = dx {
            dx.clear();
            dx.extend_from_slice(dy);
            ops::relu_grad(y, dx);
        }
    }
}

/// SAME-padded stride-1 5x5 convolution over NHWC activations of fixed
/// spatial size `h x w` (the builder threads the running spatial dims).
pub struct Conv5x5Same {
    pub name: String,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
}

const CONV_K: usize = 5;

impl Layer for Conv5x5Same {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        vec![
            (
                format!("{}_w", self.name),
                vec![CONV_K, CONV_K, self.cin, self.cout],
                LayerKind::Conv,
            ),
            (format!("{}_b", self.name), vec![self.cout], LayerKind::Conv),
        ]
    }

    fn out_len(&self, in_len: usize) -> usize {
        assert_eq!(in_len % (self.h * self.w * self.cin), 0);
        in_len / self.cin * self.cout
    }

    fn forward(
        &self,
        p: &[f32],
        x: Act<'_>,
        bsz: usize,
        tape: &mut Tape,
        ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        let x = x.f32s();
        assert_eq!(x.len(), bsz * self.h * self.w * self.cin);
        let (wgt, bias) = p.split_at(CONV_K * CONV_K * self.cin * self.cout);
        tape.ensure_f(1);
        conv::conv2d_same(
            x, wgt, bias, bsz, self.h, self.w, self.cin, CONV_K, CONV_K, self.cout,
            &mut tape.f[0], &mut ks.gemm, y,
        );
    }

    fn backward(
        &self,
        p: &[f32],
        x: Act<'_>,
        _y: &[f32],
        tape: &mut Tape,
        dy: &[f32],
        bsz: usize,
        ks: &mut KernelScratch,
        g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        let x = x.f32s();
        let (wgt, _) = p.split_at(CONV_K * CONV_K * self.cin * self.cout);
        let (gw, gb) = g.split_at_mut(CONV_K * CONV_K * self.cin * self.cout);
        tape.ensure_f(1);
        let dx_slice = dx.map(|d| {
            d.clear();
            d.resize(bsz * self.h * self.w * self.cin, 0.0);
            d.as_mut_slice()
        });
        conv::conv2d_same_bwd(
            x, wgt, dy, bsz, self.h, self.w, self.cin, CONV_K, CONV_K, self.cout,
            &mut tape.f[0], &mut ks.gemm, &mut ks.dcols, gw, gb, dx_slice,
        );
    }
}

/// 2x2 stride-2 max pool over NHWC activations of fixed spatial size.
pub struct MaxPool2 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Layer for MaxPool2 {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        Vec::new()
    }

    fn out_len(&self, in_len: usize) -> usize {
        assert_eq!(in_len % 4, 0);
        in_len / 4
    }

    fn forward(
        &self,
        _p: &[f32],
        x: Act<'_>,
        bsz: usize,
        tape: &mut Tape,
        _ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        let x = x.f32s();
        assert_eq!(x.len(), bsz * self.h * self.w * self.c);
        tape.ensure_u(1);
        conv::maxpool2(x, bsz, self.h, self.w, self.c, y, &mut tape.u[0]);
    }

    fn backward(
        &self,
        _p: &[f32],
        _x: Act<'_>,
        _y: &[f32],
        tape: &mut Tape,
        dy: &[f32],
        bsz: usize,
        _ks: &mut KernelScratch,
        _g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        if let Some(dx) = dx {
            dx.clear();
            dx.resize(bsz * self.h * self.w * self.c, 0.0);
            conv::maxpool2_bwd(dy, &tape.u[0], dx);
        }
    }
}

/// Token-id embedding table `[vocab, dim]`. Must be the first layer of a
/// net (consumes the i32 input; produces `[bsz, T, dim]`).
pub struct Embedding {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
}

impl Layer for Embedding {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        vec![(self.name.clone(), vec![self.vocab, self.dim], LayerKind::Embed)]
    }

    fn out_len(&self, in_len: usize) -> usize {
        in_len * self.dim
    }

    fn wants_ids(&self) -> bool {
        true
    }

    fn forward(
        &self,
        p: &[f32],
        x: Act<'_>,
        _bsz: usize,
        _tape: &mut Tape,
        _ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        embed::gather(p, x.ids(), self.dim, y);
    }

    fn backward(
        &self,
        _p: &[f32],
        x: Act<'_>,
        _y: &[f32],
        _tape: &mut Tape,
        dy: &[f32],
        _bsz: usize,
        _ks: &mut KernelScratch,
        g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        assert!(dx.is_none(), "embedding has no input gradient (ids are discrete)");
        embed::scatter_add(g, x.ids(), self.dim, dy);
    }
}

/// Full-sequence LSTM (`[bsz, T, in] -> [bsz, T, hidden]`) with BPTT.
/// Parameters follow the exporter convention: `wx [in, 4H]`, `wh [H, 4H]`,
/// `b [4H]` (gate order i, f, g, o). `T` is inferred from the input length,
/// so one spec serves any sequence length.
pub struct Lstm {
    pub name: String,
    pub in_dim: usize,
    pub hidden: usize,
}

impl Layer for Lstm {
    fn param_specs(&self) -> Vec<(String, Vec<usize>, LayerKind)> {
        vec![
            (
                format!("{}_wx", self.name),
                vec![self.in_dim, 4 * self.hidden],
                LayerKind::Lstm,
            ),
            (
                format!("{}_wh", self.name),
                vec![self.hidden, 4 * self.hidden],
                LayerKind::Lstm,
            ),
            (format!("{}_b", self.name), vec![4 * self.hidden], LayerKind::Lstm),
        ]
    }

    fn out_len(&self, in_len: usize) -> usize {
        assert_eq!(in_len % self.in_dim, 0);
        in_len / self.in_dim * self.hidden
    }

    fn forward(
        &self,
        p: &[f32],
        x: Act<'_>,
        bsz: usize,
        tape: &mut Tape,
        ks: &mut KernelScratch,
        y: &mut Vec<f32>,
    ) {
        let x = x.f32s();
        let (i, h) = (self.in_dim, self.hidden);
        assert_eq!(x.len() % (bsz * i), 0, "lstm '{}' input length", self.name);
        let t_len = x.len() / (bsz * i);
        let (wx, rest) = p.split_at(i * 4 * h);
        let (wh, bias) = rest.split_at(h * 4 * h);
        tape.ensure_f(3);
        let (gates, rest) = tape.f.split_at_mut(1);
        let (c, tanh_c) = rest.split_at_mut(1);
        lstm::forward(
            x, wx, wh, bias, bsz, t_len, i, h, ks, &mut gates[0], &mut c[0], &mut tanh_c[0], y,
        );
    }

    fn backward(
        &self,
        p: &[f32],
        x: Act<'_>,
        y: &[f32],
        tape: &mut Tape,
        dy: &[f32],
        bsz: usize,
        ks: &mut KernelScratch,
        g: &mut [f32],
        dx: Option<&mut Vec<f32>>,
    ) {
        let x = x.f32s();
        let (i, h) = (self.in_dim, self.hidden);
        let t_len = x.len() / (bsz * i);
        let (wx, rest) = p.split_at(i * 4 * h);
        let (wh, _) = rest.split_at(h * 4 * h);
        let (gwx, grest) = g.split_at_mut(i * 4 * h);
        let (gwh, gb) = grest.split_at_mut(h * 4 * h);
        let dx_slice = dx.map(|d| {
            d.clear();
            d.resize(bsz * t_len * i, 0.0);
            d.as_mut_slice()
        });
        lstm::backward(
            x, wx, wh, &tape.f[0], &tape.f[1], &tape.f[2], y, dy, bsz, t_len, i, h, ks, gwx,
            gwh, gb, dx_slice,
        );
    }
}

// ---------------------------------------------------------------------------
// The net
// ---------------------------------------------------------------------------

fn input_act(int_input: bool, batch: &Batch) -> Act<'_> {
    if int_input {
        Act::I32(&batch.x_i32)
    } else {
        Act::F32(&batch.x_f32)
    }
}

/// An ordered layer stack with a softmax cross-entropy head, runnable as an
/// [`Executor`]. The logits are the last layer's output reshaped to
/// `[labels, classes]` where `labels = batch.y.len()` — so classification
/// (`labels = bsz`) and per-timestep LM heads (`labels = bsz * T`) share
/// the same code path.
#[derive(Clone)]
pub struct NativeNet {
    backend: &'static str,
    layers: Vec<Arc<dyn Layer>>,
    layout: Layout,
    /// (flat offset, total len) of each graph layer's parameters.
    spans: Vec<(usize, usize)>,
    /// (first layout-layer index, count) contributed by each graph layer —
    /// the grad-ready notification unit for the streamed step path.
    lranges: Vec<(usize, usize)>,
    /// Per-sample input element count (f32 values or i32 ids).
    in_elems: usize,
    int_input: bool,
    eval_batch: usize,
    // Per-instance forward storage (reused across steps).
    acts: Vec<Vec<f32>>,
    tapes: Vec<Tape>,
    /// Kernel scratch arena shared by every layer (GEMM packing pool,
    /// conv/LSTM gather and cotangent buffers). Clone-resets to empty.
    scratch: KernelScratch,
    // Persistent backward buffers: the dy/dx ping-pong pair (swapped per
    // layer, never reallocated in steady state). `bwd_a` doubles as the
    // dlogits / eval-scratch head buffer.
    bwd_a: Vec<f32>,
    bwd_b: Vec<f32>,
}

impl NativeNet {
    pub fn new(
        backend: &'static str,
        layers: Vec<Arc<dyn Layer>>,
        in_elems: usize,
        eval_batch: usize,
    ) -> NativeNet {
        assert!(!layers.is_empty(), "a net needs at least one layer");
        let int_input = layers[0].wants_ids();
        let mut specs: Vec<(String, Vec<usize>, LayerKind)> = Vec::new();
        let mut counts = Vec::with_capacity(layers.len());
        for l in &layers {
            let s = l.param_specs();
            counts.push(s.len());
            specs.extend(s);
        }
        let layout = Layout::from_specs(
            &specs
                .iter()
                .map(|(n, s, k)| (n.as_str(), s.as_slice(), *k))
                .collect::<Vec<_>>(),
        );
        let mut spans = Vec::with_capacity(layers.len());
        let mut lranges = Vec::with_capacity(layers.len());
        let mut ti = 0usize;
        for &cnt in &counts {
            if cnt == 0 {
                spans.push((0, 0));
                lranges.push((ti, 0));
            } else {
                let off = layout.layers[ti].offset;
                let len: usize = layout.layers[ti..ti + cnt].iter().map(|l| l.len()).sum();
                spans.push((off, len));
                lranges.push((ti, cnt));
                ti += cnt;
            }
        }
        let n = layers.len();
        NativeNet {
            backend,
            layers,
            layout,
            spans,
            lranges,
            in_elems,
            int_input,
            eval_batch,
            acts: vec![Vec::new(); n],
            tapes: vec![Tape::default(); n],
            scratch: KernelScratch::default(),
            bwd_a: Vec::new(),
            bwd_b: Vec::new(),
        }
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    pub fn int_input(&self) -> bool {
        self.int_input
    }

    pub fn in_elems(&self) -> usize {
        self.in_elems
    }

    /// Override the per-sample input element count. Sequence models infer
    /// seq_len from each batch and re-pin the check before stepping.
    pub fn set_in_elems(&mut self, n: usize) {
        self.in_elems = n;
    }

    fn check_input(&self, batch: &Batch) -> Result<()> {
        let want = batch.batch_size * self.in_elems;
        let got = if self.int_input {
            batch.x_i32.len()
        } else {
            batch.x_f32.len()
        };
        if got != want {
            bail!(
                "x length mismatch: {} expects {} elements per sample ({} total at batch {}), got {}",
                self.backend, self.in_elems, want, batch.batch_size, got
            );
        }
        Ok(())
    }

    /// Run the chain forward, filling `self.acts[li]` per layer.
    fn forward_all(&mut self, params: &[f32], batch: &Batch) -> Result<()> {
        self.check_input(batch)?;
        let bsz = batch.batch_size;
        let int_input = self.int_input;
        for li in 0..self.layers.len() {
            let (done, rest) = self.acts.split_at_mut(li);
            let y = &mut rest[0];
            let x = if li == 0 {
                input_act(int_input, batch)
            } else {
                Act::F32(&done[li - 1])
            };
            let x_len = match x {
                Act::F32(v) => v.len(),
                Act::I32(v) => v.len(),
            };
            let (off, len) = self.spans[li];
            self.layers[li].forward(
                &params[off..off + len],
                x,
                bsz,
                &mut self.tapes[li],
                &mut self.scratch,
                y,
            );
            debug_assert_eq!(
                y.len(),
                self.layers[li].out_len(x_len),
                "layer {li} output length breaks its out_len contract"
            );
        }
        Ok(())
    }

    /// logits view + class count after a forward pass.
    fn logits_and_classes(&self, batch: &Batch) -> Result<(&[f32], usize)> {
        let logits = self.acts.last().unwrap().as_slice();
        let rows = batch.y.len();
        if rows == 0 || logits.len() % rows != 0 {
            bail!(
                "head shape mismatch: {} logits vs {} labels",
                logits.len(),
                rows
            );
        }
        Ok((logits, logits.len() / rows))
    }
}

impl Executor for NativeNet {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        self.step_streamed(params, batch, &mut |_, _| {})
    }

    fn streams(&self) -> bool {
        true
    }

    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &Batch,
        on_ready: &mut GradReady<'_>,
    ) -> Result<StepOut> {
        let mut grads = Vec::new();
        let loss = self.step_streamed_into(params, batch, &mut grads, on_ready)?;
        Ok(StepOut { loss, grads })
    }

    /// The streamed step core: the backward walk fires `on_ready` the
    /// moment a graph layer's parameter-gradient spans are final — reverse
    /// graph order, so the head's layout layers arrive first and the input
    /// layers last. `step`/`step_streamed` are this with a no-op callback /
    /// a fresh grads vec, so all paths are bit-identical by construction.
    ///
    /// Gradients land in the caller's `grads` buffer; together with the
    /// persistent dy/dx ping-pong pair and the kernel scratch arena this
    /// makes a steady-state step allocation-free (rust/tests/alloc_free.rs).
    fn step_streamed_into(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grads: &mut Vec<f32>,
        on_ready: &mut GradReady<'_>,
    ) -> Result<f32> {
        let bsz = batch.batch_size;
        self.forward_all(params, batch)?;
        // Take the ping-pong pair out of self so layer calls can borrow
        // acts/tapes/scratch mutably alongside them (restored below).
        let mut dy = std::mem::take(&mut self.bwd_a);
        let mut dx = std::mem::take(&mut self.bwd_b);
        let loss = {
            let (logits, classes) = match self.logits_and_classes(batch) {
                Ok(v) => v,
                Err(e) => {
                    self.bwd_a = dy;
                    self.bwd_b = dx;
                    return Err(e);
                }
            };
            dy.clear();
            dy.resize(logits.len(), 0.0);
            ops::softmax_xent(logits, &batch.y, classes, &mut dy)
        };

        grads.clear();
        grads.resize(self.layout.total, 0.0);
        for li in (0..self.layers.len()).rev() {
            let (off, len) = self.spans[li];
            let x = if li == 0 {
                input_act(self.int_input, batch)
            } else {
                Act::F32(&self.acts[li - 1])
            };
            let want_dx = li > 0;
            self.layers[li].backward(
                &params[off..off + len],
                x,
                &self.acts[li],
                &mut self.tapes[li],
                &dy,
                bsz,
                &mut self.scratch,
                &mut grads[off..off + len],
                if want_dx { Some(&mut dx) } else { None },
            );
            let (ti, cnt) = self.lranges[li];
            if cnt > 0 {
                on_ready(ti..ti + cnt, grads);
            }
            if want_dx {
                std::mem::swap(&mut dy, &mut dx);
            }
        }
        self.bwd_a = dy;
        self.bwd_b = dx;
        Ok(loss)
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        self.forward_all(params, batch)?;
        let mut scratch = std::mem::take(&mut self.bwd_a);
        let out = {
            let (logits, classes) = match self.logits_and_classes(batch) {
                Ok(v) => v,
                Err(e) => {
                    self.bwd_a = scratch;
                    return Err(e);
                }
            };
            scratch.clear();
            scratch.resize(logits.len(), 0.0);
            let loss = ops::softmax_xent(logits, &batch.y, classes, &mut scratch);
            let ncorrect = ops::count_correct(logits, &batch.y, classes) as f32;
            EvalOut {
                loss_sum_weighted: loss,
                ncorrect,
            }
        };
        self.bwd_a = scratch;
        Ok(out)
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        Vec::new() // any
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }
}

/// Spec-is-the-factory (see `native.rs`): layer specs are immutable and
/// `Arc`-shared, so stamping a per-learner executor is a cheap clone and
/// every clone produces bit-identical results.
impl ExecutorFactory for NativeNet {
    fn backend(&self) -> &'static str {
        self.backend
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn fc_relu_fc() -> NativeNet {
        NativeNet::new(
            "test_net",
            vec![
                Arc::new(Fc::new("fc1", 6, 5)),
                Arc::new(Relu),
                Arc::new(Fc::new("fc2", 5, 3)),
            ],
            6,
            4,
        )
    }

    #[test]
    fn layout_spans_skip_stateless_layers() {
        let net = fc_relu_fc();
        let l = net.layout();
        assert_eq!(l.num_layers(), 4); // fc1_w fc1_b fc2_w fc2_b
        assert_eq!(l.layers[0].name, "fc1_w");
        assert_eq!(l.layers[2].name, "fc2_w");
        assert_eq!(net.spans[0], (0, 6 * 5 + 5));
        assert_eq!(net.spans[1], (0, 0)); // relu
        assert_eq!(net.spans[2], (35, 5 * 3 + 3));
        assert_eq!(l.total, 35 + 18);
    }

    #[test]
    fn step_produces_finite_loss_and_grads() {
        let mut net = fc_relu_fc();
        let mut rng = Pcg32::seeded(3);
        let params = rng.normal_vec(net.layout().total, 0.3);
        let x = rng.normal_vec(4 * 6, 1.0);
        let batch = Batch::f32(x, vec![0, 1, 2, 0], 4);
        let out = net.step(&params, &batch).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.grads.len(), net.layout().total);
        assert!(out.grads.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn embedding_lstm_head_runs_and_learns_shape() {
        let vocab = 11usize;
        let mut net = NativeNet::new(
            "test_lm",
            vec![
                Arc::new(Embedding {
                    name: "embed".into(),
                    vocab,
                    dim: 6,
                }),
                Arc::new(Lstm {
                    name: "lstm1".into(),
                    in_dim: 6,
                    hidden: 8,
                }),
                Arc::new(Fc::new("fc", 8, vocab)),
            ],
            5, // seq_len for this test
            2,
        );
        assert!(net.int_input());
        let mut rng = Pcg32::seeded(4);
        let params = rng.normal_vec(net.layout().total, 0.2);
        let (bsz, t) = (2usize, 5usize);
        let x: Vec<i32> = (0..bsz * t).map(|i| (i % vocab) as i32).collect();
        let y: Vec<i32> = (0..bsz * t).map(|i| ((i + 1) % vocab) as i32).collect();
        let batch = Batch::i32(x, y, bsz);
        let out = net.step(&params, &batch).unwrap();
        assert!(out.loss.is_finite());
        // embedding rows for unseen ids keep zero gradient
        let emb_len = vocab * 6;
        assert_eq!(net.layout().layers[0].len(), emb_len);
        // lstm + fc kinds recorded for the compression path
        assert_eq!(net.layout().layers[1].kind, LayerKind::Lstm);
        assert_eq!(net.layout().layers[0].kind, LayerKind::Embed);
        assert_eq!(net.layout().layers[4].kind, LayerKind::Fc);
    }

    #[test]
    fn step_streamed_partitions_layers_in_reverse_with_final_spans() {
        let mut net = fc_relu_fc();
        let mut rng = Pcg32::seeded(9);
        let params = rng.normal_vec(net.layout().total, 0.3);
        let x = rng.normal_vec(4 * 6, 1.0);
        let batch = Batch::f32(x, vec![0, 1, 2, 0], 4);
        assert!(net.streams());

        let mut ranges: Vec<std::ops::Range<usize>> = Vec::new();
        let mut snapshots: Vec<Vec<f32>> = Vec::new();
        let layout = net.layout().clone();
        let out = net
            .step_streamed(&params, &batch, &mut |r, grads| {
                for li in r.clone() {
                    snapshots.push(layout.view(li, grads).to_vec());
                }
                ranges.push(r);
            })
            .unwrap();
        // fc2 (layout layers 2..4) completes before fc1 (0..2); relu is silent
        assert_eq!(ranges, vec![2..4, 0..2]);
        // every notified span was already final: it matches the returned grads
        let mut si = 0;
        for r in &ranges {
            for li in r.clone() {
                assert_eq!(snapshots[si], layout.view(li, &out.grads), "layer {li}");
                si += 1;
            }
        }
        // and the streamed path is bit-identical to the plain step
        let plain = net.step(&params, &batch).unwrap();
        assert_eq!(plain.loss.to_bits(), out.loss.to_bits());
        assert_eq!(plain.grads, out.grads);
    }

    #[test]
    fn streamed_step_is_bit_identical_across_kernel_thread_budgets() {
        use crate::tensor::parallel;
        // fc1's forward GEMM (64x256 @ 256x128) crosses gemm::MIN_PAR_FLOPS,
        // so the parallel tile grid is actually exercised, not gated off
        let mut net = NativeNet::new(
            "test_wide",
            vec![
                Arc::new(Fc::new("fc1", 256, 128)),
                Arc::new(Relu),
                Arc::new(Fc::new("fc2", 128, 10)),
            ],
            256,
            4,
        );
        let mut rng = Pcg32::seeded(11);
        let params = rng.normal_vec(net.layout().total, 0.2);
        let bsz = 64usize;
        let x = rng.normal_vec(bsz * 256, 1.0);
        let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
        let batch = Batch::f32(x, y, bsz);
        let mut base: Option<(u32, Vec<u32>)> = None;
        for t in [1usize, 2, 4] {
            parallel::set_kernel_threads(t);
            let mut grads = Vec::new();
            let loss = net
                .step_streamed_into(&params, &batch, &mut grads, &mut |_, _| {})
                .unwrap();
            let gbits: Vec<u32> = grads.iter().map(|g| g.to_bits()).collect();
            match &base {
                None => base = Some((loss.to_bits(), gbits)),
                Some((lb, gb)) => {
                    assert_eq!(loss.to_bits(), *lb, "kernel_threads={t}");
                    assert_eq!(&gbits, gb, "kernel_threads={t}");
                }
            }
        }
        parallel::set_kernel_threads(1);
    }

    #[test]
    fn x_length_mismatch_errors() {
        let mut net = fc_relu_fc();
        let params = vec![0.0f32; net.layout().total];
        let batch = Batch::f32(vec![0.0; 7], vec![0], 1);
        assert!(net.step(&params, &batch).is_err());
    }
}
