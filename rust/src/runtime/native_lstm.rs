//! Hermetic char-LSTM executor — the paper's recurrent workload (Table 2,
//! Shakespeare char-RNN) as a layer-graph spec: `Embedding -> Lstm x N ->
//! Fc head`, per-timestep softmax cross-entropy.
//!
//! The exported `char_lstm` (python/compile/model.py) feeds one-hot vectors
//! into the first LSTM; the native spec uses a learned embedding table
//! instead, which exercises the fourth layer kind (`LayerKind::Embed`,
//! L_T default 500) end-to-end in the compression path. Sequence length is
//! inferred from the batch, so one spec serves any `--seq-len`.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::net::{Embedding, Fc, Layer, Lstm, NativeNet};
use super::{Batch, EvalOut, Executor, ExecutorFactory, GradReady, StepOut};
use crate::models::Layout;

#[derive(Clone)]
pub struct NativeCharLstm {
    pub vocab: usize,
    pub embed_dim: usize,
    pub hiddens: Vec<usize>,
    net: NativeNet,
}

impl NativeCharLstm {
    /// `hiddens` is the LSTM stack (paper: `[512, 512]`; hermetic tests use
    /// much smaller). Input batches carry `seq_len` i32 char ids per sample
    /// (`Batch::i32`), labels are the next-char ids, `seq_len` per sample.
    pub fn new(
        vocab: usize,
        embed_dim: usize,
        hiddens: &[usize],
        eval_batch: usize,
    ) -> Result<NativeCharLstm> {
        if vocab == 0 || embed_dim == 0 {
            bail!("char-lstm needs vocab > 0 and embed_dim > 0");
        }
        if hiddens.is_empty() || hiddens.contains(&0) {
            bail!("char-lstm needs at least one nonzero LSTM hidden size");
        }
        let mut layers: Vec<Arc<dyn Layer>> = Vec::with_capacity(hiddens.len() + 2);
        layers.push(Arc::new(Embedding {
            name: "embed".into(),
            vocab,
            dim: embed_dim,
        }));
        let mut in_dim = embed_dim;
        for (i, &h) in hiddens.iter().enumerate() {
            layers.push(Arc::new(Lstm {
                name: format!("lstm{}", i + 1),
                in_dim,
                hidden: h,
            }));
            in_dim = h;
        }
        layers.push(Arc::new(Fc::new("fc", in_dim, vocab)));
        Ok(NativeCharLstm {
            vocab,
            embed_dim,
            hiddens: hiddens.to_vec(),
            // in_elems = 1 id per (sample, timestep); the net sees
            // seq_len-per-sample batches, so per-sample elems is seq_len —
            // but seq_len is batch-determined, so we validate per-step via
            // the head instead (see `check_batch`).
            net: NativeNet::new("native_char_lstm", layers, 1, eval_batch),
        })
    }

    /// Scaled default mirroring the paper's shape at CPU-testbed size:
    /// vocab 67, embed 32, 2 LSTM layers of 64.
    pub fn scaled(eval_batch: usize) -> NativeCharLstm {
        NativeCharLstm::new(crate::data::shakespeare::VOCAB, 32, &[64, 64], eval_batch)
            .expect("static dims are valid")
    }

    pub fn layout(&self) -> &Layout {
        self.net.layout()
    }

    /// Deterministic init mirroring the exporter's distribution family:
    /// embedding and LSTM weights at gain 1, forget-gate bias 1, fc head at
    /// He gain 2.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let layout = self.net.layout();
        let mut rng = crate::util::rng::Pcg32::new(seed, 0x157a);
        let mut out = vec![0.0f32; layout.total];
        for l in layout.layers.iter() {
            let seg = &mut out[l.offset..l.offset + l.len()];
            match l.name.as_str() {
                "embed" => {
                    let std = (1.0 / self.vocab as f32).sqrt();
                    seg.iter_mut().for_each(|v| *v = rng.normal() * std);
                }
                n if n.ends_with("_wx") || n.ends_with("_wh") => {
                    let std = (1.0 / l.shape[0] as f32).sqrt();
                    seg.iter_mut().for_each(|v| *v = rng.normal() * std);
                }
                n if n.ends_with("_b") && n.starts_with("lstm") => {
                    // forget-gate block gets bias 1 (gate order i,f,g,o)
                    let h = l.len() / 4;
                    seg[h..2 * h].iter_mut().for_each(|v| *v = 1.0);
                }
                "fc_w" => {
                    let std = (2.0 / l.shape[0] as f32).sqrt();
                    seg.iter_mut().for_each(|v| *v = rng.normal() * std);
                }
                _ => {} // fc_b stays zero
            }
        }
        out
    }

    /// seq_len is carried by the batch; x and y must both hold
    /// `batch_size * seq_len` ids.
    fn check_batch(&self, batch: &Batch) -> Result<()> {
        if batch.x_i32.is_empty() {
            bail!("char-lstm takes i32 char-id batches (Batch::i32)");
        }
        if batch.x_i32.len() != batch.y.len() {
            bail!(
                "char-lstm x/y length mismatch: {} ids vs {} labels",
                batch.x_i32.len(),
                batch.y.len()
            );
        }
        if batch.x_i32.len() % batch.batch_size != 0 {
            bail!("char-lstm batch not divisible into sequences");
        }
        Ok(())
    }
}

/// See [`NativeMlp`](super::native::NativeMlp): the spec is the factory;
/// per-learner clones are cheap and bit-identical.
impl ExecutorFactory for NativeCharLstm {
    fn backend(&self) -> &'static str {
        "native_char_lstm"
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

impl Executor for NativeCharLstm {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        self.check_batch(batch)?;
        // the net's in_elems check expects seq_len ids per sample; feed it
        // a batch-shaped view by treating (bsz * seq_len) as the row count.
        let seq_len = batch.x_i32.len() / batch.batch_size;
        self.net.set_in_elems(seq_len);
        self.net.step(params, batch)
    }

    fn streams(&self) -> bool {
        self.net.streams()
    }

    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &Batch,
        on_ready: &mut GradReady<'_>,
    ) -> Result<StepOut> {
        self.check_batch(batch)?;
        let seq_len = batch.x_i32.len() / batch.batch_size;
        self.net.set_in_elems(seq_len);
        self.net.step_streamed(params, batch, on_ready)
    }

    fn step_streamed_into(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grads: &mut Vec<f32>,
        on_ready: &mut GradReady<'_>,
    ) -> Result<f32> {
        self.check_batch(batch)?;
        let seq_len = batch.x_i32.len() / batch.batch_size;
        self.net.set_in_elems(seq_len);
        self.net.step_streamed_into(params, batch, grads, on_ready)
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        self.check_batch(batch)?;
        let seq_len = batch.x_i32.len() / batch.batch_size;
        self.net.set_in_elems(seq_len);
        self.net.eval(params, batch)
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        self.net.step_batch_sizes()
    }

    fn eval_batch(&self) -> usize {
        self.net.eval_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny() -> NativeCharLstm {
        NativeCharLstm::new(11, 6, &[8], 4).unwrap()
    }

    fn toy_batch(bsz: usize, t: usize, vocab: usize, seed: u64) -> Batch {
        let mut rng = Pcg32::seeded(seed);
        let x: Vec<i32> = (0..bsz * t).map(|_| rng.below(vocab as u32) as i32).collect();
        // next-char labels: a fixed rotation makes the task learnable
        let y: Vec<i32> = x.iter().map(|&c| (c + 1) % vocab as i32).collect();
        Batch::i32(x, y, bsz)
    }

    #[test]
    fn layout_covers_all_kinds() {
        use crate::models::LayerKind;
        let m = tiny();
        let l = m.layout();
        // embed + (wx, wh, b) + (fc_w, fc_b)
        assert_eq!(l.num_layers(), 6);
        assert_eq!(l.layers[0].kind, LayerKind::Embed);
        assert_eq!(l.layers[0].lt_default, 500);
        assert_eq!(l.layers[1].kind, LayerKind::Lstm);
        assert_eq!(l.layers[1].shape, vec![6, 32]);
        assert_eq!(l.layers[2].shape, vec![8, 32]);
        assert_eq!(l.layers[4].shape, vec![8, 11]);
    }

    #[test]
    fn forget_bias_initialized() {
        let m = tiny();
        let p = m.init_params(1);
        let l = &m.layout().layers[3]; // lstm1_b
        assert_eq!(l.name, "lstm1_b");
        let b = &p[l.offset..l.offset + l.len()];
        let h = l.len() / 4;
        assert!(b[..h].iter().all(|&v| v == 0.0));
        assert!(b[h..2 * h].iter().all(|&v| v == 1.0));
        assert!(b[2 * h..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradient_matches_numerical() {
        let mut m = tiny();
        let params = m.init_params(2);
        let batch = toy_batch(3, 4, 11, 5);
        let out = m.step(&params, &batch).unwrap();
        let eps = 1e-2;
        let mut rng = Pcg32::seeded(7);
        for _ in 0..12 {
            let i = rng.below(params.len() as u32) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let lp = m.step(&pp, &batch).unwrap().loss;
            let lm = m.step(&pm, &batch).unwrap().loss;
            let num = (lp - lm) / (2.0 * eps);
            let ana = out.grads[i];
            assert!(
                (num - ana).abs() < 3e-2_f32.max(0.1 * num.abs()),
                "grad[{i}] num {num} ana {ana}"
            );
        }
    }

    #[test]
    fn sgd_learns_rotation_task() {
        // y = x+1 mod vocab is learnable from the embedding alone
        let mut m = tiny();
        let mut params = m.init_params(3);
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..150 {
            let b = toy_batch(8, 6, 11, 100 + step as u64);
            let out = m.step(&params, &b).unwrap();
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (p, g) in params.iter_mut().zip(out.grads.iter()) {
                *p -= 0.3 * g;
            }
        }
        assert!(last < first * 0.7, "first {first} last {last}");
    }

    #[test]
    fn rejects_f32_batches() {
        let mut m = tiny();
        let params = m.init_params(1);
        let batch = Batch::f32(vec![0.0; 8], vec![0; 8], 2);
        assert!(m.step(&params, &batch).is_err());
    }
}
