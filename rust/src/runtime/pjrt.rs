//! PJRT executor: loads AOT HLO text artifacts and runs them on the CPU
//! PJRT client through the `xla` crate (xla_extension 0.5.1).
//!
//! Interchange is HLO *text* — see DESIGN.md §Interchange and
//! /opt/xla-example/README.md for why serialized protos are rejected.
//! Executables are compiled lazily per batch size and cached.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{Batch, EvalOut, Executor, ExecutorFactory, StepOut};
use crate::models::{Manifest, ModelMeta};
use crate::util::json::Json;

/// Executor factory for the PJRT backend.
///
/// `PjrtExecutor` is deliberately `!Send` (the PJRT client wraps a
/// thread-local `Rc`, and compiled executables cache per client), so this
/// factory reports `parallel() == false`: the engine keeps every learner on
/// the calling thread and drives them sequentially through one shared
/// executor — the documented fallback behind the same `ExecutorFactory`
/// API (DESIGN.md §Threading).
pub struct PjrtFactory {
    manifest: Manifest,
    model: String,
}

impl PjrtFactory {
    pub fn new(manifest: Manifest, model: impl Into<String>) -> PjrtFactory {
        PjrtFactory {
            manifest,
            model: model.into(),
        }
    }
}

impl ExecutorFactory for PjrtFactory {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn parallel(&self) -> bool {
        false
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        bail!(
            "PJRT executors are not Send (thread-local Rc client); \
             the engine must use the sequential fallback (parallel() == false)"
        )
    }

    fn build_local(&self) -> Result<Box<dyn Executor>> {
        Ok(Box::new(PjrtExecutor::new(&self.manifest, &self.model)?))
    }
}

/// Shared PJRT client — one per thread (the client wraps an `Rc`, so it is
/// deliberately not `Send`; the engine is single-threaded anyway).
pub fn client() -> Result<xla::PjRtClient> {
    use std::cell::RefCell;
    thread_local! {
        static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
    }
    CLIENT.with(|c| {
        let mut c = c.borrow_mut();
        if c.is_none() {
            *c = Some(xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?);
        }
        Ok(c.as_ref().unwrap().clone())
    })
}

/// Compile an HLO text file on the shared client.
pub fn compile_hlo(path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client()?
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

pub struct PjrtExecutor {
    meta: ModelMeta,
    dir: String,
    /// batch size -> step-HLO path (from the manifest's step_hlos map).
    step_paths: HashMap<usize, String>,
    step_cache: HashMap<usize, xla::PjRtLoadedExecutable>,
    eval_exe: Option<xla::PjRtLoadedExecutable>,
    /// Parameter tensor shapes as i64 dims, layout order.
    param_dims: Vec<Vec<i64>>,
}

impl PjrtExecutor {
    pub fn new(manifest: &Manifest, model: &str) -> Result<PjrtExecutor> {
        let meta = manifest.model(model)?.clone();
        // step_hlos lives in the manifest json; re-read for the batch map.
        let txt = std::fs::read_to_string(Path::new(&manifest.dir).join("manifest.json"))?;
        let v = Json::from_str_slice(&txt).map_err(|e| anyhow!("manifest: {e}"))?;
        let hlos = v.get("models").get(model).get("step_hlos");
        let mut step_paths = HashMap::new();
        if let Some(obj) = hlos.as_obj() {
            for (b, p) in obj {
                let bs: usize = b.parse().context("step_hlos batch key")?;
                step_paths.insert(bs, p.as_str().context("step_hlos path")?.to_string());
            }
        } else {
            step_paths.insert(meta.batch, meta.step_hlo.clone());
        }
        let param_dims = meta
            .layout
            .layers
            .iter()
            .map(|l| l.shape.iter().map(|&d| d as i64).collect())
            .collect();
        Ok(PjrtExecutor {
            meta,
            dir: manifest.dir.clone(),
            step_paths,
            step_cache: HashMap::new(),
            eval_exe: None,
            param_dims,
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn step_exe(&mut self, batch: usize) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.step_cache.contains_key(&batch) {
            let rel = self
                .step_paths
                .get(&batch)
                .with_context(|| {
                    format!(
                        "model {} has no step HLO for batch {} (have {:?})",
                        self.meta.name,
                        batch,
                        {
                            let mut v: Vec<usize> = self.step_paths.keys().copied().collect();
                            v.sort_unstable();
                            v
                        }
                    )
                })?
                .clone();
            let exe = compile_hlo(&Path::new(&self.dir).join(rel))?;
            self.step_cache.insert(batch, exe);
        }
        Ok(&self.step_cache[&batch])
    }

    fn literals(&self, params: &[f32], batch: &Batch) -> Result<Vec<xla::Literal>> {
        if params.len() != self.meta.layout.total {
            bail!(
                "params length {} != layout total {}",
                params.len(),
                self.meta.layout.total
            );
        }
        let mut lits = Vec::with_capacity(self.param_dims.len() + 2);
        for (i, dims) in self.param_dims.iter().enumerate() {
            let l = &self.meta.layout.layers[i];
            let flat = &params[l.offset..l.offset + l.len()];
            let lit = xla::Literal::vec1(flat);
            lits.push(if dims.is_empty() {
                lit
            } else {
                lit.reshape(dims).map_err(|e| anyhow!("param reshape: {e:?}"))?
            });
        }
        // x
        let bs = batch.batch_size;
        let mut x_dims: Vec<i64> = self.meta.x_shape.iter().map(|&d| d as i64).collect();
        x_dims[0] = bs as i64;
        let x_lit = if self.meta.x_is_int {
            xla::Literal::vec1(&batch.x_i32)
        } else {
            xla::Literal::vec1(&batch.x_f32)
        };
        lits.push(x_lit.reshape(&x_dims).map_err(|e| anyhow!("x reshape: {e:?}"))?);
        // y
        let mut y_dims: Vec<i64> = self.meta.y_shape.iter().map(|&d| d as i64).collect();
        y_dims[0] = bs as i64;
        lits.push(
            xla::Literal::vec1(&batch.y)
                .reshape(&y_dims)
                .map_err(|e| anyhow!("y reshape: {e:?}"))?,
        );
        Ok(lits)
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        lits: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe
            .execute::<xla::Literal>(lits)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let root = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        root.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

impl Executor for PjrtExecutor {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        let lits = self.literals(params, batch)?;
        let exe = self.step_exe(batch.batch_size)?;
        let parts = Self::run(exe, &lits)?;
        if parts.len() != 1 + self.param_dims.len() {
            bail!(
                "step returned {} parts, expected loss + {} grads",
                parts.len(),
                self.param_dims.len()
            );
        }
        let loss = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let mut grads = vec![0.0f32; self.meta.layout.total];
        for (i, part) in parts[1..].iter().enumerate() {
            let l = &self.meta.layout.layers[i];
            let v = part
                .to_vec::<f32>()
                .map_err(|e| anyhow!("grad {i}: {e:?}"))?;
            if v.len() != l.len() {
                bail!("grad {i} length {} != {}", v.len(), l.len());
            }
            grads[l.offset..l.offset + l.len()].copy_from_slice(&v);
        }
        Ok(StepOut { loss, grads })
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        if self.eval_exe.is_none() {
            self.eval_exe = Some(compile_hlo(
                &Path::new(&self.dir).join(&self.meta.eval_hlo),
            )?);
        }
        let lits = self.literals(params, batch)?;
        let parts = Self::run(self.eval_exe.as_ref().unwrap(), &lits)?;
        if parts.len() != 2 {
            bail!("eval returned {} parts, expected (loss, ncorrect)", parts.len());
        }
        let loss = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let ncorrect = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok(EvalOut {
            loss_sum_weighted: loss,
            ncorrect,
        })
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.step_paths.keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn eval_batch(&self) -> usize {
        self.meta.batch
    }
}
