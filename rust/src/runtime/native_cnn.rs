//! Hermetic CNN executor — a thin spec-builder over the layer graph.
//!
//! `NativeCnn` assembles a Caffe-quick-style stack — per stage `[Conv5x5Same,
//! Relu, MaxPool2]`, then an `Fc` head — on [`NativeNet`](super::net::NativeNet);
//! the same architecture family as the paper's MNIST-CNN / CIFAR10-CNN and
//! bit-identical to the pre-graph monolithic executor (same kernels, same
//! call order). Layout convention matches the python exporter: per conv
//! layer (`w [kh,kw,cin,cout]`, `b [cout]`), then (`fc_w [flat,classes]`,
//! `fc_b`).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::net::{Conv5x5Same, Fc, Layer, MaxPool2, NativeNet, Relu};
use super::{Batch, EvalOut, Executor, ExecutorFactory, GradReady, StepOut};
use crate::models::Layout;

/// One conv stage: 5x5 SAME conv -> relu -> 2x2 maxpool.
#[derive(Debug, Clone, Copy)]
pub struct ConvStage {
    pub cin: usize,
    pub cout: usize,
}

#[derive(Clone)]
pub struct NativeCnn {
    pub h: usize,
    pub w: usize,
    pub stages: Vec<ConvStage>,
    pub classes: usize,
    net: NativeNet,
}

impl NativeCnn {
    /// Build the stack, validating that every 2x2 pool halves the spatial
    /// dims exactly: `h` and `w` must be divisible by `2^stages` (the old
    /// monolith silently computed a wrong flattened size via `h >> stages`
    /// for e.g. 28x28 with 3 stages).
    pub fn new(
        h: usize,
        w: usize,
        stages: &[ConvStage],
        classes: usize,
        eval_batch: usize,
    ) -> Result<NativeCnn> {
        if stages.is_empty() {
            bail!("NativeCnn needs at least one conv stage");
        }
        let div = 1usize << stages.len();
        if h % div != 0 || w % div != 0 || h / div == 0 || w / div == 0 {
            bail!(
                "NativeCnn: input {}x{} is not exactly poolable through {} 2x2 stages \
                 (needs h and w divisible by {div} with a nonzero result); got {}x{} after pooling",
                h,
                w,
                stages.len(),
                h / div,
                w / div
            );
        }
        let mut layers: Vec<Arc<dyn Layer>> = Vec::with_capacity(3 * stages.len() + 1);
        let (mut sh, mut sw) = (h, w);
        for (i, s) in stages.iter().enumerate() {
            layers.push(Arc::new(Conv5x5Same {
                name: format!("conv{}", i + 1),
                h: sh,
                w: sw,
                cin: s.cin,
                cout: s.cout,
            }));
            layers.push(Arc::new(Relu));
            layers.push(Arc::new(MaxPool2 {
                h: sh,
                w: sw,
                c: s.cout,
            }));
            sh /= 2;
            sw /= 2;
        }
        let flat = sh * sw * stages.last().unwrap().cout;
        layers.push(Arc::new(Fc::new("fc", flat, classes)));
        Ok(NativeCnn {
            h,
            w,
            stages: stages.to_vec(),
            classes,
            net: NativeNet::new("native_cnn", layers, h * w * stages[0].cin, eval_batch),
        })
    }

    /// CIFAR-quick shape: 3 conv stages (3->32->32->64) + 10-way FC on 32x32x3.
    pub fn cifar_quick(eval_batch: usize) -> NativeCnn {
        NativeCnn::new(
            32,
            32,
            &[
                ConvStage { cin: 3, cout: 32 },
                ConvStage { cin: 32, cout: 32 },
                ConvStage { cin: 32, cout: 64 },
            ],
            10,
            eval_batch,
        )
        .expect("32x32 divides 3 pool stages")
    }

    pub fn layout(&self) -> &Layout {
        self.net.layout()
    }

    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let layout = self.net.layout();
        let mut rng = crate::util::rng::Pcg32::new(seed, 0xc44);
        let mut out = vec![0.0f32; layout.total];
        for l in layout.layers.iter() {
            if l.shape.len() >= 2 {
                let fan_in: usize = l.shape[..l.shape.len() - 1].iter().product();
                let std = (2.0 / fan_in as f32).sqrt();
                for v in out[l.offset..l.offset + l.len()].iter_mut() {
                    *v = rng.normal() * std;
                }
            }
        }
        out
    }
}

/// See [`NativeMlp`](super::native::NativeMlp): the spec is the factory;
/// per-learner clones are cheap and bit-identical.
impl ExecutorFactory for NativeCnn {
    fn backend(&self) -> &'static str {
        "native_cnn"
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

impl Executor for NativeCnn {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        self.net.step(params, batch)
    }

    fn streams(&self) -> bool {
        self.net.streams()
    }

    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &Batch,
        on_ready: &mut GradReady<'_>,
    ) -> Result<StepOut> {
        self.net.step_streamed(params, batch, on_ready)
    }

    fn step_streamed_into(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grads: &mut Vec<f32>,
        on_ready: &mut GradReady<'_>,
    ) -> Result<f32> {
        self.net.step_streamed_into(params, batch, grads, on_ready)
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        self.net.eval(params, batch)
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        self.net.step_batch_sizes()
    }

    fn eval_batch(&self) -> usize {
        self.net.eval_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny() -> NativeCnn {
        NativeCnn::new(
            8,
            8,
            &[ConvStage { cin: 2, cout: 4 }, ConvStage { cin: 4, cout: 4 }],
            3,
            4,
        )
        .unwrap()
    }

    #[test]
    fn layout_shapes() {
        let m = tiny();
        assert_eq!(m.layout().num_layers(), 6);
        // final spatial 2x2 x 4 channels = 16 features
        assert_eq!(m.layout().layers[4].shape, vec![16, 3]);
    }

    #[test]
    fn indivisible_dims_rejected() {
        // 28x28 through 3 pool stages (28 % 8 != 0) must error loudly, not
        // silently train on a truncated flat size.
        let stages = [
            ConvStage { cin: 1, cout: 4 },
            ConvStage { cin: 4, cout: 4 },
            ConvStage { cin: 4, cout: 4 },
        ];
        let err = NativeCnn::new(28, 28, &stages, 10, 4).unwrap_err().to_string();
        assert!(err.contains("28x28"), "{err}");
        assert!(err.contains("divisible"), "{err}");
        // 28x28 with 2 stages is fine (28 -> 14 -> 7)
        assert!(NativeCnn::new(28, 28, &stages[..2], 10, 4).is_ok());
        // degenerate: pooling to zero rejected
        assert!(NativeCnn::new(4, 4, &stages, 10, 4).is_err());
        // no stages rejected
        assert!(NativeCnn::new(8, 8, &[], 10, 4).is_err());
    }

    #[test]
    fn gradient_matches_numerical() {
        let mut m = tiny();
        let params = m.init_params(1);
        let mut rng = Pcg32::seeded(2);
        let x = rng.normal_vec(4 * 8 * 8 * 2, 1.0);
        let y: Vec<i32> = vec![0, 1, 2, 1];
        let batch = Batch::f32(x, y, 4);
        let out = m.step(&params, &batch).unwrap();
        let eps = 1e-2;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10 {
            let i = rng.below(params.len() as u32) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let lp = m.step(&pp, &batch).unwrap().loss;
            let lm = m.step(&pm, &batch).unwrap().loss;
            let num = (lp - lm) / (2.0 * eps);
            let ana = out.grads[i];
            assert!(
                (num - ana).abs() < 3e-2_f32.max(0.15 * num.abs()),
                "grad[{i}] num {num} ana {ana}"
            );
        }
    }

    #[test]
    fn learns_channel_separable_task() {
        // class = which input channel carries signal
        let mut m = NativeCnn::new(8, 8, &[ConvStage { cin: 3, cout: 8 }], 3, 16).unwrap();
        let mut params = m.init_params(5);
        let mut rng = Pcg32::seeded(6);
        let gen = |rng: &mut Pcg32, n: usize| {
            let mut x = vec![0.0f32; n * 8 * 8 * 3];
            let mut y = vec![0i32; n];
            for s in 0..n {
                let cls = rng.below(3) as usize;
                for p in 0..64 {
                    x[(s * 64 + p) * 3 + cls] = 1.0 + 0.3 * rng.normal();
                    for c in 0..3 {
                        x[(s * 64 + p) * 3 + c] += 0.2 * rng.normal();
                    }
                }
                y[s] = cls as i32;
            }
            Batch::f32(x, y, n)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..60 {
            let b = gen(&mut rng, 16);
            let out = m.step(&params, &b).unwrap();
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (p, g) in params.iter_mut().zip(out.grads.iter()) {
                *p -= 0.1 * g;
            }
        }
        assert!(last < first * 0.5, "first {first} last {last}");
    }
}
