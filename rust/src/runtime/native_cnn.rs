//! Pure-rust CNN executor: a Caffe-quick-style stack of SAME 5x5 convs with
//! 2x2 max-pools and a final FC head — the same architecture family as the
//! paper's MNIST-CNN / CIFAR10-CNN. Used for hermetic conv-path integration
//! tests and as an independent numerical cross-check of the PJRT path.
//!
//! Layout convention matches the python exporter: per conv layer
//! (w [kh,kw,cin,cout], b [cout]), then (fc_w [flat,classes], fc_b).

use anyhow::{bail, Result};

use super::{Batch, EvalOut, Executor, ExecutorFactory, StepOut};
use crate::models::{LayerKind, Layout};
use crate::tensor::{conv, ops};

/// One conv stage: 5x5 SAME conv -> relu -> 2x2 maxpool.
#[derive(Debug, Clone, Copy)]
pub struct ConvStage {
    pub cin: usize,
    pub cout: usize,
}

#[derive(Clone)]
pub struct NativeCnn {
    pub h: usize,
    pub w: usize,
    pub stages: Vec<ConvStage>,
    pub classes: usize,
    layout: Layout,
    eval_batch: usize,
    k: usize, // kernel size (5)
}

impl NativeCnn {
    pub fn new(h: usize, w: usize, stages: &[ConvStage], classes: usize, eval_batch: usize) -> NativeCnn {
        let k = 5usize;
        let mut specs: Vec<(String, Vec<usize>, LayerKind)> = Vec::new();
        for (i, s) in stages.iter().enumerate() {
            specs.push((format!("conv{}_w", i + 1), vec![k, k, s.cin, s.cout], LayerKind::Conv));
            specs.push((format!("conv{}_b", i + 1), vec![s.cout], LayerKind::Conv));
        }
        let (fh, fw) = (h >> stages.len(), w >> stages.len());
        let flat = fh * fw * stages.last().unwrap().cout;
        specs.push(("fc_w".into(), vec![flat, classes], LayerKind::Fc));
        specs.push(("fc_b".into(), vec![classes], LayerKind::Fc));
        let layout = Layout::from_specs(
            &specs
                .iter()
                .map(|(n, s, kk)| (n.as_str(), s.as_slice(), *kk))
                .collect::<Vec<_>>(),
        );
        NativeCnn {
            h,
            w,
            stages: stages.to_vec(),
            classes,
            layout,
            eval_batch,
            k,
        }
    }

    /// CIFAR-quick shape: 3 conv stages (3->32->32->64) + 10-way FC on 32x32x3.
    pub fn cifar_quick(eval_batch: usize) -> NativeCnn {
        NativeCnn::new(
            32,
            32,
            &[
                ConvStage { cin: 3, cout: 32 },
                ConvStage { cin: 32, cout: 32 },
                ConvStage { cin: 32, cout: 64 },
            ],
            10,
            eval_batch,
        )
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::new(seed, 0xc44);
        let mut out = vec![0.0f32; self.layout.total];
        for l in self.layout.layers.iter() {
            if l.shape.len() >= 2 {
                let fan_in: usize = l.shape[..l.shape.len() - 1].iter().product();
                let std = (2.0 / fan_in as f32).sqrt();
                for v in out[l.offset..l.offset + l.len()].iter_mut() {
                    *v = rng.normal() * std;
                }
            }
        }
        out
    }

    /// Forward pass caching everything the backward needs.
    fn forward(&self, params: &[f32], x: &[f32], bsz: usize) -> Fwd {
        let mut acts = vec![x.to_vec()]; // post-pool activations per stage input
        let mut pre_pool = Vec::new(); // post-relu pre-pool
        let mut argmaxes = Vec::new();
        let (mut h, mut w) = (self.h, self.w);
        let mut cols = Vec::new();
        for (i, s) in self.stages.iter().enumerate() {
            let wgt = self.layout.view(2 * i, params);
            let bias = self.layout.view(2 * i + 1, params);
            let mut y = Vec::new();
            conv::conv2d_same(
                acts.last().unwrap(),
                wgt,
                bias,
                bsz,
                h,
                w,
                s.cin,
                self.k,
                self.k,
                s.cout,
                &mut cols,
                &mut y,
            );
            ops::relu(&mut y);
            let mut pooled = Vec::new();
            let mut am = Vec::new();
            conv::maxpool2(&y, bsz, h, w, s.cout, &mut pooled, &mut am);
            pre_pool.push(y);
            argmaxes.push(am);
            acts.push(pooled);
            h /= 2;
            w /= 2;
        }
        let nf = self.layout.layers[2 * self.stages.len()].shape[0];
        let fw = self.layout.view(2 * self.stages.len(), params);
        let fb = self.layout.view(2 * self.stages.len() + 1, params);
        let mut logits = vec![0.0f32; bsz * self.classes];
        ops::matmul(acts.last().unwrap(), fw, &mut logits, bsz, nf, self.classes, false);
        for r in 0..bsz {
            for c in 0..self.classes {
                logits[r * self.classes + c] += fb[c];
            }
        }
        Fwd {
            acts,
            pre_pool,
            argmaxes,
            logits,
        }
    }
}

struct Fwd {
    acts: Vec<Vec<f32>>,
    pre_pool: Vec<Vec<f32>>,
    argmaxes: Vec<Vec<u32>>,
    logits: Vec<f32>,
}

/// See [`NativeMlp`](super::native::NativeMlp): the spec is the factory;
/// per-learner clones are cheap and bit-identical.
impl ExecutorFactory for NativeCnn {
    fn backend(&self) -> &'static str {
        "native_cnn"
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

impl Executor for NativeCnn {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        let bsz = batch.batch_size;
        if batch.x_f32.len() != bsz * self.h * self.w * self.stages[0].cin {
            bail!("x length mismatch");
        }
        let f = self.forward(params, &batch.x_f32, bsz);
        let mut dlogits = vec![0.0f32; bsz * self.classes];
        let loss = ops::softmax_xent(&f.logits, &batch.y, self.classes, &mut dlogits);

        let mut grads = vec![0.0f32; self.layout.total];
        let ns = self.stages.len();
        let nf = self.layout.layers[2 * ns].shape[0];
        // FC backward
        {
            let gw = self.layout.view_mut(2 * ns, &mut grads);
            ops::matmul_at_b(f.acts.last().unwrap(), &dlogits, gw, nf, bsz, self.classes);
        }
        {
            let gb = self.layout.view_mut(2 * ns + 1, &mut grads);
            for r in 0..bsz {
                for c in 0..self.classes {
                    gb[c] += dlogits[r * self.classes + c];
                }
            }
        }
        let fw = self.layout.view(2 * ns, params);
        let mut dpool = vec![0.0f32; bsz * nf];
        ops::matmul_a_bt(&dlogits, fw, &mut dpool, bsz, self.classes, nf);

        // conv stages backward
        let (mut h, mut w) = (self.h >> ns, self.w >> ns);
        let mut cols = Vec::new();
        let mut dout = dpool;
        for i in (0..ns).rev() {
            let s = self.stages[i];
            h *= 2;
            w *= 2;
            // unpool
            let mut dy = vec![0.0f32; bsz * h * w * s.cout];
            conv::maxpool2_bwd(&dout, &f.argmaxes[i], &mut dy);
            // relu
            ops::relu_grad(&f.pre_pool[i], &mut dy);
            // conv
            let wgt = self.layout.view(2 * i, params);
            let mut dw = vec![0.0f32; self.layout.layers[2 * i].len()];
            let mut db = vec![0.0f32; s.cout];
            let mut dx = if i > 0 {
                Some(vec![0.0f32; bsz * h * w * s.cin])
            } else {
                None
            };
            conv::conv2d_same_bwd(
                &f.acts[i],
                wgt,
                &dy,
                bsz,
                h,
                w,
                s.cin,
                self.k,
                self.k,
                s.cout,
                &mut cols,
                &mut dw,
                &mut db,
                dx.as_deref_mut(),
            );
            self.layout.view_mut(2 * i, &mut grads).copy_from_slice(&dw);
            self.layout.view_mut(2 * i + 1, &mut grads).copy_from_slice(&db);
            if let Some(dx) = dx {
                dout = dx;
            }
        }
        Ok(StepOut { loss, grads })
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        let bsz = batch.batch_size;
        let f = self.forward(params, &batch.x_f32, bsz);
        let mut scratch = vec![0.0f32; bsz * self.classes];
        let loss = ops::softmax_xent(&f.logits, &batch.y, self.classes, &mut scratch);
        Ok(EvalOut {
            loss_sum_weighted: loss,
            ncorrect: ops::count_correct(&f.logits, &batch.y, self.classes) as f32,
        })
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        Vec::new()
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tiny() -> NativeCnn {
        NativeCnn::new(
            8,
            8,
            &[ConvStage { cin: 2, cout: 4 }, ConvStage { cin: 4, cout: 4 }],
            3,
            4,
        )
    }

    #[test]
    fn layout_shapes() {
        let m = tiny();
        assert_eq!(m.layout().num_layers(), 6);
        // final spatial 2x2 x 4 channels = 16 features
        assert_eq!(m.layout().layers[4].shape, vec![16, 3]);
    }

    #[test]
    fn gradient_matches_numerical() {
        let mut m = tiny();
        let params = m.init_params(1);
        let mut rng = Pcg32::seeded(2);
        let x = rng.normal_vec(4 * 8 * 8 * 2, 1.0);
        let y: Vec<i32> = vec![0, 1, 2, 1];
        let batch = Batch::f32(x, y, 4);
        let out = m.step(&params, &batch).unwrap();
        let eps = 1e-2;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10 {
            let i = rng.below(params.len() as u32) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let lp = m.step(&pp, &batch).unwrap().loss;
            let lm = m.step(&pm, &batch).unwrap().loss;
            let num = (lp - lm) / (2.0 * eps);
            let ana = out.grads[i];
            assert!(
                (num - ana).abs() < 3e-2_f32.max(0.15 * num.abs()),
                "grad[{i}] num {num} ana {ana}"
            );
        }
    }

    #[test]
    fn learns_channel_separable_task() {
        // class = which input channel carries signal
        let mut m = NativeCnn::new(
            8,
            8,
            &[ConvStage { cin: 3, cout: 8 }],
            3,
            16,
        );
        let mut params = m.init_params(5);
        let mut rng = Pcg32::seeded(6);
        let gen = |rng: &mut Pcg32, n: usize| {
            let mut x = vec![0.0f32; n * 8 * 8 * 3];
            let mut y = vec![0i32; n];
            for s in 0..n {
                let cls = rng.below(3) as usize;
                for p in 0..64 {
                    x[(s * 64 + p) * 3 + cls] = 1.0 + 0.3 * rng.normal();
                    for c in 0..3 {
                        x[(s * 64 + p) * 3 + c] += 0.2 * rng.normal();
                    }
                }
                y[s] = cls as i32;
            }
            Batch::f32(x, y, n)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..60 {
            let b = gen(&mut rng, 16);
            let out = m.step(&params, &b).unwrap();
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (p, g) in params.iter_mut().zip(out.grads.iter()) {
                *p -= 0.1 * g;
            }
        }
        assert!(last < first * 0.5, "first {first} last {last}");
    }
}
