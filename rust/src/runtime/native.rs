//! Hermetic MLP executor — a thin spec-builder over the layer graph.
//!
//! `NativeMlp` assembles `[Fc, Relu, Fc, Relu, ..., Fc]` from a `[d0, ...,
//! dk]` dim list on [`NativeNet`](super::net::NativeNet) — same parameter
//! layout convention as python's `_build_dnn` (alternating `fc{i}_w [a,b]`,
//! `fc{i}_b [b]`) and bit-identical forward/backward to the pre-graph
//! monolithic executor (same kernels, same call order). Used by hermetic
//! tests, the parallel multi-learner engine, and as a PJRT numerics
//! cross-check (rust/tests/pjrt_integration.rs).

use std::sync::Arc;

use anyhow::Result;

use super::net::{Fc, Layer, NativeNet, Relu};
use super::{Batch, EvalOut, Executor, ExecutorFactory, GradReady, StepOut};
use crate::models::Layout;

#[derive(Clone)]
pub struct NativeMlp {
    pub dims: Vec<usize>,
    net: NativeNet,
}

impl NativeMlp {
    pub fn new(dims: &[usize], eval_batch: usize) -> NativeMlp {
        assert!(dims.len() >= 2, "an MLP needs at least [in, out] dims");
        let k = dims.len() - 1;
        let mut layers: Vec<Arc<dyn Layer>> = Vec::with_capacity(2 * k - 1);
        for (i, w) in dims.windows(2).enumerate() {
            layers.push(Arc::new(Fc::new(&format!("fc{}", i + 1), w[0], w[1])));
            if i + 1 < k {
                layers.push(Arc::new(Relu));
            }
        }
        NativeMlp {
            dims: dims.to_vec(),
            net: NativeNet::new("native_mlp", layers, dims[0], eval_batch),
        }
    }

    pub fn layout(&self) -> &Layout {
        self.net.layout()
    }

    /// He-style deterministic init, same distribution family as the python
    /// exporter (not bit-identical — used for hermetic tests only).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let layout = self.net.layout();
        let mut rng = crate::util::rng::Pcg32::new(seed, 0x1417);
        let mut out = vec![0.0f32; layout.total];
        for (i, l) in layout.layers.iter().enumerate() {
            if i % 2 == 0 {
                let fan_in = l.shape[0] as f32;
                let std = (2.0 / fan_in).sqrt();
                for v in out[l.offset..l.offset + l.len()].iter_mut() {
                    *v = rng.normal() * std;
                }
            }
        }
        out
    }
}

/// The model spec doubles as the engine's executor factory: executors are
/// pure functions of (dims, layout), so stamping one out per learner is a
/// cheap clone and every copy produces bit-identical results.
impl ExecutorFactory for NativeMlp {
    fn backend(&self) -> &'static str {
        "native_mlp"
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

impl Executor for NativeMlp {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        self.net.step(params, batch)
    }

    fn streams(&self) -> bool {
        self.net.streams()
    }

    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &Batch,
        on_ready: &mut GradReady<'_>,
    ) -> Result<StepOut> {
        self.net.step_streamed(params, batch, on_ready)
    }

    fn step_streamed_into(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grads: &mut Vec<f32>,
        on_ready: &mut GradReady<'_>,
    ) -> Result<f32> {
        self.net.step_streamed_into(params, batch, grads, on_ready)
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        self.net.eval(params, batch)
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        self.net.step_batch_sizes()
    }

    fn eval_batch(&self) -> usize {
        self.net.eval_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn toy_batch(bsz: usize, dim: usize, classes: usize, seed: u64) -> Batch {
        let mut rng = Pcg32::seeded(seed);
        let x = rng.normal_vec(bsz * dim, 1.0);
        let y: Vec<i32> = (0..bsz).map(|i| (i % classes) as i32).collect();
        Batch::f32(x, y, bsz)
    }

    #[test]
    fn layout_matches_dnn_convention() {
        let m = NativeMlp::new(&[6, 5, 3], 4);
        let l = m.layout();
        assert_eq!(l.num_layers(), 4);
        assert_eq!(l.layers[0].name, "fc1_w");
        assert_eq!(l.layers[0].shape, vec![6, 5]);
        assert_eq!(l.layers[3].name, "fc2_b");
        assert_eq!(l.total, 6 * 5 + 5 + 5 * 3 + 3);
    }

    #[test]
    fn gradient_matches_numerical() {
        let mut m = NativeMlp::new(&[6, 5, 3], 4);
        let params = m.init_params(1);
        let batch = toy_batch(4, 6, 3, 2);
        let out = m.step(&params, &batch).unwrap();
        let eps = 1e-3;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..12 {
            let i = rng.below(params.len() as u32) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let lp = m.step(&pp, &batch).unwrap().loss;
            let lm = m.step(&pm, &batch).unwrap().loss;
            let num = (lp - lm) / (2.0 * eps);
            let ana = out.grads[i];
            assert!(
                (num - ana).abs() < 2e-2 * num.abs().max(1.0),
                "i={i} num={num} ana={ana}"
            );
        }
    }

    #[test]
    fn sgd_learns_separable_task() {
        let mut m = NativeMlp::new(&[8, 16, 4], 32);
        let mut params = m.init_params(7);
        // class means pattern: one-hot-ish blocks
        let mut rng = Pcg32::seeded(11);
        let gen = |rng: &mut Pcg32, n: usize| -> Batch {
            let mut x = vec![0.0f32; n * 8];
            let mut y = vec![0i32; n];
            for i in 0..n {
                let cls = rng.below(4) as usize;
                for j in 0..8 {
                    x[i * 8 + j] = if j / 2 == cls { 1.0 } else { 0.0 } + 0.3 * rng.normal();
                }
                y[i] = cls as i32;
            }
            Batch::f32(x, y, n)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..150 {
            let b = gen(&mut rng, 32);
            let out = m.step(&params, &b).unwrap();
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (p, g) in params.iter_mut().zip(out.grads.iter()) {
                *p -= 0.3 * g;
            }
        }
        assert!(last < first * 0.5, "first {first} last {last}");
        // accuracy check
        let b = gen(&mut rng, 32);
        let ev = m.eval(&params, &b).unwrap();
        assert!(ev.ncorrect >= 24.0, "ncorrect {}", ev.ncorrect);
    }

    #[test]
    fn eval_counts_bounded() {
        let mut m = NativeMlp::new(&[4, 3], 8);
        let params = m.init_params(5);
        let batch = toy_batch(8, 4, 3, 6);
        let ev = m.eval(&params, &batch).unwrap();
        assert!(ev.ncorrect >= 0.0 && ev.ncorrect <= 8.0);
    }
}
