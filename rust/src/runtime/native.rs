//! Pure-rust reference executor for MLPs (fc stacks with ReLU).
//!
//! Exists so the engine, compression and topology layers have a hermetic,
//! artifact-free compute backend for unit/integration tests, and to
//! cross-check PJRT numerics (rust/tests/pjrt_integration.rs trains the
//! same MLP both ways). Supports any [d0, d1, ..., dk] relu stack with the
//! same parameter layout convention as python's `_build_dnn` (alternating
//! w [a,b], b [b]).

use anyhow::{bail, Result};

use super::{Batch, EvalOut, Executor, ExecutorFactory, StepOut};
use crate::models::{LayerKind, Layout};
use crate::tensor::ops;

#[derive(Clone)]
pub struct NativeMlp {
    pub dims: Vec<usize>,
    layout: Layout,
    eval_batch: usize,
}

/// The model spec doubles as the engine's executor factory: executors are
/// pure functions of (dims, layout), so stamping one out per learner is a
/// cheap clone and every copy produces bit-identical results.
impl ExecutorFactory for NativeMlp {
    fn backend(&self) -> &'static str {
        "native_mlp"
    }

    fn build_worker(&self) -> Result<Box<dyn Executor + Send>> {
        Ok(Box::new(self.clone()))
    }
}

impl NativeMlp {
    pub fn new(dims: &[usize], eval_batch: usize) -> NativeMlp {
        let mut specs: Vec<(String, Vec<usize>, LayerKind)> = Vec::new();
        for (i, w) in dims.windows(2).enumerate() {
            specs.push((format!("fc{}_w", i + 1), vec![w[0], w[1]], LayerKind::Fc));
            specs.push((format!("fc{}_b", i + 1), vec![w[1]], LayerKind::Fc));
        }
        let layout = Layout::from_specs(
            &specs
                .iter()
                .map(|(n, s, k)| (n.as_str(), s.as_slice(), *k))
                .collect::<Vec<_>>(),
        );
        NativeMlp {
            dims: dims.to_vec(),
            layout,
            eval_batch,
        }
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// He-style deterministic init, same distribution family as the python
    /// exporter (not bit-identical — used for hermetic tests only).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::new(seed, 0x1417);
        let mut out = vec![0.0f32; self.layout.total];
        for (i, l) in self.layout.layers.iter().enumerate() {
            if i % 2 == 0 {
                let fan_in = l.shape[0] as f32;
                let std = (2.0 / fan_in).sqrt();
                for v in out[l.offset..l.offset + l.len()].iter_mut() {
                    *v = rng.normal() * std;
                }
            }
        }
        out
    }

    /// Forward through the stack; returns per-layer activations
    /// (activations[0] = input, activations[k] = logits).
    fn forward(&self, params: &[f32], x: &[f32], bsz: usize) -> Vec<Vec<f32>> {
        let mut acts = vec![x.to_vec()];
        let k = self.dims.len() - 1;
        for li in 0..k {
            let (a, b) = (self.dims[li], self.dims[li + 1]);
            let w = self.layout.view(2 * li, params);
            let bias = self.layout.view(2 * li + 1, params);
            let mut out = vec![0.0f32; bsz * b];
            ops::matmul(&acts[li], w, &mut out, bsz, a, b, false);
            for r in 0..bsz {
                for j in 0..b {
                    out[r * b + j] += bias[j];
                }
            }
            if li + 1 < k {
                ops::relu(&mut out);
            }
            acts.push(out);
        }
        acts
    }
}

impl Executor for NativeMlp {
    fn step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOut> {
        let bsz = batch.batch_size;
        let c = *self.dims.last().unwrap();
        if batch.x_f32.len() != bsz * self.dims[0] {
            bail!("x length mismatch");
        }
        let acts = self.forward(params, &batch.x_f32, bsz);
        let logits = acts.last().unwrap();
        let mut dlogits = vec![0.0f32; bsz * c];
        let loss = ops::softmax_xent(logits, &batch.y, c, &mut dlogits);

        let mut grads = vec![0.0f32; self.layout.total];
        let k = self.dims.len() - 1;
        let mut dout = dlogits;
        for li in (0..k).rev() {
            let (a, b) = (self.dims[li], self.dims[li + 1]);
            // dW = act^T @ dout   (act: [bsz, a], dout: [bsz, b])
            {
                let gw = self.layout.view_mut(2 * li, &mut grads);
                ops::matmul_at_b(&acts[li], &dout, gw, a, bsz, b);
            }
            {
                let gb = self.layout.view_mut(2 * li + 1, &mut grads);
                for r in 0..bsz {
                    for j in 0..b {
                        gb[j] += dout[r * b + j];
                    }
                }
            }
            if li > 0 {
                // dact = dout @ W^T, then mask by relu
                let w = self.layout.view(2 * li, params);
                let mut dact = vec![0.0f32; bsz * a];
                ops::matmul_a_bt(&dout, w, &mut dact, bsz, b, a);
                ops::relu_grad(&acts[li], &mut dact);
                dout = dact;
            }
        }
        Ok(StepOut { loss, grads })
    }

    fn eval(&mut self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        let bsz = batch.batch_size;
        let c = *self.dims.last().unwrap();
        let acts = self.forward(params, &batch.x_f32, bsz);
        let logits = acts.last().unwrap();
        let mut scratch = vec![0.0f32; bsz * c];
        let loss = ops::softmax_xent(logits, &batch.y, c, &mut scratch);
        let ncorrect = ops::count_correct(logits, &batch.y, c) as f32;
        Ok(EvalOut {
            loss_sum_weighted: loss,
            ncorrect,
        })
    }

    fn step_batch_sizes(&self) -> Vec<usize> {
        Vec::new() // any
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn toy_batch(bsz: usize, dim: usize, classes: usize, seed: u64) -> Batch {
        let mut rng = Pcg32::seeded(seed);
        let x = rng.normal_vec(bsz * dim, 1.0);
        let y: Vec<i32> = (0..bsz).map(|i| (i % classes) as i32).collect();
        Batch::f32(x, y, bsz)
    }

    #[test]
    fn gradient_matches_numerical() {
        let mut m = NativeMlp::new(&[6, 5, 3], 4);
        let params = m.init_params(1);
        let batch = toy_batch(4, 6, 3, 2);
        let out = m.step(&params, &batch).unwrap();
        let eps = 1e-3;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..12 {
            let i = rng.below(params.len() as u32) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let lp = m.step(&pp, &batch).unwrap().loss;
            let lm = m.step(&pm, &batch).unwrap().loss;
            let num = (lp - lm) / (2.0 * eps);
            let ana = out.grads[i];
            assert!(
                (num - ana).abs() < 2e-2 * num.abs().max(1.0),
                "i={i} num={num} ana={ana}"
            );
        }
    }

    #[test]
    fn sgd_learns_separable_task() {
        let mut m = NativeMlp::new(&[8, 16, 4], 32);
        let mut params = m.init_params(7);
        // class means pattern: one-hot-ish blocks
        let mut rng = Pcg32::seeded(11);
        let gen = |rng: &mut Pcg32, n: usize| -> Batch {
            let mut x = vec![0.0f32; n * 8];
            let mut y = vec![0i32; n];
            for i in 0..n {
                let cls = rng.below(4) as usize;
                for j in 0..8 {
                    x[i * 8 + j] = if j / 2 == cls { 1.0 } else { 0.0 } + 0.3 * rng.normal();
                }
                y[i] = cls as i32;
            }
            Batch::f32(x, y, n)
        };
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..150 {
            let b = gen(&mut rng, 32);
            let out = m.step(&params, &b).unwrap();
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (p, g) in params.iter_mut().zip(out.grads.iter()) {
                *p -= 0.3 * g;
            }
        }
        assert!(last < first * 0.5, "first {first} last {last}");
        // accuracy check
        let b = gen(&mut rng, 32);
        let ev = m.eval(&params, &b).unwrap();
        assert!(ev.ncorrect >= 24.0, "ncorrect {}", ev.ncorrect);
    }

    #[test]
    fn eval_counts_bounded() {
        let mut m = NativeMlp::new(&[4, 3], 8);
        let params = m.init_params(5);
        let batch = toy_batch(8, 4, 3, 6);
        let ev = m.eval(&params, &batch).unwrap();
        assert!(ev.ncorrect >= 0.0 && ev.ncorrect <= 8.0);
    }
}
