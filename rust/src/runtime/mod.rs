//! Model execution runtime.
//!
//! `Executor` is the coordinator's contract with the compute layer: given
//! flat parameters and a batch, produce (loss, flat gradients). Two
//! implementations:
//!
//! * `pjrt::PjrtExecutor` — the production path (feature `pjrt`): loads the
//!   AOT-lowered HLO text (L1 Pallas kernels + L2 JAX models) and runs it on
//!   the PJRT CPU client via the `xla` crate. Python is never involved.
//! * `net::NativeNet` — the pure-rust layer-graph engine: composable
//!   `Layer` nodes (fc, relu, conv+pool, embedding, LSTM) over a shared
//!   flat `Layout`. `native::NativeMlp`, `native_cnn::NativeCnn` and
//!   `native_lstm::NativeCharLstm` are thin spec-builders over it — the
//!   hermetic backends used by tests (no artifacts needed), by the
//!   parallel multi-learner engine, and as a cross-check of PJRT numerics.
//!
//! `ExecutorFactory` is how the engine provisions compute for N learners:
//! the native backends stamp out one `Send` executor per learner so the
//! per-learner phase fans out across threads; the PJRT backend is `!Send`
//! (thread-local `Rc` client) and declares `parallel() == false`, which
//! makes the engine fall back to the documented sequential path behind the
//! same API (DESIGN.md §Threading).

pub mod native;
pub mod native_cnn;
pub mod native_lstm;
pub mod net;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::data::XBuf;

/// A training batch, already laid out to the executor's static shapes.
pub struct Batch {
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
    pub batch_size: usize,
}

impl Batch {
    pub fn f32(x: Vec<f32>, y: Vec<i32>, batch_size: usize) -> Batch {
        Batch {
            x_f32: x,
            x_i32: Vec::new(),
            y,
            batch_size,
        }
    }
    pub fn i32(x: Vec<i32>, y: Vec<i32>, batch_size: usize) -> Batch {
        Batch {
            x_f32: Vec::new(),
            x_i32: x,
            y,
            batch_size,
        }
    }
    pub fn x_buf(&mut self) -> XBuf<'_> {
        if self.x_i32.is_empty() {
            XBuf::F32(&mut self.x_f32)
        } else {
            XBuf::I32(&mut self.x_i32)
        }
    }
}

/// Result of one forward+backward.
pub struct StepOut {
    pub loss: f32,
    /// Flat gradient, layout order (same length as params).
    pub grads: Vec<f32>,
}

/// Result of one evaluation batch.
pub struct EvalOut {
    pub loss_sum_weighted: f32,
    pub ncorrect: f32,
}

/// Grad-ready notification for the streamed step path: invoked with
/// `(layers, grads)` where `layers` is the range of **layout-layer** indices
/// whose spans inside the flat gradient `grads` are final and will not be
/// written again this step. `NativeNet` fires one range per graph node as
/// its backward completes (reverse graph order — the output head's layers
/// arrive first, the input layers last); the ranges partition
/// `0..layout.num_layers()`.
pub type GradReady<'a> = dyn FnMut(std::ops::Range<usize>, &[f32]) + 'a;

// Note: the trait itself does not require `Send` — the PJRT client wraps an
// `Rc` and stays pinned to one thread. Backends that CAN cross threads hand
// out `Box<dyn Executor + Send>` through `ExecutorFactory::build_worker`.
pub trait Executor {
    /// forward+backward at a given per-learner batch size.
    fn step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<StepOut>;
    /// evaluation at the executor's eval batch size.
    fn eval(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<EvalOut>;
    /// Batch sizes `step` supports (empty = any).
    fn step_batch_sizes(&self) -> Vec<usize>;
    /// The batch size `eval` expects.
    fn eval_batch(&self) -> usize;

    /// Whether [`step_streamed`](Self::step_streamed) reports gradients
    /// layer-by-layer during backward. Backends that run backward as one
    /// opaque program (PJRT's AOT executable) leave this `false`: the
    /// default `step_streamed` never fires the callback and the caller
    /// packs everything after the step — barrier-equivalent behavior behind
    /// the same API.
    fn streams(&self) -> bool {
        false
    }

    /// forward+backward with grad-ready streaming: implementations that
    /// return `streams() == true` invoke `on_ready` as each layout-layer
    /// gradient span becomes final, enabling the engine to overlap pack +
    /// exchange with the remaining backward. Must compute bit-identical
    /// results to [`step`](Self::step).
    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &Batch,
        on_ready: &mut GradReady<'_>,
    ) -> anyhow::Result<StepOut> {
        let _ = on_ready;
        self.step(params, batch)
    }

    /// [`step_streamed`](Self::step_streamed) writing the flat gradient into
    /// a caller-owned buffer instead of returning a fresh `Vec` — the
    /// engine's steady-state entry point: a learner passes its reusable
    /// grads buffer every step, so backends that implement this natively
    /// (`NativeNet`) allocate nothing per step. The default delegates to
    /// `step_streamed` and moves the result, so every backend supports the
    /// API (with the allocation the legacy path always paid).
    fn step_streamed_into(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grads: &mut Vec<f32>,
        on_ready: &mut GradReady<'_>,
    ) -> anyhow::Result<f32> {
        let out = self.step_streamed(params, batch, on_ready)?;
        *grads = out.grads;
        Ok(out.loss)
    }
}

/// Provisions executors for the engine — one per learner when the backend
/// supports thread fan-out, plus a local one for evaluation and the
/// sequential fallback.
///
/// The factory is `Send + Sync` so `std::thread::scope` workers may hold it;
/// executor *instances* are single-owner (`&mut self` API) and are never
/// shared across threads.
pub trait ExecutorFactory: Send + Sync {
    /// Backend name for logs/benches.
    fn backend(&self) -> &'static str;

    /// Whether `build_worker` executors may run on worker threads. When
    /// false the engine runs every learner sequentially on the calling
    /// thread with one shared `build_local` executor — bit-identical
    /// results, no parallel speedup (the PJRT case).
    fn parallel(&self) -> bool {
        true
    }

    /// Build a `Send` executor owned by one learner. Backends with
    /// `parallel() == false` return an error here.
    fn build_worker(&self) -> anyhow::Result<Box<dyn Executor + Send>>;

    /// Build an executor pinned to the calling thread (evaluation + the
    /// sequential fallback). Every backend must support this.
    fn build_local(&self) -> anyhow::Result<Box<dyn Executor>> {
        let exe: Box<dyn Executor> = self.build_worker()?;
        Ok(exe)
    }
}
