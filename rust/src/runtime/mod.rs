//! Model execution runtime.
//!
//! `Executor` is the coordinator's contract with the compute layer: given
//! flat parameters and a batch, produce (loss, flat gradients). Two
//! implementations:
//!
//! * `pjrt::PjrtExecutor` — the production path: loads the AOT-lowered HLO
//!   text (L1 Pallas kernels + L2 JAX models) and runs it on the PJRT CPU
//!   client via the `xla` crate. Python is never involved.
//! * `native::NativeMlp` — a pure-rust reference executor for FC stacks,
//!   used by hermetic tests (no artifacts needed) and as a cross-check of
//!   the PJRT numerics.

pub mod native;
pub mod native_cnn;
pub mod pjrt;

use crate::data::XBuf;

/// A training batch, already laid out to the executor's static shapes.
pub struct Batch {
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
    pub batch_size: usize,
}

impl Batch {
    pub fn f32(x: Vec<f32>, y: Vec<i32>, batch_size: usize) -> Batch {
        Batch {
            x_f32: x,
            x_i32: Vec::new(),
            y,
            batch_size,
        }
    }
    pub fn i32(x: Vec<i32>, y: Vec<i32>, batch_size: usize) -> Batch {
        Batch {
            x_f32: Vec::new(),
            x_i32: x,
            y,
            batch_size,
        }
    }
    pub fn x_buf(&mut self) -> XBuf<'_> {
        if self.x_i32.is_empty() {
            XBuf::F32(&mut self.x_f32)
        } else {
            XBuf::I32(&mut self.x_i32)
        }
    }
}

/// Result of one forward+backward.
pub struct StepOut {
    pub loss: f32,
    /// Flat gradient, layout order (same length as params).
    pub grads: Vec<f32>,
}

/// Result of one evaluation batch.
pub struct EvalOut {
    pub loss_sum_weighted: f32,
    pub ncorrect: f32,
}

// Note: not `Send` — the PJRT client wraps an `Rc`. The engine runs learners
// sequentially in one thread (DESIGN.md §Substitutions), so this costs nothing.
pub trait Executor {
    /// forward+backward at a given per-learner batch size.
    fn step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<StepOut>;
    /// evaluation at the executor's eval batch size.
    fn eval(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<EvalOut>;
    /// Batch sizes `step` supports (empty = any).
    fn step_batch_sizes(&self) -> Vec<usize>;
    /// The batch size `eval` expects.
    fn eval_batch(&self) -> usize;
}
