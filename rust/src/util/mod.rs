//! Infrastructure substrates: JSON, PRNG, CLI parsing, timing.
//!
//! These exist because the build environment is offline and the vendored
//! crate set lacks serde_json / clap / rand / criterion; see DESIGN.md §3.

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;
