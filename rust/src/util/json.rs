//! Minimal JSON parser + serializer.
//!
//! serde/serde_json are not in the vendored crate set for this image, so the
//! manifest (`artifacts/manifest.json`), golden vectors, experiment configs
//! and result dumps go through this module. It supports the full JSON value
//! model; numbers are kept as f64 (adequate: our integers are < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Convenience: `obj.get(key)` as f32 vec (for golden vectors).
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    pub fn from_str_slice(s: &str) -> Result<Json, String> {
        parse(s)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers so result-dumping code stays readable.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn f32s(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing characters at byte {}", p.i));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}' at {}: {}", txt, start, e))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|e| e.to_string())?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path for big arrays)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\\n\""] {
            let v = Json::from_str_slice(src).unwrap();
            let back = Json::from_str_slice(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::from_str_slice(
            r#"{"a": [1, 2.5, {"b": "x"}], "c": null, "d": true}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("d").as_bool(), Some(true));
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::from_str_slice("[1, -2.5, 3e2]").unwrap();
        assert_eq!(v.f32_vec().unwrap(), vec![1.0, -2.5, 300.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::from_str_slice("{").is_err());
        assert!(Json::from_str_slice("[1,]").is_err());
        assert!(Json::from_str_slice("nul").is_err());
        assert!(Json::from_str_slice("1 2").is_err());
    }

    #[test]
    fn builder_emit() {
        let v = obj(vec![
            ("name", s("run")),
            ("loss", num(1.25)),
            ("curve", f32s(&[1.0, 0.5])),
        ]);
        let txt = v.to_string();
        let back = Json::from_str_slice(&txt).unwrap();
        assert_eq!(back.get("loss").as_f64(), Some(1.25));
        assert_eq!(back.get("curve").f32_vec().unwrap(), vec![1.0, 0.5]);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::from_str_slice("\"a\\u0041b\"").unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }
}
