//! Timing + summary-statistics helpers for the hand-rolled bench harness
//! (criterion is not in the vendored crate set).

use std::time::Instant;

/// Measure a closure `iters` times after `warmup` runs; returns per-iteration
/// timings in nanoseconds.
pub fn time_n<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Vec<u64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_nanos() as u64);
    }
    out
}

/// Summary stats over nanosecond samples.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
    pub n: usize,
}

impl Stats {
    pub fn from(samples: &[u64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s: Vec<u64> = samples.to_vec();
        s.sort_unstable();
        let n = s.len();
        let mean = s.iter().sum::<u64>() as f64 / n as f64;
        let var = s
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / n as f64;
        Stats {
            mean_ns: mean,
            median_ns: s[n / 2] as f64,
            p95_ns: s[(n * 95 / 100).min(n - 1)] as f64,
            min_ns: s[0] as f64,
            stddev_ns: var.sqrt(),
            n,
        }
    }

    /// Throughput in items/s given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.0}ns", ns)
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[10, 20, 30, 40, 50]);
        assert_eq!(s.mean_ns, 30.0);
        assert_eq!(s.median_ns, 30.0);
        assert_eq!(s.min_ns, 10.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn throughput() {
        let s = Stats::from(&[1_000_000_000]); // 1s per iter
        assert!((s.throughput(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(2500.0), "2.50us");
        assert_eq!(fmt_ns(3.5e6), "3.50ms");
        assert_eq!(fmt_ns(2.5e9), "2.50s");
    }

    #[test]
    fn time_n_counts() {
        let samples = time_n(
            || {
                std::hint::black_box(1 + 1);
            },
            2,
            10,
        );
        assert_eq!(samples.len(), 10);
    }
}
