//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Every example binary and the main CLI routes through this so
//! flag behaviour is uniform.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `known_flags` are names that
    /// take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(rest.to_string());
                    } else {
                        out.opts.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    /// Parse process args (skipping argv[0]).
    pub fn parse(known_flags: &[&str]) -> Args {
        Self::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }
    pub fn f32_or(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }
    /// Comma-separated list of usizes, e.g. `--learners 1,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| p.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad entry '{p}'")))
                .collect(),
        }
    }
    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_styles() {
        let a = args(&["--lr", "0.1", "--epochs=5", "train"], &[]);
        assert_eq!(a.f32_or("lr", 0.0), 0.1);
        assert_eq!(a.usize_or("epochs", 0), 5);
        assert_eq!(a.positional(), &["train".to_string()]);
    }

    #[test]
    fn flags() {
        let a = args(&["--verbose", "--lr", "1"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.f32_or("lr", 0.0), 1.0);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args(&["--debug"], &[]);
        assert!(a.flag("debug"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["--fast", "--lr", "2"], &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.f32_or("lr", 0.0), 2.0);
    }

    #[test]
    fn lists_and_defaults() {
        let a = args(&["--learners", "1,4,8"], &[]);
        assert_eq!(a.usize_list_or("learners", &[2]), vec![1, 4, 8]);
        assert_eq!(a.usize_list_or("missing", &[2]), vec![2]);
        assert_eq!(a.str_or("name", "x"), "x");
    }
}
