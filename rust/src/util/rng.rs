//! Deterministic PRNG (PCG32) + distributions.
//!
//! The `rand` crate is not in the vendored set; we want determinism across
//! runs and learners anyway (every learner seeds from `(seed, learner_id)`),
//! so a small PCG32 with explicit streams is the right substrate.

/// PCG32 (XSH-RR 64/32) with a selectable stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Lemire-style rejection for unbiasedness.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller (cached second value dropped: simpler,
    /// and this is never on the training hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-7 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n assumed; k==n allowed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg32::seeded(7);
        let mut sum = 0.0f64;
        for _ in 0..20000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        assert!((sum / 20000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let xs: Vec<f32> = (0..50000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0usize; 5];
        for _ in 0..50000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10000.0).abs() < 600.0, "{:?}", counts);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(9);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }
}
