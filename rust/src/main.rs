//! adacomp — CLI for the AdaComp reproduction.
//!
//! Subcommands:
//!   train      train any exported model with any compression scheme
//!   inspect    print the artifacts manifest (models, layers, L_T defaults)
//!   schemes    list compression schemes and their knobs
//!
//! Examples:
//!   adacomp train --model cifar_cnn --scheme adacomp --learners 8
//!   adacomp train --model char_lstm --backend native --scheme adacomp
//!   adacomp train --model char_lstm --scheme dryden --topk 0.003
//!   adacomp inspect
//!
//! Every figure/table of the paper has a dedicated harness under examples/
//! (cargo run --release --example fig4_robustness -- --help).

use adacomp::harness::{report, Workload};
use adacomp::models::Manifest;
use adacomp::util::cli::Args;

const FLAGS: &[&str] = &["per-bin-scale", "help", "quiet"];

fn main() {
    let args = Args::parse(FLAGS);
    let cmd = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let code = match cmd {
        "train" => cmd_train(&args),
        "inspect" => cmd_inspect(&args),
        "analyze" => cmd_analyze(&args),
        "schemes" => cmd_schemes(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn cmd_train(args: &Args) -> i32 {
    let mut w = match Workload::from_args(args, "cifar_cnn") {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    // --config FILE: JSON experiment spec overrides the CLI-derived config
    // (model must match an exported artifact; dataset comes from the model).
    if let Some(path) = args.get("config") {
        match adacomp::config::load(path) {
            Ok(cfg) => {
                // rebuild the workload when the spec changes the model or
                // pins a backend different from what the CLI resolved
                let pinned = cfg.backend != "auto" && cfg.backend != w.backend;
                if cfg.model_name != w.model || pinned {
                    let ov = pinned.then_some(cfg.backend.as_str());
                    match Workload::from_args_with_backend(args, &cfg.model_name.clone(), ov) {
                        Ok(w2) => w = w2,
                        Err(e) => {
                            eprintln!("error: {e:#}");
                            return 1;
                        }
                    }
                }
                w.cfg = cfg;
                // the workload's backend is resolved at build time; keep
                // the record truthful even if the spec said "auto"
                w.cfg.backend = w.backend.clone();
            }
            Err(e) => {
                eprintln!("error loading {path}: {e:#}");
                return 1;
            }
        }
    }
    println!(
        "training {} [{}] | scheme {} | {} learners x batch {} | {} epochs | topology {} | exchange {} | staleness {} | jitter {}",
        w.model,
        w.backend,
        w.cfg.compression.kind.name(),
        w.cfg.n_learners,
        w.cfg.batch_per_learner,
        w.cfg.epochs,
        w.cfg.topology,
        w.cfg.exchange,
        w.cfg.staleness,
        w.cfg.link.jitter
    );
    match w.run_full() {
        Ok((rec, final_params)) => {
            // --save CKPT: persist trained weights (resume with --resume).
            if let Some(path) = args.get("save") {
                let ck = adacomp::train::checkpoint::Checkpoint::new(
                    w.model.clone(),
                    rec.epochs.len() as u32,
                    final_params,
                );
                if let Err(e) = ck.save(std::path::Path::new(path)) {
                    eprintln!("checkpoint save failed: {e:#}");
                } else {
                    println!("checkpoint saved to {path}");
                }
            }
            for (i, _) in rec.epochs.iter().enumerate() {
                let partial = adacomp::metrics::RunRecord {
                    epochs: rec.epochs[..=i].to_vec(),
                    ..rec.clone()
                };
                println!("{}", report::epoch_line(&partial));
            }
            if let Some(line) = report::control_line(&rec) {
                println!("{line}");
            }
            println!(
                "final: test-err {:.2}%  mean rate (wire) {:.1}x  (paper) {:.1}x  diverged: {}",
                rec.final_test_error(),
                rec.mean_rate_wire(),
                rec.mean_rate_paper(),
                rec.diverged
            );
            if let Ok((j, c)) = report::save_runs(&rec.name.clone(), &[rec]) {
                println!("saved {j} / {c}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

// note: `--resume ckpt.bin` is handled inside Workload::from_args; saving
// final weights requires running through the library API (examples/) since
// RunRecord does not carry params — see train::checkpoint.

fn cmd_inspect(args: &Args) -> i32 {
    let dir = args.str_or("artifacts", adacomp::harness::default_artifacts_dir());
    let m = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let mut t = report::Table::new(&["model", "params", "tensors", "batch", "classes", "conv-L_T", "fc-L_T"]);
    for meta in &m.models {
        let conv = meta
            .layout
            .layers
            .iter()
            .find(|l| l.kind == adacomp::LayerKind::Conv)
            .map(|l| l.lt_default.to_string())
            .unwrap_or_else(|| "-".into());
        let fc = meta
            .layout
            .layers
            .iter()
            .find(|l| l.kind != adacomp::LayerKind::Conv)
            .map(|l| l.lt_default.to_string())
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            meta.name.clone(),
            meta.layout.total.to_string(),
            meta.layout.num_layers().to_string(),
            meta.batch.to_string(),
            meta.num_classes.to_string(),
            conv,
            fc,
        ]);
    }
    t.print();
    0
}

/// One forward/backward/pack on a real batch: per-layer compression report.
fn cmd_analyze(args: &Args) -> i32 {
    use adacomp::compress;
    let w = match Workload::from_args(args, "cifar_cnn") {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let meta = w.manifest.model(&w.model).unwrap().clone();
    let mut exe = match w.local_executor() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let mut comp = compress::build(&w.cfg.compression, &meta.layout);
    // one representative batch
    let bs = meta.batch;
    let ds = &w.dataset;
    let mut batch = if ds.int_input() {
        adacomp::runtime::Batch::i32(vec![0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
    } else {
        adacomp::runtime::Batch::f32(vec![0.0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
    };
    let idx: Vec<usize> = (0..bs).collect();
    if batch.x_i32.is_empty() {
        ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::F32(&mut batch.x_f32), &mut batch.y);
    } else {
        ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::I32(&mut batch.x_i32), &mut batch.y);
    }
    let out = match exe.step(&w.init_params, &batch) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!(
        "model {} | scheme {} | first-step loss {:.4}",
        w.model,
        w.cfg.compression.kind.name(),
        out.loss
    );
    let mut t = report::Table::new(&[
        "layer", "kind", "elements", "L_T", "sent", "sparsity", "rate(wire)", "rate(paper)",
    ]);
    for (li, l) in meta.layout.layers.iter().enumerate() {
        let p = comp.pack_layer(li, meta.layout.view(li, &out.grads));
        t.row(vec![
            l.name.clone(),
            l.kind.name().into(),
            l.len().to_string(),
            w.cfg.compression.lt_for(l.kind).to_string(),
            p.sent().to_string(),
            format!("{:.4}", p.sent() as f64 / p.n as f64),
            format!("{:.1}x", p.rate_wire()),
            format!("{:.1}x", p.rate_paper()),
        ]);
    }
    t.print();
    0
}

fn cmd_schemes() -> i32 {
    let mut t = report::Table::new(&["scheme", "selection", "quantization", "knobs"]);
    for (s, sel, q, k) in [
        ("adacomp", "per-bin soft threshold |H|>=max|G|", "ternary, layer scale", "--lt / --lt-conv / --lt-fc"),
        ("ls", "per-bin max only (ablation)", "ternary, layer scale", "--lt"),
        ("dryden", "global top-k% (quickselect)", "1-bit +/- means", "--topk"),
        ("onebit", "dense (all elements)", "1-bit +/- means", ""),
        ("terngrad", "stochastic, unbiased", "ternary, max scale", ""),
        ("strom", "fixed |G| > tau", "+/- tau", "--tau"),
        ("none", "dense", "f32", ""),
    ] {
        t.row(vec![s.into(), sel.into(), q.into(), k.into()]);
    }
    t.print();
    0
}

fn print_help() {
    println!(
        "adacomp — AdaComp (AAAI'18) reproduction CLI

USAGE:
  adacomp train [--model M] [--scheme S] [--learners N] [--batch B]
                [--epochs E] [--optimizer sgd|adam|rmsprop]
                [--lt SPEC]     (sparsifier bin size L_T: a plain integer
                                 sets every layer; a per-kind list
                                 conv=64,fc=500[,lstm=N][,embed=N] tunes
                                 kinds individually. Also --lt-conv /
                                 --lt-fc / --lt-lstm / --lt-embed)
                [--topology ring|ps|ps:S|hier:G]
                                (ps:S = S independent shard servers, reduce-
                                 plan buckets partitioned across them;
                                 hier:G = racks of G learners feeding a
                                 root. Identical results for every choice)
                [--bucket-bytes B]
                                (reduce-plan coalescing threshold: layers
                                 below B dense wire bytes share one bucket
                                 message. 0 = auto from the link model,
                                 1 = one message per layer)
                [--lr LR] [--seed S] [--seq-len T]
                [--backend native|pjrt|auto]
                                (native = hermetic layer-graph executors, no
                                 artifacts needed: mnist_dnn, mnist_cnn,
                                 cifar_cnn, bn50_dnn_s, char_lstm)
                [--threads T]   (0 = auto; learner phase fan-out over the
                                 persistent worker pool, results are
                                 bit-identical for every thread count)
                [--kernel-threads N]
                                (intra-GEMM tile fan-out per learner over
                                 the shared compute pool, 0 <= N <= 64.
                                 0 = auto budget max(1, threads /
                                 active learners), re-derived when the
                                 elastic fleet churns. Bit-identical
                                 results at every value)
                [--exchange streamed|barrier]
                                (streamed = overlap per-layer pack/exchange
                                 with the remaining backward, the default;
                                 barrier = classic join-then-exchange round.
                                 Bit-identical results either way)
                [--staleness K] (bounded-staleness window: learners run up
                                 to K steps ahead of the applied-update
                                 frontier, gradients computed at the K-back
                                 param version. 0 = synchronous (default),
                                 bit-identical to the classic engine;
                                 results at fixed K are deterministic at
                                 every thread count)
                [--jitter F]    (deterministic per-learner compute jitter,
                                 0.0 <= F < 1.0: each (learner, step) draws
                                 up to +F extra compute plus occasional
                                 straggler episodes from a seeded xorshift.
                                 Shapes only the simulated timeline /
                                 stall accounting — never the results)
                [--churn SPEC]  (elastic fleet: comma-separated membership
                                 events kind@STEP:COUNT with kind one of
                                 fail (learners vanish, residual gradient
                                 state lost), leave (graceful handover:
                                 residue + optimizer state fold into the
                                 survivors), join (cold learners added).
                                 e.g. --churn fail@120:2,join@300:1.
                                 Deterministic: same seed + schedule gives
                                 bit-identical results at every thread
                                 count and exchange mode)
                [--mtbf STEPS]  (random failure injection: each step one
                                 learner fails with probability 1/STEPS,
                                 drawn from a seeded generator so runs
                                 reproduce. 0 = off, composes with --churn)
                [--controller off|on]
                                (adaptive control plane: at each epoch
                                 boundary a deterministic feedback rule
                                 re-tunes the staleness window, the bucket
                                 coalescing threshold, and per-layer L_T
                                 from that epoch's measurements. off =
                                 default, bit-identical to the static
                                 engine; on is bit-deterministic at every
                                 thread count and exchange mode, decisions
                                 land in the run record)
  adacomp inspect [--artifacts DIR]
  adacomp schemes

  adacomp train --model char_lstm --backend native --scheme adacomp
    trains the paper's recurrent workload (embed -> LSTM x2 -> fc) fully
    offline with AdaComp at the fc/lstm/embed L_T default of 500.

Figure harnesses (one per paper figure/table) live in examples/:
  cargo run --release --example quickstart
  cargo run --release --example table2_accuracy
  cargo run --release --example fig4_robustness -- --lts 50,500,2000
  cargo run --release --example e2e_transformer"
    );
}
