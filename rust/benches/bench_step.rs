//! End-to-end step benchmark: the full Algorithm-1 loop (PJRT fwd/bwd +
//! pack + exchange + update) per model, with a pack/exchange/update time
//! breakdown — shows where the paper's "compression must be much cheaper
//! than backprop" constraint lands on this testbed.
//!
//! Requires artifacts (skips models that are missing).
//!
//!   cargo bench --bench bench_step

use adacomp::comm::{topology, Fabric, LinkModel};
use adacomp::compress::{self, Config, Kind};
use adacomp::harness::{dataset_for, defaults_for};
use adacomp::models::Manifest;
use adacomp::runtime::pjrt::PjrtExecutor;
use adacomp::runtime::{Batch, Executor};
use adacomp::util::timer::{fmt_ns, Stats, Stopwatch};

fn main() -> anyhow::Result<()> {
    let dir = adacomp::harness::default_artifacts_dir();
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(_) => {
            println!("artifacts missing — run `make artifacts` first; skipping bench_step");
            return Ok(());
        }
    };

    println!(
        "{:<12} {:>9} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "model", "params", "batch", "step(hlo)", "pack", "exchange", "update", "pack-%"
    );
    for model in ["mnist_dnn", "cifar_cnn", "bn50_dnn_s", "char_lstm", "transformer"] {
        if manifest.model(model).is_err() {
            continue;
        }
        let meta = manifest.model(model)?.clone();
        let params = manifest.load_init(&meta)?;
        let mut exe = PjrtExecutor::new(&manifest, model)?;
        let d = defaults_for(model);
        let ds = dataset_for(model, 1, 512.max(d.batch * 2), 128, meta.seq_len)?;
        let bs = meta.batch;
        let mut batch = if ds.int_input() {
            Batch::i32(vec![0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
        } else {
            Batch::f32(vec![0.0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
        };
        let idx: Vec<usize> = (0..bs).collect();
        if batch.x_i32.is_empty() {
            ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::F32(&mut batch.x_f32), &mut batch.y);
        } else {
            ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::I32(&mut batch.x_i32), &mut batch.y);
        }

        let cfg = Config::with_kind(Kind::AdaComp);
        let mut comp = compress::build(&cfg, &meta.layout);
        let mut topo = topology::build("ring").unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        let lens: Vec<usize> = meta.layout.layers.iter().map(|l| l.len()).collect();
        let mut opt = adacomp::optim::Sgd::new(params.len(), 0.9);
        let mut p = params.clone();

        let iters = 8usize;
        let (mut t_step, mut t_pack, mut t_ex, mut t_up) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        // warmup (compile)
        let _ = exe.step(&p, &batch)?;
        for _ in 0..iters {
            let sw = Stopwatch::start();
            let out = exe.step(&p, &batch)?;
            t_step.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let packets: Vec<compress::Packet> = (0..meta.layout.num_layers())
                .map(|li| comp.pack_layer(li, meta.layout.view(li, &out.grads)))
                .collect();
            t_pack.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let per_learner = vec![packets; 2];
            let red = topo.exchange(&per_learner, &lens, &mut fabric);
            t_ex.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let mut g = vec![0.0f32; p.len()];
            for (li, s) in red.sums.iter().enumerate() {
                meta.layout.view_mut(li, &mut g).copy_from_slice(s);
            }
            use adacomp::optim::Optimizer;
            opt.step(&mut p, &g, 0.01);
            t_up.push((sw.secs() * 1e9) as u64);
        }
        let (ss, sp, se, su) = (
            Stats::from(&t_step),
            Stats::from(&t_pack),
            Stats::from(&t_ex),
            Stats::from(&t_up),
        );
        println!(
            "{:<12} {:>9} {:>6} {:>12} {:>12} {:>12} {:>12} {:>9.1}%",
            model,
            meta.layout.total,
            bs,
            fmt_ns(ss.mean_ns),
            fmt_ns(sp.mean_ns),
            fmt_ns(se.mean_ns),
            fmt_ns(su.mean_ns),
            100.0 * sp.mean_ns / ss.mean_ns
        );
    }
    println!("\npack-% = compression cost relative to fwd/bwd — the paper requires this to be small");
    Ok(())
}
